"""Paper Figure 8c: approximation potential vs parallelism.

Fixed workload of N options; `items_per_thread` = options priced
sequentially per element. More items/element -> longer TAF history per
state slot -> higher approximated fraction; fewer elements -> less
parallelism to hide latency (on TPU: fewer busy cores/lanes). We report the
approximated fraction and the modeled speedup curve; the parallelism
penalty term is items/element when elements < machine lanes.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "examples")

import numpy as np
import jax
import jax.numpy as jnp

from apps import binomial_options
from repro.core import ApproxSpec, Level, TAFParams, Technique
from repro.core import taf as taf_mod

TOTAL = 2048
LANES = 128  # a VREG row: elements below this under-utilize the vector unit


def main(report):
    spec = TAFParams(history_size=2, prediction_size=32, rsd_threshold=0.5)
    for items in (2, 8, 32, 128, 512):
        n_elem = TOTAL // items
        xs = jnp.asarray(binomial_options.gen_inputs(n_elem, items, seed=1))
        fn = lambda x: binomial_options.binomial_price(x, 64)
        ys, _, frac = jax.jit(lambda xs: taf_mod.run_sequence(
            spec, xs, fn, Level.ELEMENT))(xs)
        frac = float(frac)
        modeled = 1.0 / max(1.0 - frac, 1e-3)
        # utilization penalty when elements can no longer fill the lanes
        util = min(n_elem / LANES, 1.0)
        effective = modeled * util
        report("fig8c_items_per_thread", f"items={items}",
               f"approx_frac={frac:.2f},modeled={modeled:.2f}x,"
               f"util={util:.2f},effective={effective:.2f}x")
