"""Kernel-substrate sweep: the approx_ffn app (the first workload whose
approximated region runs on the actual Pallas kernels) through the full v2
harness -- batched runners, resumable DB, Pareto summary.

Because the kernels' quality knobs are traced operands, the whole grid
compiles once per structural group (hSize/pSize for TAF, tSize for iACT,
perforation kind for the masked attention) regardless of how many
thresholds/fractions it spans.

Reports, per technique: the best-speedup-under-10%-error row (paper Fig. 6
statistic, modeled speedup = the structural FLOP bound) and the Pareto
front summary. Also cross-checks one spec per technique against the host
substrate (the ref.py oracles): `mask_parity` asserts the kernel's
approx-mask matches the oracle's bit for bit in interpret mode.

With `artifacts_dir`, writes ``BENCH_ffn.json`` (structural sweep numbers:
record/front counts, hypervolume, best-under-bound rows, parity bits) --
the committed copy under ``benchmarks/baselines/`` is a regression
baseline for ``benchmarks.run --check-regression``.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from apps import approx_ffn
from repro.core import pareto
from repro.core.harness import (best_speedup_under_error, iact_grid, sweep,
                                taf_grid)
from repro.core.types import (ApproxSpec, Level, PerforationKind,
                              PerforationParams, Technique)


def _grid():
    taf = taf_grid(h_sizes=(2, 3), p_sizes=(2, 4),
                   thresholds=(0.01, 0.05, 0.2, 1.0),
                   levels=(Level.BLOCK,))
    iact = iact_grid(t_sizes=(2, 4), thresholds=(0.05, 0.2, 0.5, 5.0),
                     tables_per_block=(1,), levels=(Level.BLOCK,))
    perfo = [ApproxSpec(Technique.PERFORATION, Level.BLOCK,
                        perforation=PerforationParams(kind=k, fraction=f))
             for k in (PerforationKind.INI, PerforationKind.FINI)
             for f in (0.25, 0.5, 0.75)]
    return taf + iact + perfo


def main(report, jobs: int = 1, db_path: Optional[str] = None,
         substrate: Optional[str] = "pallas",
         artifacts_dir: Optional[str] = None) -> None:
    app = approx_ffn.make_app(substrate=substrate)
    grid = _grid()
    recs = sweep(app, grid, repeats=1, db_path=db_path, jobs=max(jobs, 1))

    best_rows = {}
    for tech in ("taf", "iact", "perfo"):
        rows = [r for r in recs if r.spec.get("technique") == tech]
        best = best_speedup_under_error(rows, max_error=0.10,
                                        use_modeled=True)
        best_rows[tech] = best
        derived = ("no_config_under_10pct" if best is None else
                   f"modeled={best.modeled_speedup:.2f}x,"
                   f"err={best.error:.4f},approx={best.approx_fraction:.2f}")
        wall = 0.0 if best is None else best.wall_time_s * 1e6
        report(f"approx_ffn_{tech}_{app.workload['substrate']}",
               f"{wall:.0f}", derived)

    fs = pareto.front_summary(recs, use_modeled=True)
    report("approx_ffn_front", f"{len(recs)}",
           f"n_front={fs['n_front']},hv={fs['hypervolume']:.3f}")

    # host-parity spot check (masks must match the oracle bit for bit):
    # one probe per technique, selected by technique so grid edits can't
    # silently shift a probe under the wrong label
    host = approx_ffn.make_app(substrate="host")
    probes = [next(s for s in grid if s.technique == t)
              for t in (Technique.TAF, Technique.IACT,
                        Technique.PERFORATION)]
    prec = sweep(app, probes, repeats=1, db_path=db_path)
    hrec = sweep(host, probes, repeats=1)
    parity = {}
    for p, h in zip(prec, hrec):
        ok = p.extra.get("approx_mask") == h.extra.get("approx_mask")
        parity[p.spec.get("technique")] = bool(ok)
        report(f"approx_ffn_parity_{p.spec.get('technique')}", "0",
               f"mask_parity={ok},err_delta={abs(p.error - h.error):.2e}")

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        path = os.path.join(artifacts_dir, "BENCH_ffn.json")
        with open(path, "w") as f:
            json.dump({
                "substrate": app.workload["substrate"],
                "n_records": len(recs),
                "front": fs,
                "best_under_10pct": {
                    tech: (None if b is None else {
                        "modeled_speedup": b.modeled_speedup,
                        "error": b.error,
                        "approx_fraction": b.approx_fraction,
                        "spec": b.spec})
                    for tech, b in best_rows.items()},
                "parity": parity,
            }, f, indent=1)
        report("ffn_json", "0", path)
