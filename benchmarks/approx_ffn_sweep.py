"""Kernel-substrate sweep: the approx_ffn app (the first workload whose
approximated region runs on the actual Pallas kernels) through the full v2
harness -- batched runners, resumable DB, Pareto summary.

Because the kernels' quality knobs are traced operands, the whole grid
compiles once per structural group (hSize/pSize for TAF, tSize for iACT,
perforation kind for the masked attention) regardless of how many
thresholds/fractions it spans.

Reports, per technique: the best-speedup-under-10%-error row (paper Fig. 6
statistic, modeled speedup = the structural FLOP bound) and the Pareto
front summary. Also cross-checks one spec per technique against the host
substrate (the ref.py oracles): `mask_parity` asserts the kernel's
approx-mask matches the oracle's bit for bit in interpret mode.

With `artifacts_dir`, writes ``BENCH_ffn.json`` (structural sweep numbers:
record/front counts, hypervolume, best-under-bound rows, parity bits) --
the committed copy under ``benchmarks/baselines/`` is a regression
baseline for ``benchmarks.run --check-regression``.

With ``predict=True`` (``benchmarks.run --only ffn --predict``) the sweep
runs in cost-model pruned mode instead: `benchmarks.costmodel.ffn_model`
ranks the full grid by predicted front regret, only the band within the
regret budget -- capped at ``len(grid) // 5`` specs -- is measured, and
the report compares the pruned front's hypervolume against the committed
full-grid baseline (recovery must be >= `costmodel.FRONT_TOLERANCE`).
Artifacts go to ``BENCH_ffn_predict.json``; the full-grid
``BENCH_ffn.json`` baseline (which pins ``n_records`` exactly) is never
overwritten by a pruned run.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from apps import approx_ffn
from repro.core import pareto
from repro.core.harness import (best_speedup_under_error, iact_grid, sweep,
                                taf_grid)
from repro.core.types import (ApproxSpec, Level, PerforationKind,
                              PerforationParams, Technique)


def _grid():
    taf = taf_grid(h_sizes=(2, 3), p_sizes=(2, 4),
                   thresholds=(0.01, 0.05, 0.2, 1.0),
                   levels=(Level.BLOCK,))
    iact = iact_grid(t_sizes=(2, 4), thresholds=(0.05, 0.2, 0.5, 5.0),
                     tables_per_block=(1,), levels=(Level.BLOCK,))
    perfo = [ApproxSpec(Technique.PERFORATION, Level.BLOCK,
                        perforation=PerforationParams(kind=k, fraction=f))
             for k in (PerforationKind.INI, PerforationKind.FINI)
             for f in (0.25, 0.5, 0.75)]
    return taf + iact + perfo


def _predict_main(report, jobs: int, db_path: Optional[str],
                  substrate: Optional[str],
                  artifacts_dir: Optional[str]) -> None:
    """Cost-model pruned sweep: measure only the predicted front band
    (<= 1/5 of the grid) and report recovery vs the committed baseline."""
    from . import costmodel

    app = approx_ffn.make_app(substrate=substrate)
    grid = _grid()
    budget = max(1, len(grid) // 5)
    model = costmodel.ffn_model()
    band = model.select_band(grid, budget=budget)
    recs = sweep(app, band, repeats=1, db_path=db_path, jobs=max(jobs, 1))
    fs = pareto.front_summary(recs, use_modeled=True)

    base_path = os.path.join(os.path.dirname(__file__), "baselines",
                             "BENCH_ffn.json")
    base_hv = None
    if os.path.exists(base_path):
        with open(base_path) as f:
            base_hv = json.load(f)["front"]["hypervolume"]
    ratio = (fs["hypervolume"] / base_hv) if base_hv else None
    recovered = ratio is not None and ratio >= costmodel.FRONT_TOLERANCE
    report("approx_ffn_predict_band", f"{len(band)}",
           f"budget={budget},grid={len(grid)}")
    report("approx_ffn_predict_front", f"{len(recs)}",
           f"n_front={fs['n_front']},hv={fs['hypervolume']:.3f},"
           f"recovery={'n/a' if ratio is None else f'{ratio:.3f}'},"
           f"tol={costmodel.FRONT_TOLERANCE}")
    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        path = os.path.join(artifacts_dir, "BENCH_ffn_predict.json")
        with open(path, "w") as f:
            json.dump({
                "substrate": app.workload["substrate"],
                "n_grid": len(grid),
                "band_budget": budget,
                "n_records": len(recs),
                "front": fs,
                "front_recovery": {
                    "hv_band": fs["hypervolume"],
                    "hv_baseline": base_hv,
                    "ratio": ratio,
                    "tolerance": costmodel.FRONT_TOLERANCE,
                    "recovered": recovered,
                },
            }, f, indent=1)
        report("ffn_predict_json", "0", path)


def main(report, jobs: int = 1, db_path: Optional[str] = None,
         substrate: Optional[str] = "pallas",
         artifacts_dir: Optional[str] = None,
         predict: bool = False) -> None:
    if predict:
        _predict_main(report, jobs, db_path, substrate, artifacts_dir)
        return
    app = approx_ffn.make_app(substrate=substrate)
    grid = _grid()
    recs = sweep(app, grid, repeats=1, db_path=db_path, jobs=max(jobs, 1))

    best_rows = {}
    for tech in ("taf", "iact", "perfo"):
        rows = [r for r in recs if r.spec.get("technique") == tech]
        best = best_speedup_under_error(rows, max_error=0.10,
                                        use_modeled=True)
        best_rows[tech] = best
        derived = ("no_config_under_10pct" if best is None else
                   f"modeled={best.modeled_speedup:.2f}x,"
                   f"err={best.error:.4f},approx={best.approx_fraction:.2f}")
        wall = 0.0 if best is None else best.wall_time_s * 1e6
        report(f"approx_ffn_{tech}_{app.workload['substrate']}",
               f"{wall:.0f}", derived)

    fs = pareto.front_summary(recs, use_modeled=True)
    report("approx_ffn_front", f"{len(recs)}",
           f"n_front={fs['n_front']},hv={fs['hypervolume']:.3f}")

    # host-parity spot check (masks must match the oracle bit for bit):
    # one probe per technique, selected by technique so grid edits can't
    # silently shift a probe under the wrong label
    host = approx_ffn.make_app(substrate="host")
    probes = [next(s for s in grid if s.technique == t)
              for t in (Technique.TAF, Technique.IACT,
                        Technique.PERFORATION)]
    prec = sweep(app, probes, repeats=1, db_path=db_path)
    hrec = sweep(host, probes, repeats=1)
    parity = {}
    for p, h in zip(prec, hrec):
        ok = p.extra.get("approx_mask") == h.extra.get("approx_mask")
        parity[p.spec.get("technique")] = bool(ok)
        report(f"approx_ffn_parity_{p.spec.get('technique')}", "0",
               f"mask_parity={ok},err_delta={abs(p.error - h.error):.2e}")

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        path = os.path.join(artifacts_dir, "BENCH_ffn.json")
        from repro.obs import metrics as obs_metrics
        with open(path, "w") as f:
            json.dump(obs_metrics.stamp({
                "substrate": app.workload["substrate"],
                "n_records": len(recs),
                "front": fs,
                "best_under_10pct": {
                    tech: (None if b is None else {
                        "modeled_speedup": b.modeled_speedup,
                        "error": b.error,
                        "approx_fraction": b.approx_fraction,
                        "spec": b.spec})
                    for tech, b in best_rows.items()},
                "parity": parity,
            }), f, indent=1)
        report("ffn_json", "0", path)
