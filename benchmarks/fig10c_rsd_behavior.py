"""Paper Figure 10c: TAF RSD threshold behaves unintuitively.

Blackscholes, sweeping the RSD threshold: one would expect error to rise
monotonically with the threshold, but low thresholds can activate
approximation exactly when the window happens to be flat while the true
signal is about to move -- producing HIGHER error than generous thresholds
(the paper's T=3.0 anomaly). We report error vs threshold to exhibit the
non-monotonicity.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "examples")

from apps import blackscholes
from repro.core import ApproxSpec, Level, TAFParams, Technique
from repro.core.harness import mape


def main(report):
    app = blackscholes.make_app(n_elements=512, steps=64, seed=3,
                            volatility=6.0)
    exact = app.exact()
    prev_err = None
    non_monotone = 0
    for t in (0.1, 0.3, 1.0, 3.0, 5.0, 20.0):
        spec = ApproxSpec(Technique.TAF, Level.ELEMENT,
                          taf=TAFParams(5, 16, t))
        r = app.run(spec)
        err = mape(exact.qoi, r.qoi)
        if prev_err is not None and err < prev_err:
            non_monotone += 1
        prev_err = err
        report("fig10c_rsd_behavior", f"T={t}",
               f"err={err:.4%},approx_frac={r.approx_fraction:.2f}")
    report("fig10c_rsd_behavior", "non_monotone_steps",
           f"{non_monotone} (unintuitive RSD interactions -- matches paper)")
