"""QoS-controlled serving vs precise serving on an open-loop request trace.

The closed loop end to end (docs/qos.md): a resumable `harness.sweep` over
decode-TAF thresholds builds the offline Pareto DB; `QosPolicy` turns its
front into a ladder; a `QosEngine` serves a seeded open-loop trace (arrival
ticks fixed up front -- load does not adapt to service rate) with canary
monitoring and feedback control, against the same trace through a precise
engine. Mid-run a deterministic error spike is injected into the monitor,
so the report also exercises the hard precise fallback and the recovery.

Reports throughput (tokens/s), measured canary error vs the target, the
fallback rate, knob trajectory length, and TTFT/latency percentiles. With
`artifacts_dir`, writes ``BENCH_qos.json`` (throughput, measured error,
fallback rate, knob trajectory). The committed copy under
``benchmarks/baselines/`` is the regression baseline ``benchmarks.run
--check-regression`` gates CI against.

With ``devices=N`` (CLI ``--devices N``) both engines run the decode step
shard_map'd over an (N, 1) data-parallel mesh with one logical shard per
device and ``_LANES_PER_SHARD`` lanes per shard -- slots scale with the
mesh, the request trace's open-loop arrival rate scales with slots, and
the fault drill injects into ONE shard's canary stream (per-shard
fallback). The artifact then also records devices/mesh_shape/shards and
the per-shard knob trajectories.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

import dataclasses

import numpy as np
import jax

from repro import qos
from repro.core.harness import sweep
from repro.core.types import ApproxSpec
from repro.models import build
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs import trace as obs_trace
from repro.serving import Request, ServingEngine

_THRESHOLDS = (0.02, 0.04, 0.06, 0.1, 0.3)
_METRIC = "mcr"         # token-mismatch rate: bounded, the serving contract
_TARGET = 0.10          # max one-step token-mismatch rate
_CANARY_FRACTION = 0.25
_N_REQUESTS = 10
_GEN = 8
_LANES_PER_SHARD = 4    # sharded runs: slots = lanes * shards
_SPIKE_TICK = 22        # deterministic fault injection (monitor.inject),
#                         late in the batch-only phase: the knob is open,
#                         so the drill exercises a real back-off

_SPIKE_ERROR = 10.0


def _trace(cfg, seed: int = 0, *, slots: int = 4,
           n_requests: int = _N_REQUESTS):
    """Seeded open-loop trace: arrival tick, prompt, class per request.
    Interactive ("default", tight bound) requests arrive first; a batch
    tail follows, so the run exercises both the strictest-live-lane
    actuation (precise while interactive lanes are live) and the opened
    knob once only batch lanes remain. The arrival rate scales with the
    engine's slot count (one request per _GEN/slots ticks keeps the
    steady-state concurrency near the slot count), and reduces to the
    historical 2-ticks-per-request spacing at the default slots=4."""
    rng = np.random.RandomState(seed)
    reqs = []
    for i in range(n_requests):
        arrival = int(rng.randint(0, 3)) + (i * _GEN) // slots
        prompt = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
        cls = "default" if i < n_requests // 2 else "batch"
        reqs.append((arrival, Request(uid=i, prompt=prompt,
                                      max_new_tokens=_GEN, qos_class=cls)))
    return reqs


def _serve_trace(engine, trace, *, spike_at: Optional[int] = None,
                 spike_shard: Optional[int] = None):
    """Open-loop drive: submissions happen at their arrival tick whether or
    not the engine kept up. Returns (stats, wall_seconds). The caller must
    have called `engine.warmup()` -- the timed region below measures
    decode, and the compile of a sharded serve step is seconds.

    `spike_shard` routes the fault drill into one shard's canary stream
    (`QosEngine.inject(..., shard=)`): only the classes live on that shard
    react, exercising the per-shard fallback path."""
    pending = sorted(trace, key=lambda ar: ar[0])
    t0 = time.perf_counter()
    tick = 0
    while pending or engine.queue or any(engine.active):
        while pending and pending[0][0] <= tick:
            engine.submit(pending.pop(0)[1])
        if spike_at is not None and tick == spike_at and engine.qos:
            if spike_shard is None:
                engine.qos.monitor.inject(_SPIKE_ERROR)
            else:
                engine.qos.inject(_SPIKE_ERROR, shard=spike_shard)
        engine.tick()
        tick += 1
        if tick > 10_000:
            raise RuntimeError("trace did not drain")
    return engine.stats, time.perf_counter() - t0


def main(report, jobs: int = 1, db_path: Optional[str] = None,
         artifacts_dir: Optional[str] = None,
         devices: Optional[int] = None,
         shards: Optional[int] = None) -> None:
    cfg = qos.default_decode_cfg()

    if devices is not None:
        avail = len(jax.devices())
        if devices > avail:
            raise RuntimeError(
                f"--devices {devices} but only {avail} device(s) visible; "
                f"set XLA_FLAGS=--xla_force_host_platform_device_count="
                f"{devices} for a fake multi-device host")
        n_shards = int(shards) if shards is not None else int(devices)
        slots = _LANES_PER_SHARD * n_shards
        engine_kw = dict(devices=int(devices), shards=n_shards)
    else:
        n_shards = 1
        slots = 4
        engine_kw = {}
    n_requests = max(_N_REQUESTS, (5 * slots) // 2)

    # 1. offline: calibrate the decode workload through the normal harness
    #    (resumable when --db is given; one compile for the whole grid)
    app = qos.make_decode_app(cfg, gen=12, metric=_METRIC)
    recs = sweep(app, qos.threshold_grid(cfg, _THRESHOLDS), repeats=1,
                 db_path=db_path, jobs=max(jobs, 1))
    policy = qos.QosPolicy.from_records(recs, metric=_METRIC,
                                        use_modeled=True)
    report("qos_policy_ladder", f"{len(policy)}",
           ";".join(f"th={e.spec.get('thresh')}:err={e.error:.3f}"
                    for e in policy.entries[1:]) or "precise_only")

    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    trace_kw = dict(slots=slots, n_requests=n_requests)

    # 2. precise baseline over the same trace (same params, TAF disabled;
    #    same mesh/shards, so the throughput comparison is knob vs no-knob,
    #    not sharded vs unsharded)
    precise_model = build(dataclasses.replace(cfg,
                                              approx_decode=ApproxSpec()))
    precise_eng = ServingEngine(precise_model, params, slots=slots,
                                max_len=64, prompt_len=8, **engine_kw)
    precise_eng.warmup()
    p_stats, p_wall = _serve_trace(precise_eng, _trace(cfg, **trace_kw))

    # 3. QoS-controlled serving, same seeded trace + injected error spike
    #    (sharded runs drill ONE shard -- the last, which hosts batch-class
    #    lanes by the spike tick)
    engine_qos = qos.QosEngine(
        policy, {"default": _TARGET, "batch": 10 * _TARGET},
        sample_fraction=_CANARY_FRACTION, window=8,
        config=qos.ControllerConfig(min_samples=2, hold_ticks=2,
                                    fallback_hold=4))
    q_eng = ServingEngine(model, params, slots=slots, max_len=64,
                          prompt_len=8, qos=engine_qos, **engine_kw)
    q_eng.warmup()
    # flight recorder over the QoS run: the injected spike trips a hard
    # fallback, so the artifact also proves the last-N-ticks dump fires
    flight = obs_recorder.install(capacity=32, out_dir=artifacts_dir)
    try:
        q_stats, q_wall = _serve_trace(
            q_eng, _trace(cfg, **trace_kw), spike_at=_SPIKE_TICK,
            spike_shard=(n_shards - 1 if n_shards > 1 else None))
    finally:
        obs_recorder.uninstall()
    report("qos_mesh", "0",
           f"devices={devices or 1},mesh_shape={q_eng.mesh_shape},"
           f"shards={n_shards},slots={slots},requests={n_requests}")

    summary = engine_qos.summary()
    # per CLASS: the fault drill fires in the batch-only phase, so the
    # back-off/recovery events live on the "batch" controller -- an
    # artifact holding only "default" would never show them.
    traj = {cls: ctl.trajectory_json()
            for cls, ctl in engine_qos.controllers.items()}
    p_tps = p_stats.tokens_out / max(p_wall, 1e-9)
    q_tps = q_stats.tokens_out / max(q_wall, 1e-9)

    report("qos_precise_throughput", f"{1e6 / max(p_tps, 1e-9):.0f}",
           f"tokens_per_s={p_tps:.1f}")
    report("qos_approx_throughput", f"{1e6 / max(q_tps, 1e-9):.0f}",
           f"tokens_per_s={q_tps:.1f},skip_frac="
           f"{q_stats.taf_skip_fraction:.3f}")
    report("qos_measured_error", "0",
           f"genuine_mean={summary['genuine_mean_error']:.4f},"
           f"canaries={summary['canary_samples']},"
           f"injected_faults={summary['injected_faults']}")
    for cls, tgt in (("default", _TARGET), ("batch", 10 * _TARGET)):
        c = summary["classes"][cls]
        report(f"qos_class_{cls}", "0",
               f"target={tgt},exposed_error={c['exposed_mean_error']:.4f},"
               f"exposed_canaries={c['exposed_canaries']},"
               f"rung={c['index']}")
    report("qos_fallback", "0",
           f"rate={summary['fallback_rate']:.3f},knob_moves="
           f"{q_stats.knob_moves},flight_dumps={len(flight.dumps)}")
    lat = q_stats.latency_summary()
    report("qos_latency", "0",
           f"ttft_p50={lat['ttft_p50_s']:.3f}s,ttft_p99="
           f"{lat['ttft_p99_s']:.3f}s,p50={lat['latency_p50_s']:.3f}s,"
           f"p99={lat['latency_p99_s']:.3f}s")

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        path = os.path.join(artifacts_dir, "BENCH_qos.json")
        # engine-level knob actuations (with the typed move's reason);
        # sharded entries hold one value per shard, and the per-shard
        # trajectories below slice them out
        actuations = [
            {"tick": m.tick,
             "threshold": (list(m.value) if isinstance(m.value, tuple)
                           else m.value),
             "reason": m.reason}
            for m in q_eng.knob_events]
        per_shard_traj = None
        if n_shards > 1:
            per_shard_traj = {
                str(s): [{"tick": t,
                          "threshold": (v[s] if isinstance(v, tuple)
                                        else v)}
                         for t, v in q_eng.knob_log]
                for s in range(n_shards)}
        with open(path, "w") as f:
            json.dump(obs_metrics.stamp({
                "target_max_error": _TARGET,
                "metric": policy.metric,
                "canary_fraction": _CANARY_FRACTION,
                "devices": int(devices) if devices else 1,
                "mesh_shape": (list(q_eng.mesh_shape)
                               if q_eng.mesh_shape else None),
                "shards": n_shards,
                "slots": slots,
                "requests": n_requests,
                "policy_ladder": policy.to_json()["entries"],
                "precise": {"tokens_per_s": p_tps,
                            "latency": p_stats.latency_summary()},
                "approx": {"tokens_per_s": q_tps,
                           "taf_skip_fraction": q_stats.taf_skip_fraction,
                           "knob_moves": q_stats.knob_moves,
                           "canary_ticks": q_stats.canary_ticks,
                           "latency": q_stats.latency_summary()},
                "measured_error": summary["genuine_mean_error"],
                "measured_error_with_faults": summary["mean_error"],
                "injected_faults": summary["injected_faults"],
                "error_estimate": summary["estimate"],
                "fallback_rate": summary["fallback_rate"],
                "classes": {
                    cls: {k: c[k] for k in
                          ("target", "exposed_mean_error",
                           "exposed_canaries", "index", "fallback_rate")}
                    for cls, c in summary["classes"].items()},
                "knob_actuations": actuations,
                "knob_trajectory": traj,
                "knob_trajectory_per_shard": per_shard_traj,
                "shard_exposure": summary.get("shard_exposure"),
                "flight_dumps": [
                    {"reason": d["reason"], "context": d["context"],
                     "ticks": len(d["ticks"])}
                    for d in flight.dumps],
            }), f, indent=1)
        report("qos_json", "0", path)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(
        description="QoS serving drill (the `qos` module of benchmarks.run, "
        "runnable standalone for tracing)")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--db", default=None)
    ap.add_argument("--artifacts", default=None)
    ap.add_argument("--devices", type=int, default=None)
    ap.add_argument("--shards", type=int, default=None)
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome/Perfetto trace of the run "
                    "(serving tick sub-spans, QoS decision events, sweep "
                    "and compile spans) and write it to this path")
    args = ap.parse_args()

    tracer = None
    if args.trace:
        tracer = obs_trace.Tracer()
        obs_trace.enable(tracer)
    try:
        main(lambda n, us, d="": print(f"{n},{us},{d}", flush=True),
             jobs=args.jobs, db_path=args.db, artifacts_dir=args.artifacts,
             devices=args.devices, shards=args.shards)
    finally:
        if tracer is not None:
            obs_trace.disable()
            tracer.save(args.trace)
            print(f"trace,{len(tracer)},{args.trace}", flush=True)
