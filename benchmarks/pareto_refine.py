"""Pareto-aware adaptive search (harness v2) on Blackscholes/TAF.

Sweeps a deliberately coarse threshold grid, then lets
`repro.core.pareto.refine` spend a small extra budget subdividing parameter
neighborhoods around the error/speedup front -- the successive-halving-style
replacement for brute-force grid densification. Reports the front size and
hypervolume before and after refinement, plus how many extra evaluations the
budget actually bought.

With --db (see benchmarks/run.py) both the coarse sweep and the refinement
write through the same keyed cache, so re-runs are incremental.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "examples")

from apps import blackscholes
from repro.core import Level
from repro.core.harness import sweep, taf_grid
from repro.core.pareto import front_summary, refine

COARSE = taf_grid(h_sizes=(3,), p_sizes=(8, 64), thresholds=(0.1, 1.5),
                  levels=(Level.ELEMENT,))


def main(report, jobs: int = 1, db_path=None):
    # db_path=None runs purely in memory: refine already dedupes against the
    # in-memory record pool, so no scratch file is needed.
    app = blackscholes.make_app(n_elements=256, steps=32)
    # use_modeled: on a CPU container measured wall speedups are noisy and
    # mostly < 1x; the modeled (roofline) axis is deterministic.
    recs = sweep(app, COARSE, repeats=1, jobs=jobs, db_path=db_path)
    before = front_summary(recs, use_modeled=True)
    report("pareto_refine", "coarse_front",
           f"n={before['n_front']}/{before['n_records']},"
           f"hv={before['hypervolume']:.3f}")
    new = refine(app, recs, budget=8, rounds=2, repeats=1, jobs=jobs,
                 db_path=db_path, use_modeled=True)
    after = front_summary(list(recs) + new, use_modeled=True)
    report("pareto_refine", "refined_front",
           f"n={after['n_front']}/{after['n_records']},"
           f"hv={after['hypervolume']:.3f},new_evals={len(new)}")
