"""Paper Figure 12c: K-Means speedup comes from early convergence.

Runs TAF/iACT configs over K-Means, collecting (convergence speedup =
iters_exact / iters_approx) and wall-time speedup; reports the linear
correlation between them (paper: R^2 = 0.95).
"""
from __future__ import annotations

import sys

sys.path.insert(0, "examples")

import numpy as np

from apps import kmeans
from repro.core import Level
from repro.core.harness import iact_grid, sweep, taf_grid


def main(report, jobs: int = 1, db_path=None):
    app = kmeans.make_app(n=1024, d=6, k=8)
    exact = app.exact()
    iters_exact = exact.extra["iters"]
    grid = taf_grid(h_sizes=(2, 3), p_sizes=(8,), thresholds=(0.3, 1.5),
                    levels=(Level.ELEMENT,)) + \
        iact_grid(t_sizes=(4,), thresholds=(0.5, 3.0), tables_per_block=(0,),
                  levels=(Level.ELEMENT,))
    recs = sweep(app, grid, repeats=1, jobs=jobs, db_path=db_path)
    conv_sp, time_sp = [], []
    for r in recs:
        it = r.extra.get("iters", iters_exact)
        conv_sp.append(iters_exact / max(it, 1))
        time_sp.append(r.speedup)
    conv_sp = np.asarray(conv_sp)
    time_sp = np.asarray(time_sp)
    if len(conv_sp) > 2 and conv_sp.std() > 0 and time_sp.std() > 0:
        r2 = float(np.corrcoef(conv_sp, time_sp)[0, 1] ** 2)
    else:
        r2 = float("nan")
    report("fig12c_kmeans_convergence", "r_squared",
           f"{r2:.3f} over {len(recs)} configs "
           f"(conv_speedup range {conv_sp.min():.1f}..{conv_sp.max():.1f})")
