"""Roofline/dry-run table: summarize results/dryrun/*.json +
results/roofline/*.json (produced by launch.dryrun / launch.roofline)."""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def main(report):
    dr = sorted(glob.glob(os.path.join(RESULTS, "dryrun", "*.json")))
    ok = skipped = failed = 0
    for path in dr:
        with open(path) as f:
            rec = json.load(f)
        s = rec.get("status")
        ok += s == "ok"
        skipped += s == "skipped"
        failed += s == "FAILED"
    report("dryrun_matrix", f"{len(dr)}",
           f"ok={ok},skipped={skipped},failed={failed}")

    rf = sorted(glob.glob(os.path.join(RESULTS, "roofline", "*.json")))
    for path in rf:
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        report("roofline",
               f"{rec['arch']}/{rec['shape']}/{rec.get('tag', 'baseline')}",
               f"dominant={rec['dominant']},"
               f"compute={rec['compute_s']:.3g}s,"
               f"memory={rec['memory_s']:.3g}s,"
               f"coll={rec['collective_s']:.3g}s,"
               f"frac={rec['roofline_fraction']:.3f}")
