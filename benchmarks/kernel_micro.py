"""Kernel microbenchmarks: per-kernel interpret-mode validation timing and
the block-skip savings profile (structural FLOP reduction per config).

Wall times here are interpret-mode (Python) -- meaningful only relatively;
the structural numbers (executed grid fraction, FLOPs) are machine-true.
With `artifacts_dir`, those structural numbers are also written to
``<artifacts_dir>/kernel_micro.json`` (one row per measurement) so CI can
upload them as a build artifact and diffs across commits are machine-
comparable.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import PerforationKind, PerforationParams
from repro.core.perforation import drop_fraction
from repro.kernels import ops, ref


def _time(f, *args):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6


def main(report, artifacts_dir: Optional[str] = None):
    rows = []

    def emit(name, us, derived, **structural):
        report(name, f"{us:.0f}", derived)
        rows.append(dict(name=name, us_per_call=round(us, 1), **structural))

    rng = np.random.RandomState(0)
    m = k = n = 256
    x = jnp.asarray(np.tile(rng.randn(1, k), (m, 1)).astype(np.float32))
    w = jnp.asarray(rng.randn(k, n).astype(np.float32))

    matmul_flops = 2.0 * m * k * n
    us = _time(lambda a, b: ops.taf_matmul(a, b, block_m=64, block_n=64)[0],
               x, w)
    y, mask = ops.taf_matmul(x, w, block_m=64, block_n=64)
    yr, mr = ref.taf_matmul_ref(x, w, block_m=64, block_n=64, history_size=3,
                                prediction_size=8, rsd_threshold=0.5)
    ok = np.allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
    skipped = float(np.asarray(mask).mean())
    emit("kernel_taf_matmul", us,
         f"oracle_match={ok},blocks_skipped={skipped:.0%}",
         oracle_match=bool(ok), executed_grid_fraction=1.0 - skipped,
         flops_total=matmul_flops,
         flops_executed=matmul_flops * (1.0 - skipped))

    # 4 distinct row-values, each spanning 2 consecutive 32-row blocks:
    # the second block of each pair hits the table written by the first
    x2 = jnp.asarray(np.repeat(rng.randn(4, 64), 64, 0).astype(np.float32))
    w1 = jnp.asarray(rng.randn(64, 128).astype(np.float32) * 0.1)
    w2 = jnp.asarray(rng.randn(128, 32).astype(np.float32) * 0.1)
    ffn_flops = 2.0 * 256 * 64 * 128 + 2.0 * 256 * 128 * 32
    us = _time(lambda a: ops.iact_rowfn(a, w1, w2, block_rows=32)[0], x2)
    y2, m2 = ops.iact_rowfn(x2, w1, w2, block_rows=32)
    y2r, m2r = ref.iact_rowfn_ref(x2, w1, w2, block_rows=32, table_size=4,
                                  threshold=0.5)
    ok = np.allclose(np.asarray(y2), np.asarray(y2r), atol=1e-3)
    hit = float(np.asarray(m2).mean())
    emit("kernel_iact_rowfn", us,
         f"oracle_match={ok},blocks_hit={hit:.0%}",
         oracle_match=bool(ok), executed_grid_fraction=1.0 - hit,
         flops_total=ffn_flops, flops_executed=ffn_flops * (1.0 - hit))

    for skip in (2, 4, 8):
        p = PerforationParams(kind=PerforationKind.SMALL, skip=skip)
        us = _time(lambda a, b: ops.perforated_matmul(
            a, b, block_m=64, block_n=64, block_k=64, perfo=p), x, w)
        saved = drop_fraction(k // 64, p)
        emit("kernel_perforated_matmul", us,
             f"skip={skip},flops_saved={saved:.0%}",
             skip=skip, executed_grid_fraction=1.0 - saved,
             flops_total=matmul_flops,
             flops_executed=matmul_flops * (1.0 - saved))

    q = jnp.asarray(rng.randn(1, 4, 128, 64).astype(np.float32))
    kk = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    attn_flops = 4.0 * 4 * 128 * 256 * 64  # qk + pv over 4 q heads
    for fr in (0.0, 0.5):
        p = (None if fr == 0.0 else
             PerforationParams(kind=PerforationKind.INI, fraction=fr))
        us = _time(lambda a, b, c: ops.perforated_attention(
            a, b, c, block_q=64, block_kv=64, perfo=p), q, kk, v)
        emit("kernel_perforated_attention", us, f"ini_drop={fr:.0%}",
             ini_drop=fr, executed_grid_fraction=1.0 - fr,
             flops_total=attn_flops, flops_executed=attn_flops * (1.0 - fr))

    # traced-knob dispatch cost: same kernel, swept threshold, ZERO recompiles
    from repro.kernels.taf_matmul import taf_matmul as taf_jit
    ops.taf_matmul(x, w, block_m=64, block_n=64, rsd_threshold=0.1)
    before = taf_jit._cache_size()
    t0 = time.perf_counter()
    n_sweep = 16
    for th in np.linspace(0.05, 2.0, n_sweep):
        jax.block_until_ready(ops.taf_matmul(
            x, w, block_m=64, block_n=64, rsd_threshold=float(th))[0])
    us = (time.perf_counter() - t0) * 1e6 / n_sweep
    recompiles = taf_jit._cache_size() - before
    emit("kernel_taf_threshold_sweep", us,
         f"n={n_sweep},recompiles={recompiles}",
         n_sweep=n_sweep, recompiles=int(recompiles))

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        path = os.path.join(artifacts_dir, "kernel_micro.json")
        with open(path, "w") as f:
            json.dump(rows, f, indent=1)
        report("kernel_micro_json", "0", path)
