"""Kernel microbenchmarks: per-kernel interpret-mode validation timing and
the block-skip savings profile (structural FLOP reduction per config).

Wall times here are interpret-mode (Python) -- meaningful only relatively;
the structural numbers (executed grid fraction, FLOPs) are machine-true.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import PerforationKind, PerforationParams
from repro.core.perforation import drop_fraction
from repro.kernels import ops, ref


def _time(f, *args):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) * 1e6


def main(report):
    rng = np.random.RandomState(0)
    m = k = n = 256
    x = jnp.asarray(np.tile(rng.randn(1, k), (m, 1)).astype(np.float32))
    w = jnp.asarray(rng.randn(k, n).astype(np.float32))

    us = _time(lambda a, b: ops.taf_matmul(a, b, block_m=64, block_n=64)[0],
               x, w)
    y, mask = ops.taf_matmul(x, w, block_m=64, block_n=64)
    yr, mr = ref.taf_matmul_ref(x, w, block_m=64, block_n=64, history_size=3,
                                prediction_size=8, rsd_threshold=0.5)
    ok = np.allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
    report("kernel_taf_matmul", f"{us:.0f}",
           f"oracle_match={ok},blocks_skipped={np.asarray(mask).mean():.0%}")

    # 4 distinct row-values, each spanning 2 consecutive 32-row blocks:
    # the second block of each pair hits the table written by the first
    x2 = jnp.asarray(np.repeat(rng.randn(4, 64), 64, 0).astype(np.float32))
    w1 = jnp.asarray(rng.randn(64, 128).astype(np.float32) * 0.1)
    w2 = jnp.asarray(rng.randn(128, 32).astype(np.float32) * 0.1)
    us = _time(lambda a: ops.iact_rowfn(a, w1, w2, block_rows=32)[0], x2)
    y2, m2 = ops.iact_rowfn(x2, w1, w2, block_rows=32)
    y2r, m2r = ref.iact_rowfn_ref(x2, w1, w2, block_rows=32, table_size=4,
                                  threshold=0.5)
    ok = np.allclose(np.asarray(y2), np.asarray(y2r), atol=1e-3)
    report("kernel_iact_rowfn", f"{us:.0f}",
           f"oracle_match={ok},blocks_hit={np.asarray(m2).mean():.0%}")

    for skip in (2, 4, 8):
        p = PerforationParams(kind=PerforationKind.SMALL, skip=skip)
        us = _time(lambda a, b: ops.perforated_matmul(
            a, b, block_m=64, block_n=64, block_k=64, perfo=p), x, w)
        saved = drop_fraction(k // 64, p)
        report("kernel_perforated_matmul", f"{us:.0f}",
               f"skip={skip},flops_saved={saved:.0%}")

    q = jnp.asarray(rng.randn(1, 4, 128, 64).astype(np.float32))
    kk = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    for fr in (0.0, 0.5):
        p = (None if fr == 0.0 else
             PerforationParams(kind=PerforationKind.INI, fraction=fr))
        us = _time(lambda a, b, c: ops.perforated_attention(
            a, b, c, block_q=64, block_kv=64, perfo=p), q, kk, v)
        report("kernel_perforated_attention", f"{us:.0f}",
               f"ini_drop={fr:.0%}")
