"""Kernel microbenchmarks: per-kernel interpret-mode validation timing, the
block-skip savings profile (structural FLOP reduction per config), the
pipelined-variant parity check, and the block-shape autotuner run on the
approx_ffn geometry.

Wall times here are interpret-mode (Python) -- meaningful only relatively;
the structural numbers (executed grid fraction, FLOPs) are machine-true.
Every timed number is a median-of-k around `jax.block_until_ready` with
explicit warm-up calls, so neither compiles nor async dispatch land inside
a timed window.

With `artifacts_dir`, three machine-readable outputs are written:

  kernel_micro.json  -- one row per structural measurement (as before);
  BENCH_kernel.json  -- the regression-gate summary (`benchmarks.run
                        --check-regression` compares it against the
                        committed baseline): oracle parity, pipelined-
                        variant bit parity, sweep recompile count, and the
                        tuned-vs-default speedups;
  tuning_cache.json  -- the autotuner's winners for this host (the same
                        schema `kernels/ops.py` resolves None blocks from).

The tuning section measures each kernel at its historical hardcoded
default blocks and at the autotuned blocks (`kernels.tuning.autotune`:
divisor-valid search space, roofline pre-prune, median-of-k wall-clock on
the survivors) on the approx_ffn app geometry -- the acceptance check is
that tuned blocks beat the defaults in measured wall-clock on every
kernel. In interpret mode the win comes from the same term that dominates
on hardware at these sizes: per-grid-step dispatch overhead.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import PerforationKind, PerforationParams
from repro.core.perforation import drop_fraction
from repro.kernels import ops, ref, tuning


def _time(f, *args, warmup: int = 1, repeats: int = 3):
    """Median-of-k microseconds (warm-up absorbs compile + first dispatch;
    every timed call blocks on its result)."""
    return tuning.measure_s(f, *args, warmup=warmup, repeats=repeats) * 1e6


# The approx_ffn app geometry (examples/apps/approx_ffn.py) and its
# hardcoded default blocks -- what `make_app(blocks=None)` runs today, and
# the baseline the autotuned blocks must beat. perforated_matmul is not in
# the ffn pipeline; it is tuned at this module's own 256^3 micro shape.
_FFN = dict(seq=128, d=32, d_h=64, heads=2)
_TUNE_DEFAULTS = {
    "taf_matmul": {"block_m": 16, "block_n": 32},
    "iact_rowfn": {"block_rows": 16},
    "perforated_attention": {"block_q": 32, "block_kv": 32},
    "perforated_matmul": {"block_m": 64, "block_n": 64, "block_k": 64},
}


def _tuning_arrays():
    """kernel -> operand arrays at the geometry its defaults come from."""
    rng = np.random.RandomState(7)
    seq, d, d_h = _FFN["seq"], _FFN["d"], _FFN["d_h"]
    heads = _FFN["heads"]
    x = jnp.asarray(rng.randn(seq, d).astype(np.float32))
    wp = jnp.asarray(rng.randn(d, d).astype(np.float32))
    w1 = jnp.asarray(rng.randn(d, d_h).astype(np.float32))
    w2 = jnp.asarray(rng.randn(d_h, d).astype(np.float32))
    q = jnp.asarray(
        rng.randn(1, heads, seq, d // heads).astype(np.float32))
    xm = jnp.asarray(rng.randn(256, 256).astype(np.float32))
    wm = jnp.asarray(rng.randn(256, 256).astype(np.float32))
    return {
        "taf_matmul": (x, wp),
        "iact_rowfn": (x, w1, w2),
        "perforated_attention": (q, q, q),
        "perforated_matmul": (xm, wm),
    }


def main(report, artifacts_dir: Optional[str] = None):
    rows = []
    bench = {"metric": "kernel_micro",
             "substrate": tuning.current_substrate(),
             "machine": tuning.current_machine_name()}

    def emit(name, us, derived, **structural):
        report(name, f"{us:.0f}", derived)
        rows.append(dict(name=name, us_per_call=round(us, 1), **structural))

    rng = np.random.RandomState(0)
    m = k = n = 256
    x = jnp.asarray(np.tile(rng.randn(1, k), (m, 1)).astype(np.float32))
    w = jnp.asarray(rng.randn(k, n).astype(np.float32))

    matmul_flops = 2.0 * m * k * n
    us = _time(lambda a, b: ops.taf_matmul(a, b, block_m=64, block_n=64)[0],
               x, w)
    y, mask = ops.taf_matmul(x, w, block_m=64, block_n=64)
    yr, mr = ref.taf_matmul_ref(x, w, block_m=64, block_n=64, history_size=3,
                                prediction_size=8, rsd_threshold=0.5)
    ok_taf = bool(np.allclose(np.asarray(y), np.asarray(yr), atol=1e-3))
    skipped = float(np.asarray(mask).mean())
    emit("kernel_taf_matmul", us,
         f"oracle_match={ok_taf},blocks_skipped={skipped:.0%}",
         oracle_match=ok_taf, executed_grid_fraction=1.0 - skipped,
         flops_total=matmul_flops,
         flops_executed=matmul_flops * (1.0 - skipped))

    # 4 distinct row-values, each spanning 2 consecutive 32-row blocks:
    # the second block of each pair hits the table written by the first
    x2 = jnp.asarray(np.repeat(rng.randn(4, 64), 64, 0).astype(np.float32))
    w1 = jnp.asarray(rng.randn(64, 128).astype(np.float32) * 0.1)
    w2 = jnp.asarray(rng.randn(128, 32).astype(np.float32) * 0.1)
    ffn_flops = 2.0 * 256 * 64 * 128 + 2.0 * 256 * 128 * 32
    us = _time(lambda a: ops.iact_rowfn(a, w1, w2, block_rows=32)[0], x2)
    y2, m2 = ops.iact_rowfn(x2, w1, w2, block_rows=32)
    y2r, m2r = ref.iact_rowfn_ref(x2, w1, w2, block_rows=32, table_size=4,
                                  threshold=0.5)
    ok_iact = bool(np.allclose(np.asarray(y2), np.asarray(y2r), atol=1e-3))
    hit = float(np.asarray(m2).mean())
    emit("kernel_iact_rowfn", us,
         f"oracle_match={ok_iact},blocks_hit={hit:.0%}",
         oracle_match=ok_iact, executed_grid_fraction=1.0 - hit,
         flops_total=ffn_flops, flops_executed=ffn_flops * (1.0 - hit))
    bench["oracle_match"] = {"taf": ok_taf, "iact": ok_iact}
    bench["executed_grid_fraction"] = {"taf": 1.0 - skipped,
                                       "iact": 1.0 - hit}

    for skip in (2, 4, 8):
        p = PerforationParams(kind=PerforationKind.SMALL, skip=skip)
        us = _time(lambda a, b: ops.perforated_matmul(
            a, b, block_m=64, block_n=64, block_k=64, perfo=p), x, w)
        saved = drop_fraction(k // 64, p)
        emit("kernel_perforated_matmul", us,
             f"skip={skip},flops_saved={saved:.0%}",
             skip=skip, executed_grid_fraction=1.0 - saved,
             flops_total=matmul_flops,
             flops_executed=matmul_flops * (1.0 - saved))

    q = jnp.asarray(rng.randn(1, 4, 128, 64).astype(np.float32))
    kk = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    v = jnp.asarray(rng.randn(1, 2, 256, 64).astype(np.float32))
    attn_flops = 4.0 * 4 * 128 * 256 * 64  # qk + pv over 4 q heads
    for fr in (0.0, 0.5):
        p = (None if fr == 0.0 else
             PerforationParams(kind=PerforationKind.INI, fraction=fr))
        us = _time(lambda a, b, c: ops.perforated_attention(
            a, b, c, block_q=64, block_kv=64, perfo=p), q, kk, v)
        emit("kernel_perforated_attention", us, f"ini_drop={fr:.0%}",
             ini_drop=fr, executed_grid_fraction=1.0 - fr,
             flops_total=attn_flops, flops_executed=attn_flops * (1.0 - fr))

    # pipelined-variant parity: the double-buffered kernels (parallel
    # dimension_semantics on the state-free grid axes) must be BIT-equal
    # to the sequential variants -- outputs and approx masks both
    def _eq(a, b):
        return bool(jax.tree_util.tree_all(jax.tree_util.tree_map(
            lambda u, v2: bool(jnp.array_equal(u, v2)), a, b)))

    pperfo = PerforationParams(kind=PerforationKind.SMALL, skip=2)
    parity = {
        "taf_matmul": _eq(
            ops.taf_matmul(x, w, block_m=64, block_n=64, pipeline=True),
            ops.taf_matmul(x, w, block_m=64, block_n=64, pipeline=False)),
        "perforated_matmul": _eq(
            ops.perforated_matmul(x, w, block_m=64, block_n=64, block_k=64,
                                  perfo=pperfo, pipeline=True),
            ops.perforated_matmul(x, w, block_m=64, block_n=64, block_k=64,
                                  perfo=pperfo, pipeline=False)),
        "perforated_attention": _eq(
            ops.perforated_attention(q, kk, v, block_q=64, block_kv=64,
                                     perfo=None, pipeline=True),
            ops.perforated_attention(q, kk, v, block_q=64, block_kv=64,
                                     perfo=None, pipeline=False)),
    }
    bench["pipeline_parity"] = parity
    report("kernel_pipeline_parity", "0",
           ",".join(f"{k2}={v2}" for k2, v2 in sorted(parity.items())))

    # traced-knob dispatch cost: same kernel, swept threshold, ZERO recompiles
    from repro.kernels.taf_matmul import taf_matmul as taf_jit
    ops.taf_matmul(x, w, block_m=64, block_n=64, rsd_threshold=0.1)
    before = taf_jit._cache_size()
    t0 = time.perf_counter()
    n_sweep = 16
    for th in np.linspace(0.05, 2.0, n_sweep):
        jax.block_until_ready(ops.taf_matmul(
            x, w, block_m=64, block_n=64, rsd_threshold=float(th))[0])
    us = (time.perf_counter() - t0) * 1e6 / n_sweep
    recompiles = taf_jit._cache_size() - before
    emit("kernel_taf_threshold_sweep", us,
         f"n={n_sweep},recompiles={recompiles}",
         n_sweep=n_sweep, recompiles=int(recompiles))
    bench["sweep"] = {"n": n_sweep, "recompiles": int(recompiles)}

    # block-shape autotuning vs the hardcoded defaults, on the geometries
    # the defaults were written for (a fresh in-memory cache per run: the
    # committed cache must not pre-answer its own validation benchmark)
    cache = tuning.TuningCache()
    tune = {}
    for kernel, arrays in _tuning_arrays().items():
        default = _TUNE_DEFAULTS[kernel]
        tuned = tuning.autotune(kernel, *arrays, cache=cache,
                                max_measure=4, warmup=1, repeats=3)
        entry = cache.get(tuning.cache_key(
            kernel,
            tuning.key_shapes(kernel, tuning.operand_shapes(arrays)),
            str(arrays[0].dtype), tuning.current_machine_name(),
            tuning.current_substrate()))
        tuned_us = float(entry["us"])
        default_us = _time(tuning.build_call(kernel, default), *arrays)
        speedup = default_us / max(tuned_us, 1e-9)
        tune[kernel] = {
            "default": default, "tuned": tuned,
            "default_us": round(default_us, 1),
            "tuned_us": round(tuned_us, 1),
            "speedup": round(speedup, 3),
            "candidates": entry["candidates"],
            "measured": entry["measured"],
        }
        emit(f"kernel_tuned_{kernel}", tuned_us,
             f"default_us={default_us:.0f},speedup={speedup:.2f}x,"
             f"blocks={'/'.join(str(v2) for _, v2 in sorted(tuned.items()))}",
             tuned=tuned, default=default, speedup=round(speedup, 3))
    tune["all_beat_default"] = bool(all(
        v2["speedup"] > 1.0 for k2, v2 in tune.items() if isinstance(
            v2, dict)))
    bench["tuning"] = tune
    report("kernel_tuning_all_beat_default", "0",
           str(tune["all_beat_default"]))

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        path = os.path.join(artifacts_dir, "kernel_micro.json")
        with open(path, "w") as f:
            json.dump(rows, f, indent=1)
        report("kernel_micro_json", "0", path)
        bpath = os.path.join(artifacts_dir, "BENCH_kernel.json")
        from repro.obs import metrics as obs_metrics
        with open(bpath, "w") as f:
            json.dump(obs_metrics.stamp(bench), f, indent=1)
        report("BENCH_kernel_json", "0", bpath)
        cpath = cache.save(os.path.join(artifacts_dir, "tuning_cache.json"))
        report("tuning_cache_json", "0", cpath)
