"""approxlint as a benchmark module: one static-analysis pass over every
app group, reported in the harness's CSV rows.

The "measurement" here is the analyzer itself -- wall time of the full
pass plus the finding counts it produced. A non-zero error count (or a
crashed rule) is reported as a FAIL row so it is visible in the CSV, and
the regression gate pins the counts exactly via ``BENCH_lint.json``: a
new finding OR a new allowlist entry both show up as baseline drift and
must be reviewed, not slipped in. With ``artifacts_dir``, the full
machine-readable findings report (every finding, every allowlisted
finding with its reason, every rule crash) is written to
``<artifacts_dir>/BENCH_lint.json`` so CI can upload it as a build
artifact and commits are diffable finding-by-finding.
"""
from __future__ import annotations

import json
import os
import time
from typing import Optional


def main(report, artifacts_dir: Optional[str] = None):
    from repro.analysis.findings import Allowlist, default_allowlist_path
    from repro.analysis.lint import run_lint

    allow_path = default_allowlist_path(os.path.dirname(__file__))
    allow = Allowlist.load(allow_path) if allow_path else None
    t0 = time.perf_counter()
    rep = run_lint(allowlist=allow)   # all app groups, no policies
    us = (time.perf_counter() - t0) * 1e6

    doc = rep.to_json()
    doc["metric"] = "approxlint"
    s = doc["summary"]
    report("lint_pass", f"{us:.0f}",
           f"findings={s['total']} allowlisted={s['allowlisted']}")
    for rule, n in sorted(s["by_rule"].items()):
        report(f"lint_{rule}", f"{us:.0f}", f"n={n}")
    if rep.errors:
        report("lint_rule_crash", "FAIL", "; ".join(rep.errors)[:200])
    if s["errors"]:
        subjects = ", ".join(sorted(
            f"{f['rule']} {f['subject']}" for f in doc["findings"]
            if f["severity"] == "error"))
        report("lint_errors", "FAIL", subjects[:200])

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        out = os.path.join(artifacts_dir, "BENCH_lint.json")
        from repro.obs import metrics as obs_metrics
        with open(out, "w") as f:
            json.dump(obs_metrics.stamp(doc), f, indent=1, sort_keys=True)
        report("lint_artifact", f"{us:.0f}", out)
