"""Paper Figure 11c: hierarchical decision level vs speedup (LavaMD).

Thread-level (ELEMENT) decisions on a vector machine save NOTHING (masked
lanes still execute -- the TPU-hardened version of warp divergence); group
decisions (BLOCK, driving lax.cond / @pl.when) skip whole invocations. We
compare ELEMENT vs BLOCK at equal thresholds: wall-time speedup appears
only at BLOCK level; the paper's warp-level result (up to 2.27x median
speedup) is the GPU shadow of the same effect.
"""
from __future__ import annotations

import sys

sys.path.insert(0, "examples")

from apps import lavamd
from repro.core import ApproxSpec, Level, TAFParams, Technique
from repro.core.harness import mape


def main(report):
    app = lavamd.make_app(nx=4, seed=2)
    exact = app.exact()
    for t in (0.3, 1.0, 3.0):
        row = {}
        for level in (Level.ELEMENT, Level.BLOCK):
            spec = ApproxSpec(Technique.TAF, level,
                              taf=TAFParams(3, 16, t))
            r = app.run(spec)
            err = mape(exact.qoi, r.qoi)
            row[level] = (exact.wall_time_s / max(r.wall_time_s, 1e-9),
                          r.approx_fraction, err)
        e_sp, e_frac, e_err = row[Level.ELEMENT]
        b_sp, b_frac, b_err = row[Level.BLOCK]
        report("fig11c_hierarchy", f"T={t}",
               f"element:wall={e_sp:.2f}x(frac={e_frac:.2f},err={e_err:.2%});"
               f"block:wall={b_sp:.2f}x(frac={b_frac:.2f},err={b_err:.2%})")
