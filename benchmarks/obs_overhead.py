"""Regression gate for the obs layer's overhead contract.

Two promises from docs/observability.md, measured on a real
`ServingEngine` decode loop and pinned by ``BENCH_obs.json``:

  * **zero-cost when disabled** -- with no tracer installed, the
    instrumented `tick()` must not add compiles (the serve step's jit
    cache size is read before/after) and the tracing-enabled-vs-disabled
    tick throughput ratio must stay >= ``_RATIO_FLOOR``;
  * **no recompiles when enabled** -- installing a tracer changes no jit
    signature either (spans are host-side timers around unchanged calls).

Phases interleave disabled/enabled ([off, on, off, on]) and each mode
takes its best phase, so a one-off scheduler stall cannot fail the gate
in either direction. The ratio is gated as a boolean (``ratio_ok``) under
the regression harness's ``exact`` rules: the ``close``/``atleast``
tolerances (rtol=0.25 / noise=0.8) are far looser than the 0.95 floor
this contract needs.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Optional

import numpy as np
import jax

from repro import qos
from repro.models import build
from repro.obs import metrics as obs_metrics
from repro.obs import trace
from repro.serving import Request, ServingEngine

_TICKS = 24             # decode ticks per phase
_SLOTS = 4
_PROMPT_LEN = 8
_RATIO_FLOOR = 0.95


def _engine():
    # widened from the smoke config: the overhead contract is measured
    # against a realistically-costed decode step. On the 64-wide smoke
    # model a tick is sub-millisecond pure Python/dispatch, so the span
    # bookkeeping would dominate the measurement instead of the serving
    # work it wraps.
    cfg = dataclasses.replace(qos.default_decode_cfg(), n_layers=4,
                              d_model=256, d_ff=1024, n_heads=4,
                              n_kv_heads=2, head_dim=64)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, slots=_SLOTS,
                        max_len=_PROMPT_LEN + 6 * _TICKS,
                        prompt_len=_PROMPT_LEN)
    rng = np.random.RandomState(0)
    for i in range(_SLOTS):
        eng.submit(Request(
            uid=i,
            prompt=rng.randint(0, cfg.vocab_size, _PROMPT_LEN)
            .astype(np.int32),
            max_new_tokens=5 * _TICKS))
    return eng


def _ticks_per_s(eng, n: int) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        eng.tick()
    return n / max(time.perf_counter() - t0, 1e-9)


def main(report, artifacts_dir: Optional[str] = None) -> None:
    eng = _engine()
    eng.warmup()
    eng.tick()          # admission prefill + first decode, outside timing
    cache_size0 = eng._serve._cache_size()

    tracer = trace.Tracer()
    tps = {"off": [], "on": []}
    compiles = {"off": cache_size0, "on": cache_size0}
    for mode in ("off", "on", "off", "on"):
        if mode == "on":
            trace.enable(tracer)
        try:
            tps[mode].append(_ticks_per_s(eng, _TICKS))
        finally:
            if mode == "on":
                trace.disable()
        compiles[mode] = eng._serve._cache_size()

    off_tps, on_tps = max(tps["off"]), max(tps["on"])
    ratio = on_tps / max(off_tps, 1e-9)
    extra_off = compiles["off"] - cache_size0
    extra_on = compiles["on"] - cache_size0
    events = len(tracer)

    report("obs_disabled_ticks_per_s", f"{1e6 / max(off_tps, 1e-9):.0f}",
           f"ticks_per_s={off_tps:.1f}")
    report("obs_enabled_ticks_per_s", f"{1e6 / max(on_tps, 1e-9):.0f}",
           f"ticks_per_s={on_tps:.1f},trace_events={events}")
    report("obs_overhead", "0",
           f"ratio={ratio:.3f},floor={_RATIO_FLOOR},"
           f"extra_compiles_disabled={extra_off},"
           f"extra_compiles_enabled={extra_on}")

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        path = os.path.join(artifacts_dir, "BENCH_obs.json")
        doc = obs_metrics.stamp({
            "metric": "obs_overhead",
            "ticks_per_phase": _TICKS,
            "slots": _SLOTS,
            "disabled_ticks_per_s": off_tps,
            "enabled_ticks_per_s": on_tps,
            "ratio": ratio,
            "ratio_floor": _RATIO_FLOOR,
            "ratio_ok": bool(ratio >= _RATIO_FLOOR),
            "extra_compiles_disabled": int(extra_off),
            "extra_compiles_enabled": int(extra_on),
            "trace_events": int(events),
        })
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
        report("obs_json", "0", path)


if __name__ == "__main__":
    main(lambda n, us, d="": print(f"{n},{us},{d}"),
         artifacts_dir=os.environ.get("ARTIFACTS_DIR", "artifacts"))
