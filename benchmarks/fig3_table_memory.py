"""Paper Figure 3: memoization-table memory vs thread count.

Per-thread tables grow linearly with logical threads and exhaust device
memory around 2^27 threads (paper: 16 GB V100, 5-entry 36-byte tables).
HPAC-Offload's insight -- state sized by RESIDENT execution slots -- maps on
TPU to VMEM scratch sized by the Pallas grid block (DESIGN.md section 2): a
constant ~KB per core regardless of logical iteration count.
"""
from __future__ import annotations

ENTRY_BYTES = 36
TABLE_ENTRIES = 5
V100_GLOBAL = 16 * 2**30
TPU_VMEM = 16 * 2**20          # ~16 MiB VMEM per TPU core
BLOCK_ROWS = 128               # our iact kernel's resident decision slots
D_IN, D_OUT = 32, 32


def rows():
    out = []
    per_thread = TABLE_ENTRIES * ENTRY_BYTES
    # our kernel: one table per grid block, resident in VMEM
    kernel_bytes = TABLE_ENTRIES * (D_IN + D_OUT) * 4
    for log2_threads in range(16, 33, 2):
        n = 2 ** log2_threads
        gpu_frac = n * per_thread / V100_GLOBAL
        out.append({
            "n_threads": n,
            "per_thread_tables_bytes": n * per_thread,
            "pct_of_V100": 100.0 * gpu_frac,
            "hpac_offload_tpu_bytes": kernel_bytes,
            "pct_of_VMEM": 100.0 * kernel_bytes / TPU_VMEM,
        })
    return out


def main(report):
    for r in rows():
        report("fig3_table_memory",
               f"threads=2^{r['n_threads'].bit_length()-1}",
               f"per_thread={r['pct_of_V100']:.1f}%V100,"
               f"ours={r['pct_of_VMEM']:.3f}%VMEM")
