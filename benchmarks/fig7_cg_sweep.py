"""Paper Figure 7/9c analogue: the implicit-solver case (MiniFE).

Sweeps TAF + perforation over the CG solve and reports the error
distribution -- reproducing the paper's finding that iterative implicit
solvers amplify local approximation error (MiniFE errors: 593% .. 3.4e22%),
making them hostile AC targets.
"""
from __future__ import annotations

import sys

import numpy as np

sys.path.insert(0, "examples")

from apps import minife_cg
from repro.core import Level
from repro.core.harness import perfo_grid, sweep, taf_grid


def main(report, jobs: int = 1, db_path=None):
    app = minife_cg.make_app(n=48)
    grid = taf_grid(h_sizes=(3,), p_sizes=(8,), thresholds=(0.5, 5.0),
                    levels=(Level.ELEMENT,)) + \
        perfo_grid(skips=(4, 16), fractions=(0.1,),
                   kinds=tuple(__import__(
                       "repro.core.types", fromlist=["PerforationKind"]
                   ).PerforationKind(k) for k in ("small", "ini")))
    recs = sweep(app, grid, repeats=1, jobs=jobs, db_path=db_path)
    errs = np.asarray([r.error for r in recs])
    finite = errs[np.isfinite(errs)]
    report("fig7_cg_sweep", "error_range",
           f"min={finite.min():.3g},max={finite.max():.3g},"
           f"n_diverged={int((~np.isfinite(errs)).sum())}/{len(errs)}")
    under = [r for r in recs if r.error < 0.10]
    report("fig7_cg_sweep", "configs_under_10pct", f"{len(under)}/{len(recs)}"
           " (implicit solvers amplify AC error -- matches paper)")
