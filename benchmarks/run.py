"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Usage:

  PYTHONPATH=src python -m benchmarks.run [--only fig6,kernel] [--jobs N]

``--jobs`` is threaded through to every module whose ``main`` accepts a
``jobs`` keyword (the sweep-based figures): it sets the harness's parallel
evaluation width (batched runner chunk size / thread-pool workers).
``--db`` points those modules at a persistent results database, making
re-runs resumable (cached specs are not re-executed).
``--substrate`` selects the execution substrate (host | pallas); a module
must be able to measure the named path -- see ``substrate_support()`` for
the per-module table (`ffn` dispatches through `repro.core.substrate`,
`kernel` is pallas-native, everything else host-only) -- so the flag can
never silently measure the wrong path. ``--artifacts`` names a directory for machine-readable
outputs (kernel_micro writes its structural numbers there as JSON;
qos_serving writes ``BENCH_qos.json``).
"""
from __future__ import annotations

import argparse
import inspect
import sys
import time

sys.path.insert(0, "examples")

from . import (approx_ffn_sweep, fig3_table_memory, fig6_best_speedup,
               fig7_cg_sweep, fig8c_items_per_thread, fig10c_rsd_behavior,
               fig11c_hierarchy, fig12c_kmeans_convergence, kernel_micro,
               pareto_refine, qos_serving, roofline_table)

MODULES = {
    "fig3": fig3_table_memory,
    "fig6": fig6_best_speedup,
    "fig7": fig7_cg_sweep,
    "fig8c": fig8c_items_per_thread,
    "fig10c": fig10c_rsd_behavior,
    "fig11c": fig11c_hierarchy,
    "fig12c": fig12c_kmeans_convergence,
    "kernel": kernel_micro,
    "ffn": approx_ffn_sweep,
    "pareto": pareto_refine,
    "qos": qos_serving,
    "roofline": roofline_table,
}


def substrate_support() -> dict:
    """Explicit --substrate support table: the substrates each module's
    measurements can come from. A module declaring a `substrate` parameter
    on its `main` dispatches through `repro.core.substrate` (host or
    pallas); kernel_micro is pallas-NATIVE (it times the Pallas kernels
    directly and cannot emulate the host path); everything else always
    runs the host technique emulation. The CLI fails fast whenever
    --substrate names a path a selected module cannot measure -- in
    EITHER direction, so the flag can never silently measure the wrong
    thing."""
    table = {key: {"host", "pallas"}
             if "substrate" in inspect.signature(mod.main).parameters
             else {"host"}
             for key, mod in MODULES.items()}
    table["kernel"] = {"pallas"}
    return table


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys "
                    f"(default all: {','.join(MODULES)})")
    ap.add_argument("--jobs", type=int, default=1,
                    help="parallel evaluation width for sweep-based modules")
    ap.add_argument("--db", default=None,
                    help="path to a persistent sweep DB (enables resume)")
    ap.add_argument("--substrate", default=None, choices=["host", "pallas"],
                    help="execution substrate for kernel-aware modules")
    ap.add_argument("--artifacts", default=None,
                    help="directory for machine-readable outputs (JSON)")
    args = ap.parse_args()
    keys = args.only.split(",") if args.only else list(MODULES)
    for key in keys:  # fail fast, before any module burns sweep time
        if key.strip() not in MODULES:
            ap.error(f"unknown module {key.strip()!r} "
                     f"(choose from: {','.join(MODULES)})")
    if args.substrate:
        # Fail fast (before any module burns sweep time) when the named
        # substrate is not what a selected module measures: a host-only
        # module would silently measure the host emulation under
        # --substrate pallas, and the pallas-native kernel module would
        # silently measure the kernels under --substrate host.
        support = substrate_support()
        deaf = sorted(k.strip() for k in keys
                      if args.substrate not in support[k.strip()])
        if deaf:
            ap.error(
                f"--substrate {args.substrate} cannot be honored by "
                f"{','.join(deaf)}: the flag would silently measure a "
                "different path. Per-module support: "
                + "; ".join(f"{k}={'|'.join(sorted(v))}"
                            for k, v in sorted(support.items())
                            if k in {x.strip() for x in keys}))

    print("name,us_per_call,derived")

    def report(name: str, us, derived: str = ""):
        print(f"{name},{us},{derived}", flush=True)

    for key in keys:
        mod = MODULES[key.strip()]
        accepted = inspect.signature(mod.main).parameters
        kw = {k: v for k, v in (("jobs", args.jobs), ("db_path", args.db),
                                ("substrate", args.substrate),
                                ("artifacts_dir", args.artifacts))
              if k in accepted and v is not None}
        t0 = time.time()
        try:
            mod.main(report, **kw)
        except Exception as e:  # keep the harness running
            report(key, "ERROR", str(e)[:200])
        report(f"_{key}_total_s", f"{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
