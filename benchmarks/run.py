"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Usage:

  PYTHONPATH=src python -m benchmarks.run [--only fig6,kernel]
"""
from __future__ import annotations

import argparse
import sys
import time

sys.path.insert(0, "examples")

from . import (fig3_table_memory, fig6_best_speedup, fig7_cg_sweep,
               fig8c_items_per_thread, fig10c_rsd_behavior, fig11c_hierarchy,
               fig12c_kmeans_convergence, kernel_micro, roofline_table)

MODULES = {
    "fig3": fig3_table_memory,
    "fig6": fig6_best_speedup,
    "fig7": fig7_cg_sweep,
    "fig8c": fig8c_items_per_thread,
    "fig10c": fig10c_rsd_behavior,
    "fig11c": fig11c_hierarchy,
    "fig12c": fig12c_kmeans_convergence,
    "kernel": kernel_micro,
    "roofline": roofline_table,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys "
                    f"(default all: {','.join(MODULES)})")
    args = ap.parse_args()
    keys = args.only.split(",") if args.only else list(MODULES)

    print("name,us_per_call,derived")

    def report(name: str, us, derived: str = ""):
        print(f"{name},{us},{derived}", flush=True)

    for key in keys:
        mod = MODULES[key.strip()]
        t0 = time.time()
        try:
            mod.main(report)
        except Exception as e:  # keep the harness running
            report(key, "ERROR", str(e)[:200])
        report(f"_{key}_total_s", f"{time.time() - t0:.1f}")


if __name__ == "__main__":
    main()
