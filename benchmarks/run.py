"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines. Usage:

  PYTHONPATH=src python -m benchmarks.run [--only fig6,kernel] [--jobs N]

``--jobs`` is threaded through to every module whose ``main`` accepts a
``jobs`` keyword (the sweep-based figures): it sets the harness's parallel
evaluation width (batched runner chunk size / thread-pool workers).
``--db`` points those modules at a persistent results database, making
re-runs resumable (cached specs are not re-executed).
``--substrate`` selects the execution substrate (host | pallas); a module
must be able to measure the named path -- see ``substrate_support()`` for
the per-module table (`ffn` dispatches through `repro.core.substrate`,
`kernel` is pallas-native, everything else host-only) -- so the flag can
never silently measure the wrong path. ``--artifacts`` names a directory for machine-readable
outputs (kernel_micro writes its structural numbers, its regression
summary ``BENCH_kernel.json``, and the autotuner's ``tuning_cache.json``;
qos_serving writes ``BENCH_qos.json``; approx_ffn_sweep writes
``BENCH_ffn.json``; costmodel validates the analytical predictor against
measured sweeps and writes ``BENCH_costmodel.json``).
``--predict`` switches predict-aware modules (currently `ffn`) into
cost-model pruned mode: only the predicted Pareto-front band of the grid
(<= 1/5 of it) is measured, and the module reports how much of the
committed full-grid front the pruned sweep recovers (writes
``BENCH_ffn_predict.json``, never the full-grid baseline artifact).
``--devices`` runs device-aware modules (currently `qos`) with the decode
data plane sharded over that many devices (pair with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` on a 1-GPU/CPU
host).
``--check-regression <baseline-dir-or-file>`` compares the artifacts
produced THIS run against committed baselines (benchmarks/baselines/) and
exits non-zero beyond the noise margin -- the CI perf gate. Structural
numbers (counts, fractions, hypervolumes) are held to a tight relative
tolerance; wall-clock throughputs only have to stay above
``(1 - noise) * baseline`` (default --noise 0.8, i.e. a 5x slowdown
fails: the gate exists to catch order-of-magnitude regressions like a
compile landing inside a timed region, not scheduler jitter across CI
hosts).
"""
from __future__ import annotations

import argparse
import glob
import inspect
import json
import os
import sys
import time

sys.path.insert(0, "examples")

from . import (approx_ffn_sweep, costmodel, fig3_table_memory,
               fig6_best_speedup, fig7_cg_sweep, fig8c_items_per_thread,
               fig10c_rsd_behavior, fig11c_hierarchy,
               fig12c_kmeans_convergence, kernel_micro, lint, obs_overhead,
               pareto_refine, qos_serving, roofline_table)

MODULES = {
    "fig3": fig3_table_memory,
    "fig6": fig6_best_speedup,
    "fig7": fig7_cg_sweep,
    "fig8c": fig8c_items_per_thread,
    "fig10c": fig10c_rsd_behavior,
    "fig11c": fig11c_hierarchy,
    "fig12c": fig12c_kmeans_convergence,
    "kernel": kernel_micro,
    "lint": lint,
    "ffn": approx_ffn_sweep,
    "pareto": pareto_refine,
    "qos": qos_serving,
    "roofline": roofline_table,
    "costmodel": costmodel,
    "obs": obs_overhead,
}


def substrate_support() -> dict:
    """Explicit --substrate support table: the substrates each module's
    measurements can come from. A module declaring a `substrate` parameter
    on its `main` dispatches through `repro.core.substrate` (host or
    pallas); kernel_micro is pallas-NATIVE (it times the Pallas kernels
    directly and cannot emulate the host path); everything else always
    runs the host technique emulation. The CLI fails fast whenever
    --substrate names a path a selected module cannot measure -- in
    EITHER direction, so the flag can never silently measure the wrong
    thing."""
    table = {key: {"host", "pallas"}
             if "substrate" in inspect.signature(mod.main).parameters
             else {"host"}
             for key, mod in MODULES.items()}
    table["kernel"] = {"pallas"}
    return table


# --------------------------------------------------------------------------
# regression gate: fresh artifacts vs committed baselines
# --------------------------------------------------------------------------

# Per-artifact check rules, by dotted path into the JSON:
#   exact    -- configuration identity: a mismatch means the benchmark is
#               no longer measuring the same thing as the baseline;
#   close    -- structural/quality numbers, deterministic up to float
#               rounding across hosts: |new - base| <= atol + rtol * |base|;
#   atleast  -- wall-clock throughputs: new >= (1 - noise) * base.
_BASELINE_CHECKS = {
    "BENCH_qos.json": {
        "exact": ("metric", "devices", "shards", "slots", "requests"),
        "close": ("measured_error", "fallback_rate",
                  "approx.taf_skip_fraction"),
        "atleast": ("precise.tokens_per_s", "approx.tokens_per_s"),
    },
    "BENCH_ffn.json": {
        "exact": ("substrate", "n_records", "parity.taf", "parity.iact",
                  "parity.perfo"),
        "close": ("front.n_front", "front.hypervolume", "front.best_error",
                  "front.best_speedup"),
        "atleast": (),
    },
    # approxlint must stay CLEAN, and the allowlist may only grow through
    # a reviewed baseline bump: a new finding, a crashed rule, or a new
    # allow entry all drift from the committed counts and fail the gate.
    "BENCH_lint.json": {
        "exact": ("metric", "summary.total", "summary.errors",
                  "summary.warnings", "summary.allowlisted"),
        "close": (),
        "atleast": (),
    },
    # kernel microbenchmarks: oracle/pipeline parity, recompile counts and
    # the tuned-beats-default verdict are deterministic (exact); the
    # data-dependent skip fractions are deterministic up to float rounding
    # (close); tuned-vs-default speedup ratios are wall-clock and only
    # have to stay above the noise margin (absolute microseconds are
    # machine-dependent and never gated).
    "BENCH_kernel.json": {
        "exact": ("metric", "substrate", "oracle_match.taf",
                  "oracle_match.iact", "sweep.n", "sweep.recompiles",
                  "pipeline_parity.taf_matmul",
                  "pipeline_parity.perforated_matmul",
                  "pipeline_parity.perforated_attention",
                  "tuning.all_beat_default"),
        "close": ("executed_grid_fraction.taf",
                  "executed_grid_fraction.iact"),
        "atleast": ("tuning.taf_matmul.speedup",
                    "tuning.iact_rowfn.speedup",
                    "tuning.perforated_matmul.speedup",
                    "tuning.perforated_attention.speedup"),
    },
    # the analytical predictor's validation: kept/dropped grid counts are
    # structural (exact); rank correlations and the pruned-sweep front
    # recovery are deterministic up to float rounding (close).
    "BENCH_costmodel.json": {
        "exact": ("apps.blackscholes.kept", "apps.blackscholes.bound_holds",
                  "apps.binomial_options.bound_holds",
                  "apps.lavamd.bound_holds",
                  "ffn.n_grid", "ffn.kept", "ffn.dropped",
                  "ffn.band_budget", "ffn.band_measured", "ffn.recovered"),
        "close": ("apps.blackscholes.spearman",
                  "apps.binomial_options.spearman", "apps.kmeans.spearman",
                  "apps.lavamd.spearman", "apps.minife_cg.spearman",
                  "ffn.spearman", "ffn.front_recovery.ratio"),
        "atleast": (),
    },
    # the obs layer's overhead contract: the 0.95-tracing-overhead floor
    # is gated as a precomputed boolean (`ratio_ok`) under `exact` --
    # `close` (rtol=0.25) and `atleast` (noise=0.8) are both far looser
    # than the contract -- and the disabled/enabled paths must add ZERO
    # compiles to the serve step (an instrumentation hook that changes a
    # jit signature is exactly the regression this file exists to catch).
    "BENCH_obs.json": {
        "exact": ("metric", "ratio_ok", "extra_compiles_disabled",
                  "extra_compiles_enabled"),
        "close": (),
        "atleast": ("disabled_ticks_per_s", "enabled_ticks_per_s"),
    },
}


def _lookup(doc, dotted):
    cur = doc
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def check_regression(artifacts_dir: str, baseline: str, *,
                     noise: float = 0.8, rtol: float = 0.25,
                     atol: float = 0.05) -> list:
    """Compare this run's artifacts against committed baselines. Returns a
    list of human-readable failure strings (empty = gate passed), ALWAYS
    covering every baseline file: an unreadable/corrupt artifact becomes a
    failure entry for that module and the scan continues, so one broken
    artifact cannot mask regressions in the modules after it. Every
    baseline file must have a fresh counterpart: a module silently dropped
    from the benchmark run is itself a regression."""
    if os.path.isdir(baseline):
        base_files = sorted(glob.glob(os.path.join(baseline,
                                                   "BENCH_*.json")))
    else:
        base_files = [baseline]
    if not base_files:
        return [f"no BENCH_*.json baselines found under {baseline}"]
    failures = []
    for bf in base_files:
        name = os.path.basename(bf)
        af = os.path.join(artifacts_dir, name)
        rules = _BASELINE_CHECKS.get(name)
        if rules is None:
            failures.append(f"{name}: no check rules registered in "
                            f"benchmarks.run._BASELINE_CHECKS")
            continue
        if not os.path.exists(af):
            failures.append(f"{name}: baseline committed but no fresh "
                            f"artifact in {artifacts_dir} (module not run?)")
            continue
        try:
            with open(bf) as f:
                base = json.load(f)
        except (OSError, ValueError) as e:
            failures.append(f"{name}: baseline unreadable "
                            f"({type(e).__name__}: {e})")
            continue
        try:
            with open(af) as f:
                new = json.load(f)
        except (OSError, ValueError) as e:
            failures.append(f"{name}: fresh artifact unreadable "
                            f"({type(e).__name__}: {e})")
            continue
        for key in rules.get("exact", ()):
            b, n = _lookup(base, key), _lookup(new, key)
            if b != n:
                failures.append(f"{name}:{key}: expected {b!r}, got {n!r}")
        for key in rules.get("close", ()):
            b, n = _lookup(base, key), _lookup(new, key)
            if not isinstance(n, (int, float)) or not isinstance(
                    b, (int, float)):
                failures.append(f"{name}:{key}: non-numeric "
                                f"(base={b!r}, new={n!r})")
            elif abs(n - b) > atol + rtol * abs(b):
                failures.append(
                    f"{name}:{key}: {n:.6g} vs baseline {b:.6g} "
                    f"(tolerance atol={atol} rtol={rtol})")
        for key in rules.get("atleast", ()):
            b, n = _lookup(base, key), _lookup(new, key)
            if not isinstance(n, (int, float)) or not isinstance(
                    b, (int, float)):
                failures.append(f"{name}:{key}: non-numeric "
                                f"(base={b!r}, new={n!r})")
            elif n < (1.0 - noise) * b:
                failures.append(
                    f"{name}:{key}: {n:.6g} below {(1 - noise):.0%} of "
                    f"baseline {b:.6g} (noise margin {noise})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module keys "
                    f"(default all: {','.join(MODULES)})")
    ap.add_argument("--jobs", type=int, default=1,
                    help="parallel evaluation width for sweep-based modules")
    ap.add_argument("--db", default=None,
                    help="path to a persistent sweep DB (enables resume)")
    ap.add_argument("--substrate", default=None, choices=["host", "pallas"],
                    help="execution substrate for kernel-aware modules")
    ap.add_argument("--artifacts", default=None,
                    help="directory for machine-readable outputs (JSON)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard device-aware modules (qos) over N devices")
    ap.add_argument("--check-regression", default=None, metavar="BASELINE",
                    help="after the run, compare --artifacts against this "
                    "baseline dir/file and exit non-zero on regression")
    ap.add_argument("--noise", type=float, default=0.8,
                    help="throughput noise margin for --check-regression "
                    "(fail below (1-noise)*baseline; default 0.8)")
    ap.add_argument("--predict", action="store_true",
                    help="cost-model pruned mode for predict-aware modules "
                    "(ffn: measure only the predicted front band, <= 1/5 of "
                    "the grid, and report recovery vs the committed front)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a Chrome/Perfetto trace of the whole run "
                    "(one span per module, plus every repro.obs span the "
                    "modules emit) and write it to this path")
    args = ap.parse_args()
    if args.check_regression and not args.artifacts:
        ap.error("--check-regression needs --artifacts (the gate compares "
                 "the artifacts THIS run writes)")
    keys = args.only.split(",") if args.only else list(MODULES)
    for key in keys:  # fail fast, before any module burns sweep time
        if key.strip() not in MODULES:
            ap.error(f"unknown module {key.strip()!r} "
                     f"(choose from: {','.join(MODULES)})")
    if args.substrate:
        # Fail fast (before any module burns sweep time) when the named
        # substrate is not what a selected module measures: a host-only
        # module would silently measure the host emulation under
        # --substrate pallas, and the pallas-native kernel module would
        # silently measure the kernels under --substrate host.
        support = substrate_support()
        deaf = sorted(k.strip() for k in keys
                      if args.substrate not in support[k.strip()])
        if deaf:
            ap.error(
                f"--substrate {args.substrate} cannot be honored by "
                f"{','.join(deaf)}: the flag would silently measure a "
                "different path. Per-module support: "
                + "; ".join(f"{k}={'|'.join(sorted(v))}"
                            for k, v in sorted(support.items())
                            if k in {x.strip() for x in keys}))

    print("name,us_per_call,derived")

    def report(name: str, us, derived: str = ""):
        print(f"{name},{us},{derived}", flush=True)

    tracer = None
    if args.trace:
        from repro.obs import trace as obs_trace
        tracer = obs_trace.Tracer()
        obs_trace.enable(tracer)

    from repro.obs import metrics as obs_metrics

    for key in keys:
        mod = MODULES[key.strip()]
        accepted = inspect.signature(mod.main).parameters
        kw = {k: v for k, v in (("jobs", args.jobs), ("db_path", args.db),
                                ("substrate", args.substrate),
                                ("artifacts_dir", args.artifacts),
                                ("devices", args.devices),
                                ("predict", True if args.predict else None))
              if k in accepted and v is not None}
        # each module starts from a clean metrics registry, so the obs
        # snapshot stamped into its BENCH_*.json is that module's alone
        obs_metrics.reset()
        t0 = time.time()
        try:
            if tracer is not None:
                from repro.obs import trace as obs_trace
                with obs_trace.span(f"bench.{key.strip()}"):
                    mod.main(report, **kw)
            else:
                mod.main(report, **kw)
        except Exception as e:  # keep the harness running
            report(key, "ERROR", str(e)[:200])
        report(f"_{key}_total_s", f"{time.time() - t0:.1f}")

    if tracer is not None:
        from repro.obs import trace as obs_trace
        obs_trace.disable()
        tracer.save(args.trace)
        report("trace", len(tracer), args.trace)

    if args.check_regression:
        # after the module loop, OUTSIDE the per-module exception guard:
        # the gate must fail the process, not become an ERROR row
        fails = check_regression(args.artifacts, args.check_regression,
                                 noise=args.noise)
        for f in fails:
            report("regression", "FAIL", f)
        if fails:
            # name the offending artifact:metric pairs on stderr too --
            # CI log scrapers (and humans skimming a red job) should not
            # have to fish the failure out of the CSV stream
            print(f"regression gate FAILED ({len(fails)} check(s)):",
                  file=sys.stderr)
            for f in fails:
                print(f"  {f}", file=sys.stderr)
            sys.exit(2)
        report("regression", "OK",
               f"artifacts match {args.check_regression} "
               f"(noise={args.noise})")


if __name__ == "__main__":
    main()
