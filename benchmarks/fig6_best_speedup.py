"""Paper Figure 6: best speedup with error < 10%, per app x technique.

Sweeps a reduced Table-2-style grid per technique over each benchmark app
and reports the fastest configuration under the 10% error bound, in both
measured wall time (this CPU container) and modeled speedup
(1 / executed-fraction: the roofline-bound speedup on a machine where
skipped work is genuinely free, i.e. TPU block-level).
"""
from __future__ import annotations

import sys

sys.path.insert(0, "examples")

from apps import binomial_options, blackscholes, kmeans, lavamd
from repro.core import Level
from repro.core.harness import (best_speedup_under_error, iact_grid, sweep,
                                taf_grid)

APPS = {
    "blackscholes": (blackscholes.make_app, dict(n_elements=512, steps=48)),
    "binomial": (binomial_options.make_app,
                 dict(n_elements=48, steps=16, tree_steps=96)),
    "kmeans": (kmeans.make_app, dict(n=1024, d=6, k=8)),
    "lavamd": (lavamd.make_app, dict(nx=4)),
}

TAF_GRID = taf_grid(h_sizes=(2, 3), p_sizes=(8, 64),
                    thresholds=(0.1, 0.5, 1.5),
                    levels=(Level.ELEMENT, Level.BLOCK))
IACT_GRID = iact_grid(t_sizes=(2, 4), thresholds=(0.3, 0.9),
                      tables_per_block=(0, 8),
                      levels=(Level.ELEMENT, Level.BLOCK))


def main(report, jobs: int = 1, db_path=None):
    for name, (make, kw) in APPS.items():
        app = make(**kw)
        for tech, grid in (("taf", TAF_GRID), ("iact", IACT_GRID)):
            recs = sweep(app, grid, repeats=2, jobs=jobs, db_path=db_path)
            best = best_speedup_under_error(recs, 0.10, use_modeled=True)
            if best is None:
                report("fig6_best_speedup", f"{name}/{tech}",
                       "no config under 10% error")
                continue
            report("fig6_best_speedup", f"{name}/{tech}",
                   f"modeled={best.modeled_speedup:.2f}x,"
                   f"wall={best.speedup:.2f}x,err={best.error:.3%},"
                   f"level={best.spec['level']}")
