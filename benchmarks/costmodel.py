"""approxcost validation: predicted-vs-measured on the example apps + ffn.

Two claims get checked, statically-predicted numbers against the same
measured `Record` stream every other benchmark consumes:

1. **Ranking.** Per app, the analytical predictor
   (`repro.analysis.cost.AppCostModel`, region costs traced with
   `trace_cost` -- no hand-counted FLOPs) must rank a TAF threshold grid
   the same way the measured structural speedups
   (`Record.modeled_speedup`) do: Spearman rank correlation, reported
   per app and pinned by the regression gate.

2. **Pruned front recovery.** For the ffn app, the predictor's
   `select_band` picks ``len(grid) // 5`` specs out of the full
   30-spec sweep grid; only those are measured, and the measured band's
   Pareto hypervolume must recover the committed full-grid front
   (``benchmarks/baselines/BENCH_ffn.json``) within
   ``FRONT_TOLERANCE`` -- the ISSUE's "same front at an order of
   magnitude fewer measured points" statistic, here at the 1/5 budget
   the acceptance bar sets.

Writes ``BENCH_costmodel.json`` (kept/dropped counts exact, Spearman
and hypervolume-recovery close) for ``benchmarks.run
--check-regression``.
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, Optional

import numpy as np
import jax.numpy as jnp

from repro.analysis.cost import AppCostModel, CostVector, Site, trace_cost
from repro.analysis.machine import get_machine
from repro.core import pareto
from repro.core.harness import sweep, taf_grid
from repro.core.types import Level, Technique

# Documented tolerance for the ffn front-recovery acceptance criterion:
# the measured band's hypervolume must reach this fraction of the
# committed full-grid front's hypervolume.
FRONT_TOLERANCE = 0.90

# Small-but-representative validation workloads: the predictor only
# consumes structure (traced region cost, invocation counts), so scaled-
# down shapes validate the same model the full-size sweeps would use.
# blackscholes runs the regime-switching walk (volatility > 1, as in
# fig10c) so the RSD activation genuinely discriminates across the grid.
_WORKLOADS = {
    "blackscholes": dict(n_elements=128, steps=32, volatility=2.0),
    "binomial_options": dict(n_elements=32, steps=16, tree_steps=64),
    "kmeans": dict(n=256, d=4, k=4, max_iters=10),
    "lavamd": dict(nx=3),
    "minife_cg": dict(n=32, iters=20),
}

# Per-app TAF threshold grids, chosen inside each workload's RSD
# activation range (outside it every threshold saturates the duty cycle
# and the measured speedups tie -- nothing left to rank).
_THRESHOLDS = {
    "blackscholes": (0.005, 0.05, 0.2, 1.0),
    "binomial_options": (0.0002, 0.001, 0.005, 0.02),
    "kmeans": (0.05, 0.2, 0.5, 1.0),
    "lavamd": (0.05, 0.2, 0.5, 1.0),
    "minife_cg": (0.05, 0.2, 0.5, 1.0),
}


# --------------------------------------------------------------------------
# per-app cost models (region costs TRACED, not hand-counted)
# --------------------------------------------------------------------------

def blackscholes_model(n_elements: int = 128, steps: int = 32,
                       volatility: float = 1.0,
                       machine=None) -> AppCostModel:
    """One TAF/iACT decision per sequence step over the bs_price region.
    `volatility` shapes the data, not the program -- it is accepted so the
    builder mirrors `make_app`'s workload signature. Option prices cross
    zero (deep out-of-the-money calls), so the QoI's relative error is
    heavy-tailed: `qoi_condition` floors the residual accordingly."""
    from apps import blackscholes
    region = trace_cost(blackscholes.bs_price,
                        jnp.ones((n_elements, 5), jnp.float32))
    site = Site(region=region, invocations=float(steps), in_dim=5,
                qoi_condition=0.05)
    return AppCostModel(
        name="blackscholes", total=region * float(steps),
        sites={Technique.TAF: site, Technique.IACT: site},
        machine=get_machine(machine), dispatches=1.0)


def binomial_options_model(n_elements: int = 32, steps: int = 16,
                           tree_steps: int = 64,
                           machine=None) -> AppCostModel:
    from apps import binomial_options
    region = trace_cost(
        lambda x: binomial_options.binomial_price(x, tree_steps),
        jnp.ones((n_elements, 5), jnp.float32))
    site = Site(region=region, invocations=float(steps), in_dim=5)
    return AppCostModel(
        name="binomial_options", total=region * float(steps),
        sites={Technique.TAF: site, Technique.IACT: site},
        machine=get_machine(machine), dispatches=1.0)


def kmeans_model(n: int = 256, d: int = 4, k: int = 4,
                 max_iters: int = 10, machine=None) -> AppCostModel:
    """The assignment kernel is the approximable region, once per
    Lloyd iteration."""
    from apps import kmeans
    region = trace_cost(kmeans._assign_exact,
                        jnp.ones((n, d), jnp.float32),
                        jnp.ones((k, d), jnp.float32))
    site = Site(region=region, invocations=float(max_iters), in_dim=d)
    return AppCostModel(
        name="kmeans", total=region * float(max_iters),
        sites={Technique.TAF: site, Technique.IACT: site},
        machine=get_machine(machine), dispatches=float(max_iters))


def lavamd_model(nx: int = 3, seed: int = 0, machine=None) -> AppCostModel:
    """27 neighbor-box force invocations; one decision each."""
    from apps import lavamd
    region_fn, xs, _nb = lavamd._region_setup(nx, seed)
    region = trace_cost(region_fn, jnp.asarray(xs[0]))
    site = Site(region=region, invocations=27.0,
                in_dim=int(np.asarray(xs).shape[-1]))
    return AppCostModel(
        name="lavamd", total=region * 27.0,
        sites={Technique.TAF: site, Technique.IACT: site},
        machine=get_machine(machine), dispatches=1.0)


def minife_cg_model(n: int = 32, iters: int = 20,
                    machine=None) -> AppCostModel:
    """The stencil matvec dominates each CG iteration. Errors injected in
    one iteration feed every later one through the residual recurrence
    (the paper's MiniFE pathology), so the site amplification is the
    iteration count -- linear accumulation, not a random walk, because CG
    updates are NOT independently signed."""
    from apps import minife_cg
    region = trace_cost(minife_cg.poisson_matvec,
                        jnp.ones((n, n), jnp.float32))
    site = Site(region=region, invocations=float(iters), in_dim=n,
                n_iters=iters, amplification=float(iters))
    return AppCostModel(
        name="minife_cg", total=region * float(iters),
        sites={Technique.TAF: site, Technique.PERFORATION: site},
        machine=get_machine(machine), dispatches=float(iters))


def ffn_model(seq: int = 128, d: int = 32, d_h: int = 64,
              machine=None) -> AppCostModel:
    """Three sites, one per technique, mirroring `approx_ffn`'s
    `_flop_fraction` accounting: TAF gates the projection row blocks,
    iACT memoizes the FFN row blocks, perforation drops attention KV
    blocks."""
    from apps import approx_ffn
    proj, attn, ffn = approx_ffn._flops(seq, d, d_h)
    total = CostVector(proj + attn + ffn,
                       4.0 * (seq * d * 4 + d * d + 2 * d * d_h))
    n_rows = float(seq // approx_ffn._BLOCK_M)
    n_kv = seq // approx_ffn._BLOCK_ATTN
    sites = {
        Technique.TAF: Site(region=CostVector(proj / n_rows,
                                              4.0 * seq * d / n_rows),
                            invocations=n_rows, in_dim=d),
        Technique.IACT: Site(region=CostVector(ffn / n_rows,
                                               4.0 * seq * d / n_rows),
                             invocations=n_rows, in_dim=d),
        Technique.PERFORATION: Site(region=CostVector(attn, 4.0 * seq * d),
                                    invocations=1.0, n_iters=n_kv),
    }
    return AppCostModel(name="approx_ffn", total=total, sites=sites,
                        machine=get_machine(machine), dispatches=3.0)


MODEL_BUILDERS = {
    "blackscholes": blackscholes_model,
    "binomial_options": binomial_options_model,
    "kmeans": kmeans_model,
    "lavamd": lavamd_model,
    "minife_cg": minife_cg_model,
}


def _make_app(name: str):
    import importlib
    mod = importlib.import_module(f"apps.{name}")
    return mod.make_app(**_WORKLOADS[name])


def spearman(xs, ys) -> float:
    """Spearman rank correlation (average ranks for ties; no scipy)."""
    def _ranks(v):
        v = np.asarray(v, np.float64)
        order = np.argsort(v, kind="mergesort")
        ranks = np.empty_like(v)
        ranks[order] = np.arange(len(v), dtype=np.float64)
        for val in np.unique(v):
            m = v == val
            ranks[m] = ranks[m].mean()
        return ranks
    rx, ry = _ranks(xs), _ranks(ys)
    rx -= rx.mean()
    ry -= ry.mean()
    denom = math.sqrt(float((rx * rx).sum()) * float((ry * ry).sum()))
    if denom == 0.0:
        return 1.0 if np.allclose(rx, ry) else 0.0
    return float((rx * ry).sum() / denom)


def _validation_grid(name: str):
    """The per-app grid: one structural TAF group over four thresholds
    (every example app accepts TAF; rank correlation is within-technique,
    matching the predictor's contract)."""
    return taf_grid(h_sizes=(2,), p_sizes=(4,),
                    thresholds=_THRESHOLDS[name],
                    levels=(Level.ELEMENT,))


def main(report, jobs: int = 1, db_path: Optional[str] = None,
         artifacts_dir: Optional[str] = None) -> None:
    doc: Dict = {"apps": {}, "front_tolerance": FRONT_TOLERANCE}

    for name, builder in MODEL_BUILDERS.items():
        app = _make_app(name)
        model = builder(**{**_WORKLOADS[name]})
        grid = _validation_grid(name)
        kept, dropped = model.select(grid)
        recs = sweep(app, kept, repeats=1, db_path=db_path,
                     jobs=max(jobs, 1))
        preds = [model.predict(_spec_of(r)) for r in recs]
        rho = spearman([p.speedup for p in preds],
                       [r.modeled_speedup for r in recs])
        bound_ok = None
        if app.error_metric == "mape" and name != "minife_cg":
            bound_ok = all(p.error_bound >= r.error
                           for p, r in zip(preds, recs))
        doc["apps"][name] = {
            "n_grid": len(grid), "kept": len(kept), "dropped": len(dropped),
            "spearman": rho, "bound_holds": bound_ok,
        }
        report(f"costmodel_{name}", f"{len(recs)}",
               f"spearman={rho:.3f},kept={len(kept)}/{len(grid)},"
               f"bound_holds={bound_ok}")

    # -- ffn: predicted-band front recovery vs the committed full front --
    from apps import approx_ffn
    from benchmarks import approx_ffn_sweep

    grid = approx_ffn_sweep._grid()
    model = ffn_model()
    budget = len(grid) // 5
    kept, dropped = model.select(grid)
    band = model.select_band(grid, budget=budget)
    app = approx_ffn.make_app(substrate="pallas")
    recs = sweep(app, band, repeats=1, db_path=db_path, jobs=max(jobs, 1))
    fs = pareto.front_summary(recs, use_modeled=True)

    base_path = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             "baselines", "BENCH_ffn.json")
    with open(base_path) as f:
        base_hv = json.load(f)["front"]["hypervolume"]
    ratio = fs["hypervolume"] / base_hv if base_hv else 0.0

    rho_ffn = spearman(
        [model.predict(_spec_of(r)).speedup for r in recs],
        [r.modeled_speedup for r in recs])
    doc["ffn"] = {
        "n_grid": len(grid), "kept": len(kept), "dropped": len(dropped),
        "band_budget": budget, "band_measured": len(recs),
        "spearman": rho_ffn,
        "front_recovery": {"hv_band": fs["hypervolume"],
                           "hv_baseline": base_hv, "ratio": ratio},
        "recovered": bool(ratio >= FRONT_TOLERANCE),
    }
    report("costmodel_ffn", f"{len(recs)}",
           f"band={len(recs)}/{len(grid)},hv_ratio={ratio:.3f},"
           f"spearman={rho_ffn:.3f},recovered={ratio >= FRONT_TOLERANCE}")

    if artifacts_dir:
        os.makedirs(artifacts_dir, exist_ok=True)
        path = os.path.join(artifacts_dir, "BENCH_costmodel.json")
        from repro.obs import metrics as obs_metrics
        with open(path, "w") as f:
            json.dump(obs_metrics.stamp(doc), f, indent=1)
        report("costmodel_json", "0", path)


def _spec_of(rec):
    from repro.core.harness import spec_from_dict
    return spec_from_dict(rec.spec)
