"""Kernel-substrate tests: host-vs-pallas parity, traced quality knobs, and
the no-recompile-per-sweep-point regression.

The kernels' quality knobs (TAF rsd threshold, iACT distance threshold,
perforation fraction) are TRACED operands: a threshold grid must compile
each kernel at most once per structural group (block shape + state-shaping
params), and the kernel results in interpret mode must match the ref.py
oracles -- which double as the approx_ffn app's "host" substrate -- bit for
bit on the approx masks.
"""
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import substrate
from repro.core.approx import ApproxRegion
from repro.core.harness import iact_grid, run_specs, sweep, taf_grid
from repro.core.types import (ApproxSpec, IACTParams, Level, PerforationKind,
                              PerforationParams, TAFParams, Technique)
from repro.kernels import ops, ref
from repro.kernels.iact_memo import iact_rowfn as _iact_jit
from repro.kernels.taf_matmul import taf_matmul as _taf_jit
from repro.kernels.perforated_attention import (perforated_attention as
                                                _attn_jit)
from repro.kernels.perforated_matmul import perforated_matmul as _pmm_jit

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "examples"))

from apps import approx_ffn  # noqa: E402


def _rowblock_x(rng, m, k, block=16, noise=0.02):
    base = rng.randn(1, k).astype(np.float32)
    return np.tile(base, (m, 1)) + noise * rng.randn(m, k).astype(np.float32)


# --------------------------------------------------------------- substrate


class TestSubstrateSelection:
    def test_resolve_and_use(self):
        assert substrate.resolve(None) == substrate.get_default()
        assert substrate.resolve("pallas") == "pallas"
        with substrate.use("pallas"):
            assert substrate.get_default() == "pallas"
            with substrate.use(None):  # no-op scope
                assert substrate.get_default() == "pallas"
        assert substrate.get_default() == "host"

    def test_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown substrate"):
            substrate.resolve("cuda")

    def test_use_restores_on_error(self):
        with pytest.raises(RuntimeError):
            with substrate.use("pallas"):
                raise RuntimeError("boom")
        assert substrate.get_default() == "host"

    def test_dispatch(self):
        assert substrate.dispatch(Technique.TAF) is substrate.taf_matmul_region
        with pytest.raises(ValueError, match="no pallas region"):
            substrate.dispatch(Technique.NONE)


# --------------------------------------------- traced knobs: recompile-free


class TestTracedKnobsNoRecompile:
    def test_taf_threshold_grid_single_trace(self):
        """A 16-point rsd-threshold grid costs at most ONE kernel compile
        per structural group (the acceptance-criterion regression)."""
        rng = np.random.RandomState(0)
        x = jnp.asarray(_rowblock_x(rng, 128, 32, noise=0.05))
        w = jnp.asarray(rng.randn(32, 32).astype(np.float32))
        thresholds = np.geomspace(1e-5, 4.0, 16)
        # group 1: history_size=3
        ops.taf_matmul(x, w, block_m=32, block_n=32, rsd_threshold=0.5)
        base = _taf_jit._cache_size()
        masks = []
        for t in thresholds:
            _, m = ops.taf_matmul(x, w, block_m=32, block_n=32,
                                  rsd_threshold=float(t))
            masks.append(np.asarray(m))
        assert _taf_jit._cache_size() - base == 0
        assert not np.array_equal(masks[0], masks[-1])  # knob is live
        # a different structural group costs exactly one more trace
        ops.taf_matmul(x, w, block_m=32, block_n=32, history_size=2,
                       rsd_threshold=0.5)
        grew = _taf_jit._cache_size() - base
        assert grew == 1

    def test_iact_threshold_grid_single_trace(self):
        rng = np.random.RandomState(1)
        x = jnp.asarray(np.repeat(rng.randn(4, 16), 32, 0).astype(np.float32))
        w1 = jnp.asarray(rng.randn(16, 32).astype(np.float32) * 0.1)
        w2 = jnp.asarray(rng.randn(32, 8).astype(np.float32) * 0.1)
        ops.iact_rowfn(x, w1, w2, block_rows=32, threshold=0.5)
        base = _iact_jit._cache_size()
        for t in np.linspace(0.01, 5.0, 16):
            ops.iact_rowfn(x, w1, w2, block_rows=32, threshold=float(t))
        assert _iact_jit._cache_size() - base == 0

    def test_attention_fraction_grid_single_trace(self):
        """The natural sweep pattern -- a FRESH PerforationParams per grid
        point -- must still hit one compile: masked mode normalizes the
        dead `fraction` field out of the static jit key."""
        rng = np.random.RandomState(2)
        q = jnp.asarray(rng.randn(1, 2, 64, 16).astype(np.float32))
        k = jnp.asarray(rng.randn(1, 2, 64, 16).astype(np.float32))
        ops.perforated_attention(
            q, k, k, block_q=32, block_kv=32,
            perfo=PerforationParams(kind=PerforationKind.INI, fraction=0.5),
            fraction=0.25)
        base = _attn_jit._cache_size()
        for fr in np.linspace(0.0, 0.9, 16):
            p = PerforationParams(kind=PerforationKind.INI,
                                  fraction=float(fr) if fr else 0.1)
            ops.perforated_attention(q, k, k, block_q=32, block_kv=32,
                                     perfo=p, fraction=float(fr))
        assert _attn_jit._cache_size() - base == 0

    def test_vmap_stacks_thresholds_through_kernel(self):
        """The batched-runner protocol's kernel leg: stacked thresholds
        vmap through one compiled kernel, lane-for-lane equal to serial."""
        rng = np.random.RandomState(3)
        x = jnp.asarray(_rowblock_x(rng, 64, 16))
        w = jnp.asarray(rng.randn(16, 16).astype(np.float32))
        ths = jnp.asarray([0.05, 0.5, 2.0], jnp.float32)
        ys, masks = jax.jit(jax.vmap(
            lambda th: _taf_jit(x, w, block_m=16, block_n=16,
                                rsd_threshold=th, interpret=True)))(ths)
        for i, t in enumerate(np.asarray(ths)):
            y1, m1 = ops.taf_matmul(x, w, block_m=16, block_n=16,
                                    rsd_threshold=float(t))
            np.testing.assert_allclose(np.asarray(ys[i]), np.asarray(y1),
                                       atol=1e-5)
            assert np.array_equal(np.asarray(masks[i]), np.asarray(m1))


# ------------------------------------------------ masked attention parity


class TestMaskedAttention:
    @pytest.mark.parametrize("kind,fr", [
        (PerforationKind.INI, 0.25), (PerforationKind.INI, 0.5),
        (PerforationKind.FINI, 0.25), (PerforationKind.RANDOM, 0.5),
    ])
    def test_traced_fraction_matches_structural(self, kind, fr):
        rng = np.random.RandomState(4)
        q = jnp.asarray(rng.randn(1, 2, 64, 16).astype(np.float32))
        k = jnp.asarray(rng.randn(1, 2, 128, 16).astype(np.float32))
        v = jnp.asarray(rng.randn(1, 2, 128, 16).astype(np.float32))
        p = PerforationParams(kind=kind, fraction=fr)
        o_struct = ops.perforated_attention(q, k, v, block_q=32, block_kv=32,
                                            perfo=p)
        o_masked = ops.perforated_attention(q, k, v, block_q=32, block_kv=32,
                                            perfo=p, fraction=fr)
        np.testing.assert_allclose(np.asarray(o_masked),
                                   np.asarray(o_struct), atol=1e-5)

    def test_fraction_hook_needs_fraction_kind(self):
        rng = np.random.RandomState(5)
        q = jnp.asarray(rng.randn(1, 1, 32, 16).astype(np.float32))
        p = PerforationParams(kind=PerforationKind.SMALL, skip=2)
        with pytest.raises(ValueError, match="traced hook"):
            ops.perforated_attention(q, q, q, block_q=32, block_kv=32,
                                     perfo=p, fraction=0.5)


# -------------------------------------------------- masked matmul parity


class TestMaskedMatmul:
    @pytest.mark.parametrize("kind,fr", [
        (PerforationKind.INI, 0.25), (PerforationKind.INI, 0.5),
        (PerforationKind.FINI, 0.25), (PerforationKind.RANDOM, 0.5),
    ])
    def test_traced_fraction_matches_structural(self, kind, fr):
        rng = np.random.RandomState(7)
        x = jnp.asarray(rng.randn(64, 256).astype(np.float32))
        w = jnp.asarray(rng.randn(256, 64).astype(np.float32))
        p = PerforationParams(kind=kind, fraction=fr)
        y_struct = ops.perforated_matmul(x, w, block_m=32, block_n=32,
                                         block_k=32, perfo=p)
        y_masked = ops.perforated_matmul(x, w, block_m=32, block_n=32,
                                         block_k=32, perfo=p, fraction=fr)
        np.testing.assert_allclose(np.asarray(y_masked),
                                   np.asarray(y_struct), atol=1e-3)

    def test_traced_fraction_matches_ref_with_rescale(self):
        rng = np.random.RandomState(8)
        x = jnp.asarray(rng.randn(32, 128).astype(np.float32))
        w = jnp.asarray(rng.randn(128, 32).astype(np.float32))
        for fr in (0.0, 0.25, 0.5, 0.75):
            p = PerforationParams(kind=PerforationKind.INI,
                                  fraction=fr if fr else 0.1)
            y = ops.perforated_matmul(x, w, block_m=32, block_n=32,
                                      block_k=32, perfo=p, rescale=True,
                                      fraction=fr)
            pr = PerforationParams(kind=PerforationKind.INI, fraction=fr)
            yr = ref.perforated_matmul_ref(x, w, block_k=32, perfo=pr,
                                           rescale=True)
            np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                       rtol=1e-4, atol=1e-3)

    def test_matmul_fraction_grid_single_trace(self):
        """A fresh PerforationParams per grid point must still hit one
        compile in masked mode: the traced fraction operand carries the
        knob and the dead perfo.fraction field is normalized out of the
        static jit key."""
        rng = np.random.RandomState(9)
        x = jnp.asarray(rng.randn(32, 128).astype(np.float32))
        w = jnp.asarray(rng.randn(128, 32).astype(np.float32))
        ops.perforated_matmul(
            x, w, block_m=32, block_n=32, block_k=32,
            perfo=PerforationParams(kind=PerforationKind.INI, fraction=0.5),
            fraction=0.25)
        base = _pmm_jit._cache_size()
        for fr in np.linspace(0.0, 0.9, 16):
            p = PerforationParams(kind=PerforationKind.INI,
                                  fraction=float(fr) if fr else 0.1)
            ops.perforated_matmul(x, w, block_m=32, block_n=32, block_k=32,
                                  perfo=p, fraction=float(fr))
        assert _pmm_jit._cache_size() - base == 0

    def test_fraction_hook_needs_fraction_kind(self):
        rng = np.random.RandomState(10)
        x = jnp.asarray(rng.randn(32, 64).astype(np.float32))
        w = jnp.asarray(rng.randn(64, 32).astype(np.float32))
        p = PerforationParams(kind=PerforationKind.SMALL, skip=2)
        with pytest.raises(ValueError, match="traced hook"):
            ops.perforated_matmul(x, w, block_m=32, block_n=32, block_k=32,
                                  perfo=p, fraction=0.5)


# -------------------------------------------- ApproxRegion substrate plumb


class TestApproxRegionSubstrate:
    def _region(self, **kw):
        rng = np.random.RandomState(6)
        x = jnp.asarray(_rowblock_x(rng, 64, 16))
        w = jnp.asarray(rng.randn(16, 16).astype(np.float32))
        spec = ApproxSpec(Technique.TAF, Level.BLOCK,
                          taf=TAFParams(3, 4, 0.5))

        def pallas_impl(_x, rsd_threshold=None, threshold=None):
            return substrate.taf_matmul_region(
                x, w, spec, block_m=16, block_n=16,
                rsd_threshold=rsd_threshold)

        region = ApproxRegion(spec, lambda: x @ w, n_elements=64,
                              pallas_impl=pallas_impl, **kw)
        return region, x, w

    def test_pinned_pallas_substrate(self):
        region, x, w = self._region(substrate="pallas")
        out, state, mask = region.step(())
        yr, mr = ref.taf_matmul_ref(x, w, block_m=16, block_n=16,
                                    history_size=3, prediction_size=4,
                                    rsd_threshold=0.5)
        np.testing.assert_allclose(np.asarray(out), np.asarray(yr), atol=1e-3)
        assert np.array_equal(np.asarray(mask), np.asarray(mr))

    def test_ambient_substrate_flips_region(self):
        region, x, w = self._region()  # substrate=None -> ambient
        with substrate.use("pallas"):
            out, _, mask = region.step(())
        assert np.asarray(mask).ndim == 2  # kernel's (num_i, num_j) mask
        # run(): one kernel call is the sequence; hook overrides the spec
        with substrate.use("pallas"):
            ys, frac = region.run(x, rsd_threshold=0.0)
        assert float(frac) == 0.0  # zero threshold never approximates

    def test_pallas_without_impl_raises(self):
        spec = ApproxSpec(Technique.TAF, Level.BLOCK)
        region = ApproxRegion(spec, lambda: 0, n_elements=4,
                              substrate="pallas")
        with pytest.raises(ValueError, match="needs a pallas_impl"):
            region.step(())

    def test_exact_region_runs_on_pallas_substrate(self):
        """Technique.NONE has no kernel side: an exact-baseline region must
        run its fn on the pallas substrate without a pallas_impl."""
        xs = jnp.ones((4, 2))
        region = ApproxRegion(ApproxSpec(), lambda x: x * 2.0, n_elements=4,
                              substrate="pallas")
        out, _, mask = region.step((), xs)
        np.testing.assert_allclose(np.asarray(out), 2.0)
        assert not np.asarray(mask).any()
        with substrate.use("pallas"):
            ys, frac = ApproxRegion(ApproxSpec(), lambda x: x + 1.0,
                                    n_elements=2).run(jnp.zeros((3, 2)))
        assert float(frac) == 0.0


# ------------------------------------------------- app host/pallas parity


def _parity_grid():
    taf = taf_grid(h_sizes=(3,), p_sizes=(2,),
                   thresholds=(0.02, 0.1, 1.0), levels=(Level.BLOCK,))
    iact = iact_grid(t_sizes=(4,), thresholds=(0.05, 0.5, 20.0),
                     tables_per_block=(1,), levels=(Level.BLOCK,))
    perfo = [ApproxSpec(Technique.PERFORATION, Level.BLOCK,
                        perforation=PerforationParams(kind=k, fraction=f))
             for k, f in ((PerforationKind.INI, 0.25),
                          (PerforationKind.FINI, 0.5))]
    return taf + iact + perfo


class TestApproxFFNParity:
    def test_host_vs_pallas_masks_and_qoi(self):
        """The tentpole parity contract: over TAF/iACT/perforation grids the
        pallas substrate (interpret mode on CPU) must reproduce the host
        substrate's approx masks exactly and its QoI within fp tolerance."""
        grid = _parity_grid()
        papp = approx_ffn.make_app(substrate="pallas")
        happ = approx_ffn.make_app(substrate="host")
        precs = sweep(papp, grid, repeats=1)
        hrecs = sweep(happ, grid, repeats=1)
        assert papp.workload_hash != happ.workload_hash  # distinct DB keys
        for p, h in zip(precs, hrecs):
            assert p.extra["approx_mask"] == h.extra["approx_mask"], p.spec
            assert abs(p.error - h.error) < 1e-4, p.spec
            assert abs(p.approx_fraction - h.approx_fraction) < 1e-6

    def test_thresholds_discriminate(self):
        """The sweep must not be flat: different thresholds produce
        different approximation fractions somewhere in the grid."""
        papp = approx_ffn.make_app(substrate="pallas")
        recs = run_specs(papp, _parity_grid(), repeats=1)
        fracs = {round(r.approx_fraction, 6) for r in recs}
        assert len(fracs) > 2

    def test_batched_runner_matches_serial(self):
        grid = _parity_grid()
        papp = approx_ffn.make_app(substrate="pallas")
        serial = run_specs(papp, grid, repeats=1, jobs=1)
        batched = run_specs(papp, grid, repeats=1, jobs=len(grid))
        for s, b in zip(serial, batched):
            np.testing.assert_allclose(np.asarray(b.qoi), np.asarray(s.qoi),
                                       rtol=1e-5, atol=1e-6)
            assert s.extra["approx_mask"] == b.extra["approx_mask"]
            assert abs(s.approx_fraction - b.approx_fraction) < 1e-6
            assert abs(s.flop_fraction - b.flop_fraction) < 1e-6

    def test_app_level_one_compile_per_structural_group(self):
        """Sweeping a 16-point threshold grid through the pallas-substrate
        app compiles each kernel-backed pipeline at most once per
        structural group (2 groups here), serial or batched."""
        papp = approx_ffn.make_app(substrate="pallas")
        grid = taf_grid(h_sizes=(2, 3), p_sizes=(2,),
                        thresholds=(0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0,
                                    2.0),
                        levels=(Level.BLOCK,))
        assert len(grid) == 16
        run_specs(papp, grid[:1], repeats=1)  # pin workload arrays
        runners = {}
        from repro.core import batching
        for s in grid:
            key = batching.static_key(s)
            runners[key] = approx_ffn._pallas_knob_runner(
                key, *[papp.workload[k]
                       for k in ("seq", "d", "d_h", "heads", "seed")])
        assert len(runners) == 2
        before = {k: fn._cache_size() for k, fn in runners.items()}
        run_specs(papp, grid, repeats=1)  # serial sweep
        after = {k: fn._cache_size() for k, fn in runners.items()}
        for k in runners:
            assert after[k] - before[k] <= 1, (k, before[k], after[k])
        # and sweeping again (any order, any thresholds) adds nothing
        run_specs(papp, grid[::-1], repeats=1)
        assert {k: fn._cache_size() for k, fn in runners.items()} == after


# -------------------------------------------------- harness substrate kwarg


class TestHarnessSubstratePlumbing:
    def test_run_specs_scopes_ambient_substrate(self):
        seen = []

        def run(spec):
            seen.append(substrate.get_default())
            return AppResultStub()

        class AppResultStub:
            qoi = np.zeros((2,))
            wall_time_s = 1.0
            approx_fraction = 0.0
            flop_fraction = 1.0
            extra = {}

        from repro.core.harness import ApproxApp
        app = ApproxApp("probe", run)
        run_specs(app, [ApproxSpec()], repeats=1, substrate="pallas")
        assert seen == ["pallas"]
        assert substrate.get_default() == "host"

    def test_sweep_and_refine_accept_substrate(self):
        import inspect
        from repro.core.autotune import random_search, successive_halving
        from repro.core.pareto import refine
        for fn in (sweep, run_specs, refine, random_search,
                   successive_halving):
            assert "substrate" in inspect.signature(fn).parameters, fn
