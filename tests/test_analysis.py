"""approxlint test suite (docs/analysis.md): the findings/allowlist
plumbing, each rule against KNOWN-BAD fixtures (a baked constant and a
static argument for A001, taint into control flow and gather indices for
A003, dominated/stale/duplicated ladders for A004, uncommitted serve-step
leaves for A005), the two opt-in lint hooks, the CLI's exit-code
contract, and the meta-test that the current tree itself lints clean."""
import json
import os
import subprocess
import sys

import pytest

import jax
import jax.numpy as jnp

from repro.analysis.findings import (AllowEntry, Allowlist, Finding, Report,
                                     Severity, default_allowlist_path)
from repro.analysis.taint import find_taint_sinks
from repro.analysis.trace import jaxpr_fingerprint, probe_knob
from repro.analysis import rules as rules_mod
from repro.analysis.lint import run_lint


# ------------------------------------------------------------- findings

def _f(rule="A001", sev=Severity.ERROR, subject="kernels.toy.knob"):
    return Finding(rule, sev, subject, "msg", {})


def test_severity_parse_and_order():
    assert Severity.parse("warning") is Severity.WARNING
    assert Severity.INFO < Severity.WARNING < Severity.ERROR
    with pytest.raises(ValueError):
        Severity.parse("fatal")


def test_allowlist_matches_by_rule_and_fnmatch():
    allow = Allowlist([AllowEntry("A001", "kernels.*", reason="r")])
    assert allow.match(_f()) is not None
    assert allow.match(_f(rule="A002")) is None          # rule must match
    assert allow.match(_f(subject="regions.toy")) is None


def test_allowlist_load_rejects_empty_reason(tmp_path):
    p = tmp_path / ".approxlint.json"
    p.write_text(json.dumps(
        {"version": 1,
         "allow": [{"rule": "A001", "subject": "x", "reason": ""}]}))
    with pytest.raises(ValueError, match="reason"):
        Allowlist.load(str(p))


def test_default_allowlist_path_walks_up(tmp_path):
    (tmp_path / ".approxlint.json").write_text('{"version":1,"allow":[]}')
    nested = tmp_path / "a" / "b"
    nested.mkdir(parents=True)
    assert default_allowlist_path(str(nested)) == str(
        tmp_path / ".approxlint.json")


def test_report_routes_allowlisted_and_fails_on_rule_crash():
    rep = Report()
    allow = Allowlist([AllowEntry("A001", "kernels.*", reason="known")])
    rep.extend([_f(), _f(rule="A002", subject="bench.x")], allow)
    assert [f.rule for f in rep.findings] == ["A002"]
    assert len(rep.allowlisted) == 1
    assert rep.failed(Severity.ERROR)
    clean = Report()
    assert not clean.failed()
    clean.errors.append("A003: crashed")
    assert clean.failed()         # a crashed rule always fails the lint


# ---------------------------------------------------- A001: knob tracing

def test_probe_knob_traced_clean():
    x = jnp.arange(8.0)
    res = probe_knob(lambda th: jnp.where(jnp.abs(x) < th, 0.0, x))
    assert res.verdict == "traced" and res.clean


def test_probe_knob_static_argument_is_a_finding():
    x = jnp.arange(8.0)
    f = jax.jit(lambda x, th: jnp.where(jnp.abs(x) < th, 0.0, x),
                static_argnames=("th",))
    res = probe_knob(lambda th: f(x, th))
    assert res.verdict == "static"
    assert res.error


def test_probe_knob_python_control_flow_is_a_finding():
    x = jnp.arange(8.0)

    def branchy(th):
        return x * 2 if th > 0.5 else x      # concretizes the tracer
    res = probe_knob(branchy)
    assert res.verdict == "static"


def test_probe_knob_baked_constant_is_a_finding():
    x = jnp.arange(8.0)

    def build(v):          # captures the VALUE before the trace: baked
        return lambda th: jnp.where(jnp.abs(x) < float(v), 0.0, x) + th * 0
    fingerprints = [
        jaxpr_fingerprint(jax.make_jaxpr(build(v))(jnp.float32(v)))
        for v in (0.25, 0.75)]
    assert fingerprints[0] != fingerprints[1]

    holder = {"v": 0.0}

    def leaky(th):           # ignores th; bakes the swept value instead
        holder["v"] += 0.5
        return jnp.where(jnp.abs(x) < holder["v"], 0.0, x)
    assert probe_knob(leaky).verdict == "baked"


def test_fingerprint_normalizes_hex_addresses():
    a = "custom_call[callback=<function f at 0x7f01>]"
    b = "custom_call[callback=<function f at 0x7f02>]"
    import re
    from repro.analysis.trace import _HEX_ADDR
    assert _HEX_ADDR.sub("0x", a) == _HEX_ADDR.sub("0x", b)


def test_check_spec_grouping_clean_and_leaky(monkeypatch):
    from repro.core import batching
    from repro.core.harness import taf_grid
    from repro.core.types import Level
    grid = taf_grid(h_sizes=(3,), p_sizes=(2,), thresholds=(0.02, 0.1),
                    levels=(Level.BLOCK,))
    assert rules_mod.check_spec_grouping(grid) == []

    orig = batching.static_key

    def leaky(spec):         # the knob value leaks into the static key
        k = orig(spec)
        return k + (spec.taf.rsd_threshold,) if k and spec.taf else k
    monkeypatch.setattr(batching, "static_key", leaky)
    findings = rules_mod.check_spec_grouping(grid, subject_prefix="t")
    assert [f.rule for f in findings] == ["A001"]
    assert "static_key" in findings[0].subject


# -------------------------------------------------------- A003: taint

def test_taint_cond_predicate_sink():
    def step(memo, x):
        return jax.lax.cond(jnp.sum(memo) > 0.0,
                            lambda v: v * 2.0, lambda v: v, x)
    closed = jax.make_jaxpr(step)(jnp.ones(4), jnp.ones(4))
    sinks = find_taint_sinks(closed, tainted_inputs=[0])
    assert any(s.kind == "branch predicate" for s in sinks)
    assert find_taint_sinks(closed, tainted_inputs=[1]) == []


def test_taint_gather_indices_sink():
    def step(memo, x):
        idx = jnp.argmax(memo).astype(jnp.int32)
        return x[idx]
    closed = jax.make_jaxpr(step)(jnp.ones(4), jnp.ones(4))
    sinks = find_taint_sinks(closed, tainted_inputs=[0])
    assert any("indices" in s.kind for s in sinks)


def test_taint_while_predicate_via_carry_fixpoint():
    def step(memo, x):
        def cond(c):
            i, acc = c
            return acc < 10.0          # acc is memo-derived
        def body(c):
            i, acc = c
            return i + 1, acc + 1.0
        return jax.lax.while_loop(cond, body, (0, jnp.sum(memo)))
    closed = jax.make_jaxpr(step)(jnp.ones(4), jnp.ones(4))
    sinks = find_taint_sinks(closed, tainted_inputs=[0])
    assert any(s.kind == "while predicate" for s in sinks)


def test_taint_pure_arithmetic_is_clean():
    def step(memo, x):
        return x * jnp.tanh(memo) + jnp.sum(memo)
    closed = jax.make_jaxpr(step)(jnp.ones(4), jnp.ones(4))
    assert find_taint_sinks(closed, tainted_inputs=[0]) == []


def test_taint_walks_into_pjit():
    inner = jax.jit(lambda m, v: jax.lax.cond(
        m[0] > 0, lambda y: y, lambda y: -y, v))

    def step(memo, x):
        return inner(memo, x)
    closed = jax.make_jaxpr(step)(jnp.ones(4), jnp.ones(4))
    sinks = find_taint_sinks(closed, tainted_inputs=[0])
    assert any(s.kind == "branch predicate" for s in sinks)
    assert all("pjit" in s.path for s in sinks)


# ------------------------------------------------------ A004: ladders

def _rung(thresh, error, speedup, h=2, p=4, **over):
    from repro.core.harness import spec_hash
    spec = {"technique": "taf", "level": "block", "hSize": h, "pSize": p,
            "thresh": thresh}
    d = {"spec": spec, "error": error, "speedup": speedup,
         "modeled_speedup": speedup, "spec_hash": spec_hash(spec)}
    d.update(over)
    return d


def _precise_rung():
    from repro.core.harness import spec_hash
    spec = {"technique": "none"}
    return {"spec": spec, "error": 0.0, "speedup": 1.0,
            "modeled_speedup": 1.0, "spec_hash": spec_hash(spec)}


def _doc(entries, **over):
    d = {"version": 1, "app": "toy", "metric": "mape",
         "use_modeled": False, "entries": entries}
    d.update(over)
    return d


def _a004(doc, **kw):
    return rules_mod.check_policy_document(doc, subject="p", **kw)


def test_a004_clean_ladder():
    doc = _doc([_precise_rung(), _rung(0.05, 0.01, 1.5),
                _rung(0.2, 0.04, 2.2)])
    assert _a004(doc) == []


def test_a004_dominated_rung():
    doc = _doc([_precise_rung(), _rung(0.05, 0.01, 2.0),
                _rung(0.2, 0.04, 1.8)])    # more error, LESS speedup
    msgs = [f.message for f in _a004(doc)]
    assert any("dominated" in m for m in msgs)


def test_a004_non_ascending_error():
    doc = _doc([_precise_rung(), _rung(0.05, 0.04, 1.5),
                _rung(0.2, 0.04, 2.2)])    # equal error on a later rung
    msgs = [f.message for f in _a004(doc)]
    assert any("ascending" in m for m in msgs)


def test_a004_missing_precise_anchor():
    doc = _doc([_rung(0.05, 0.01, 1.5)])
    assert any("#rung0" in f.subject for f in _a004(doc))


def test_a004_sub_1x_rung_and_duplicate_spec():
    doc = _doc([_precise_rung(), _rung(0.05, 0.01, 0.9)])
    assert any("<= 1x" in f.message for f in _a004(doc))
    doc = _doc([_precise_rung(), _rung(0.05, 0.01, 1.5),
                _rung(0.05, 0.04, 2.0)])   # same spec dict twice
    assert any("duplicate spec" in f.message for f in _a004(doc))


def test_a004_stale_spec_hash():
    bad = _rung(0.05, 0.01, 1.5)
    bad["spec_hash"] = "deadbeef"
    msgs = [f.message for f in _a004(_doc([_precise_rung(), bad]))]
    assert any("spec_hash" in m for m in msgs)


def test_a004_model_taf_mismatch_and_structural_split():
    doc = _doc([_precise_rung(), _rung(0.05, 0.01, 1.5, h=2, p=4)])
    assert _a004(doc, model_taf=(2, 4)) == []
    assert any("target model" in f.message
               for f in _a004(doc, model_taf=(8, 2)))
    split = _doc([_precise_rung(), _rung(0.05, 0.01, 1.5, h=2, p=4),
                  _rung(0.2, 0.04, 2.2, h=8, p=2)])
    assert any("structural" in f.message for f in _a004(split))


def test_a004_raw_json_not_healed_load(tmp_path):
    """QosPolicy.load re-normalizes, so the linter must see the RAW file:
    a saved ladder with a dominated rung loads 'clean' but lints dirty."""
    from repro import qos
    doc = _doc([_precise_rung(), _rung(0.05, 0.01, 2.0),
                _rung(0.2, 0.04, 1.8)])
    p = tmp_path / "policy.json"
    p.write_text(json.dumps(doc))
    healed = qos.QosPolicy.load(str(p))
    assert len(healed.entries) == 2        # load silently drops the rung
    findings = rules_mod.check_policy_file(str(p))
    assert any(f.rule == "A004" for f in findings)


def test_a004_saved_policy_roundtrip_is_clean(tmp_path):
    from repro import qos
    from repro.core.harness import Record
    recs = [Record(app="toy",
                   spec={"technique": "taf", "level": "block", "hSize": 2,
                         "pSize": 4, "thresh": t},
                   error=e, speedup=s, modeled_speedup=s,
                   approx_fraction=0.5, wall_time_s=1.0, exact_time_s=1.0,
                   extra={})
            for t, e, s in ((0.05, 0.002, 1.2), (0.1, 0.01, 1.5),
                            (0.2, 0.04, 2.2))]
    pol = qos.QosPolicy.from_records(recs)
    p = tmp_path / "ok.json"
    pol.save(str(p))
    assert rules_mod.check_policy_file(str(p)) == []


def test_a004_unreadable_file_reported():
    findings = rules_mod.check_policy_file("/nonexistent/policy.json")
    assert [f.rule for f in findings] == ["A004"]
    assert "unreadable" in findings[0].message


# --------------------------------- A006: statically-hopeless rungs

def _iact_rung(tsize, thresh, error, speedup):
    from repro.core.harness import spec_hash
    spec = {"technique": "iact", "level": "block", "tSize": tsize,
            "thresh": thresh, "tPerBlock": 1}
    return {"spec": spec, "error": error, "speedup": speedup,
            "modeled_speedup": speedup, "spec_hash": spec_hash(spec)}


def test_a006_oversized_iact_table_flagged():
    """An iACT rung whose table probes out-cost the memoized region: the
    measured ladder may look fine (A004-clean), but the predicted speedup
    on the target machine is sub-1x -- a rung that should never ship."""
    doc = _doc([_precise_rung(), _iact_rung(4096, 0.2, 0.01, 1.5)])
    findings = rules_mod.check_policy_cost(doc, subject="p")
    assert [f.rule for f in findings] == ["A006"]
    assert findings[0].subject == "p#rung1"
    assert findings[0].severity is rules_mod.Severity.ERROR
    assert findings[0].detail["predicted_speedup"] <= 1.0


def test_a006_plausible_ladder_clean():
    doc = _doc([_precise_rung(), _rung(0.5, 0.01, 1.2),
                _iact_rung(2, 0.2, 0.04, 1.1)])
    assert rules_mod.check_policy_cost(doc, subject="p") == []


def test_a006_unparseable_spec_left_to_a004():
    doc = _doc([_precise_rung(),
                {"spec": {"technique": "taf", "hSize": -1},
                 "error": 0.01, "speedup": 1.5}])
    assert rules_mod.check_policy_cost(doc, subject="p") == []


def test_a006_policy_file_roundtrip(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps(
        _doc([_precise_rung(), _iact_rung(4096, 0.2, 0.01, 1.5)])))
    good = tmp_path / "good.json"
    good.write_text(json.dumps(_doc([_precise_rung(),
                                     _rung(0.5, 0.01, 1.2)])))
    findings = rules_mod.rule_a006([str(bad), str(good)])
    assert [f.rule for f in findings] == ["A006"]
    assert str(bad) in findings[0].subject


# --------------------------------- A007: divergent loop carries

def _while_program(body_update):
    """A while loop with a data-dependent trip count whose carry folds in
    the tainted memo value via `body_update(v, memo_scalar)`."""
    def fn(state, x):
        def cond(c):
            _, v = c
            return v < 1e6
        def body(c):
            i, v = c
            return i + 1, body_update(v, state["memo"][0])
        return jax.lax.while_loop(cond, body, (jnp.int32(0), x))
    args = ({"memo": jnp.ones((4,), jnp.float32)}, jnp.float32(1.0))
    return fn, args


def test_a007_amplifying_while_carry_flagged():
    # v <- 2v + memo: the carry's relative error grows every iteration
    # and the trip count is data-dependent -- no static bound exists
    fn, args = _while_program(lambda v, m: 2.0 * v + m)
    findings = rules_mod.check_divergence(fn, args, ("memo",), "toy.loop")
    assert [f.rule for f in findings] == ["A007"]
    assert findings[0].severity is rules_mod.Severity.ERROR
    assert findings[0].detail["loop"]["kind"] == "while"
    assert findings[0].detail["loop"]["gain"] > 1.0


def test_a007_bounded_while_carry_clean():
    # v <- max(v, memo): the carry error saturates at the injected bound
    # (max is error-preserving), so the fixpoint converges -- no finding
    fn, args = _while_program(jnp.maximum)
    assert rules_mod.check_divergence(fn, args, ("memo",), "toy.loop") == []


def test_a007_no_tainted_leaves_is_a_warning():
    fn, args = _while_program(lambda v, m: 2.0 * v + m)
    findings = rules_mod.check_divergence(fn, args, ("nonexistent",), "toy")
    assert [f.rule for f in findings] == ["A007"]
    assert findings[0].severity is rules_mod.Severity.WARNING
    assert "unchecked" in findings[0].message


def test_a007_committed_region_steps_clean():
    """The shipped region step programs must not amplify their memoized
    values unboundedly -- the same contract the tree-wide lint enforces."""
    assert rules_mod.rule_a007(("regions",)) == []


# ------------------------------------------- A005 + the two lint hooks

@pytest.fixture(scope="module")
def engine():
    from repro.analysis.targets import engine_fixture
    return engine_fixture()


def test_a005_committed_engine_is_clean(engine):
    assert rules_mod.check_engine_placement(engine) == []


def test_a005_uncommitted_leaves_flagged(engine):
    from repro.analysis.targets import decode_fixture
    from repro.serving.scheduler import ServingEngine
    fx = decode_fixture()
    eng = ServingEngine(fx["model"], fx["params"], slots=2, max_len=16,
                        prompt_len=4, devices=1)
    eng.params = fx["params"]          # raw host arrays: no mesh commitment
    findings = rules_mod.check_engine_placement(eng)
    assert [f.key for f in findings] == ["A005:serving.engine.params"]
    assert "without mesh commitment" in findings[0].message


def test_engine_lint_hook_clean_and_raises():
    from repro.analysis.targets import decode_fixture
    from repro.serving.scheduler import ServingEngine
    fx = decode_fixture()
    ServingEngine(fx["model"], fx["params"], slots=2, max_len=16,
                  prompt_len=4, devices=1, lint=True)   # must not raise
    orig = jax.device_put
    try:
        jax.device_put = lambda tree, *a, **k: tree   # sabotage placement
        with pytest.raises(ValueError, match="A005"):
            ServingEngine(fx["model"], fx["params"], slots=2, max_len=16,
                          prompt_len=4, devices=1, lint=True)
    finally:
        jax.device_put = orig


def test_run_specs_lint_hook(monkeypatch):
    sys.path.insert(0, "examples")
    from apps import approx_ffn
    from repro.core import batching
    from repro.core.harness import run_specs, taf_grid
    from repro.core.types import Level
    grid = taf_grid(h_sizes=(3,), p_sizes=(2,), thresholds=(0.02, 0.1),
                    levels=(Level.BLOCK,))
    app = approx_ffn.make_app(substrate="host")
    assert len(run_specs(app, grid, repeats=1, lint=True)) == len(grid)

    orig = batching.static_key

    def leaky(spec):
        k = orig(spec)
        return k + (spec.taf.rsd_threshold,) if k and spec.taf else k
    monkeypatch.setattr(batching, "static_key", leaky)
    with pytest.raises(ValueError, match="A001"):
        run_specs(app, grid, repeats=1, lint=True)


# ------------------------------------------------- CLI + the meta-test

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _cli(*argv):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis.lint", *argv],
        capture_output=True, text=True, env=env, cwd=_ROOT)


def test_cli_bad_policy_exits_1_good_policy_0(tmp_path):
    bad = _doc([_precise_rung(), _rung(0.05, 0.01, 2.0),
                _rung(0.2, 0.04, 1.8)])
    bp = tmp_path / "bad.json"
    bp.write_text(json.dumps(bad))
    r = _cli("--rules", "A004", "--policies", str(bp), "--format", "json")
    assert r.returncode == 1, r.stderr
    doc = json.loads(r.stdout)
    assert doc["summary"]["errors"] >= 1
    assert all(f["rule"] == "A004" for f in doc["findings"])

    good = _doc([_precise_rung(), _rung(0.05, 0.01, 1.5)])
    gp = tmp_path / "good.json"
    gp.write_text(json.dumps(good))
    r = _cli("--rules", "A004", "--policies", str(gp))
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_allowlist_is_load_bearing():
    """The committed allowlist is what keeps the structural-perforation
    probes green: --no-allowlist must fail on exactly those A001s."""
    r = _cli("--apps", "kernels", "--rules", "A001", "--no-allowlist",
             "--format", "json")
    assert r.returncode == 1, r.stdout + r.stderr
    doc = json.loads(r.stdout)
    subjects = {f["subject"] for f in doc["findings"]}
    assert subjects == {"kernels.perforated_matmul.perfo",
                        "kernels.perforated_attention.perfo"}
    r = _cli("--apps", "kernels", "--rules", "A001")
    assert r.returncode == 0, r.stdout + r.stderr


def test_meta_current_tree_lints_clean():
    """The tree itself must lint clean under the committed allowlist --
    the same contract CI's lint step enforces. Serving group excluded
    here (the engine fixture executes; it has its own tests above)."""
    allow = Allowlist.load(default_allowlist_path(_ROOT))
    rep = run_lint(apps=("kernels", "regions", "ffn"), allowlist=allow)
    assert not rep.errors, rep.errors
    assert not rep.findings, rep.render_text()
    assert len(rep.allowlisted) == 3     # pinned: bump with .approxlint.json
