"""Autotuner tests (core/autotune.py: paper section 4.2 future work)."""
import numpy as np
import jax.numpy as jnp

from repro.core import ApproxSpec, Level, TAFParams, Technique
from repro.core.autotune import random_search, successive_halving
from repro.core.harness import AppResult, ApproxApp
from repro.core import taf as taf_mod


def _make_app():
    xs = jnp.ones((40, 16, 4)) + 0.001 * jnp.asarray(
        np.random.RandomState(0).standard_normal((40, 16, 4)))

    def run(spec: ApproxSpec) -> AppResult:
        import time
        t0 = time.perf_counter()
        if spec.technique == Technique.TAF:
            ys, _, frac = taf_mod.run_sequence(spec.taf, xs,
                                               lambda x: jnp.sum(x, -1))
            frac = float(frac)
        else:
            ys = jnp.sum(xs, -1)
            frac = 0.0
        return AppResult(qoi=np.asarray(ys),
                         wall_time_s=time.perf_counter() - t0,
                         approx_fraction=frac,
                         flop_fraction=max(1 - frac, 1e-3))

    return ApproxApp("tune_demo", run)


def _grid():
    specs = []
    for t in (0.0, 0.1, 1.0, 10.0):
        for p in (2, 16):
            specs.append(ApproxSpec(Technique.TAF, Level.ELEMENT,
                                    taf=TAFParams(3, p, t)))
    return specs


def test_successive_halving_finds_high_speedup_config():
    app = _make_app()
    recs = successive_halving(app, _grid(), max_error=0.10, eta=2)
    assert recs, "must return final-rung records"
    best = recs[0]
    # stable data: the tuner must find a config that approximates a lot
    assert best.error < 0.10
    assert best.modeled_speedup > 2.0
    # t=0 configs cannot win (they never approximate)
    assert best.spec["thresh"] > 0.0


def test_successive_halving_cheaper_than_exhaustive():
    app = _make_app()
    calls = {"n": 0}
    orig = app.run

    def counting(spec):
        calls["n"] += 1
        return orig(spec)

    app.run = counting
    successive_halving(app, _grid(), max_error=0.10, eta=2, base_repeats=1)
    # the race reached fidelity 4 (two halvings): exhaustive at that
    # fidelity costs 4 * n; the race must undercut it
    assert calls["n"] < 4 * len(_grid())


def test_random_search_respects_budget():
    app = _make_app()
    calls = {"n": 0}
    orig = app.run

    def counting(spec):
        calls["n"] += 1
        return orig(spec)

    app.run = counting

    def sampler(rng):
        return ApproxSpec(Technique.TAF, Level.ELEMENT,
                          taf=TAFParams(3, rng.choice([2, 16]),
                                        rng.choice([0.1, 1.0, 10.0])))

    recs = random_search(app, sampler, budget=6)
    assert len(recs) == 6
    assert calls["n"] == 6 + 1  # budget + exact baseline
