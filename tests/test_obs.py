"""repro.obs regression suite (ISSUE 10).

Pins the observability layer's contracts:

- **tracing**: span nesting, instant/counter events, Chrome trace export
  shape, and the scoped `use()` tracer swap;
- **zero-cost when disabled**: no tracer -> the span fast path returns the
  shared null singleton and records nothing, and an instrumented
  `ServingEngine.tick()` adds ZERO compiles to the serve step whether
  tracing is on or off (`_cache_size()`, as in test_qos.py);
- **metrics**: typed counters/gauges/histograms, the `_percentile` edge
  cases the serving stats lean on (empty/singleton/duplicates), and the
  BENCH_*.json `stamp()` schema;
- **timing**: the shared `measure()` helper that replaced the four
  hand-rolled timer loops (block_until_ready semantics, stat selection,
  value passthrough);
- **flight recorder**: ring capacity, `amend`, and the `trip` dump;
- **typed knob moves**: `KnobMove` reasons and the backward-compatible
  `knob_log` property;
- **A008**: the instrumentation-safety lint catches both known-bad modes
  (concretization inside jit; traced value escaping into a payload) and
  the tree itself lints clean.
"""
import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import obs, qos
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs import report as obs_report
from repro.obs import trace as obs_trace
from repro.obs.timing import Measurement, measure


@pytest.fixture(autouse=True)
def _no_ambient_obs():
    """Every test starts with tracing disabled, fresh metrics, and no
    flight recorder (and cannot leak any of them into other tests)."""
    obs_trace.disable()
    obs_metrics.reset()
    obs_recorder.uninstall()
    yield
    obs_trace.disable()
    obs_metrics.reset()
    obs_recorder.uninstall()


# --------------------------------------------------------------------------
# trace
# --------------------------------------------------------------------------

def test_trace_disabled_is_null_and_records_nothing():
    assert not obs_trace.enabled()
    s1 = obs_trace.span("a", x=1)
    s2 = obs_trace.span("b")
    assert s1 is s2, "disabled fast path must return the shared singleton"
    with s1:
        obs_trace.event("nope")
        obs_trace.counter("nope", 1)
    assert obs_trace.get_tracer() is None


def test_trace_spans_nest_and_export_chrome():
    t = obs_trace.Tracer()
    with obs_trace.use(t):
        with obs_trace.span("outer", k="v"):
            with obs_trace.span("inner"):
                pass
        obs_trace.event("marker", reason="x")
        obs_trace.counter("tokens", 3)
        obs_trace.counter("tokens", 2)
    assert len(t) == 5      # 2 spans + 1 instant + 2 counter samples
    doc = t.to_chrome()
    evs = doc["traceEvents"]
    by_name = {e["name"]: e for e in evs}
    assert by_name["outer"]["ph"] == "X"
    assert by_name["inner"]["ph"] == "X"
    assert by_name["marker"]["ph"] == "i"
    assert by_name["marker"]["args"]["reason"] == "x"
    # inner completes first and nests inside outer's [ts, ts+dur)
    o, i = by_name["outer"], by_name["inner"]
    assert o["ts"] <= i["ts"]
    assert i["ts"] + i["dur"] <= o["ts"] + o["dur"] + 1e-6
    # counters are cumulative
    cts = [e for e in evs if e["ph"] == "C"]
    assert [c["args"]["value"] for c in cts] == [3.0, 5.0]
    assert t.counter_value("tokens") == 5.0
    assert doc["otherData"]["schema"] == obs_trace.SCHEMA_VERSION


def test_trace_use_restores_previous_tracer():
    t1, t2 = obs_trace.Tracer(), obs_trace.Tracer()
    obs_trace.enable(t1)
    with obs_trace.use(t2):
        assert obs_trace.get_tracer() is t2
        obs_trace.event("inner_only")
    assert obs_trace.get_tracer() is t1
    assert len(t2) == 1 and len(t1) == 0


def test_trace_save_roundtrip(tmp_path):
    t = obs_trace.Tracer()
    with obs_trace.use(t):
        with obs_trace.span("s", arr=[1, 2]):
            pass
    path = str(tmp_path / "trace.json")
    t.save(path)
    doc = json.load(open(path))
    assert doc["traceEvents"][0]["name"] == "s"
    assert doc["traceEvents"][0]["args"]["arr"] == [1, 2]


# --------------------------------------------------------------------------
# metrics (incl. the EngineStats percentile edge cases)
# --------------------------------------------------------------------------

def test_percentile_empty_is_none():
    assert obs_metrics.percentile([], 50) is None
    assert obs_metrics.percentile([], 99) is None


def test_percentile_singleton_and_duplicates():
    assert obs_metrics.percentile([3.5], 50) == pytest.approx(3.5)
    assert obs_metrics.percentile([3.5], 99) == pytest.approx(3.5)
    assert obs_metrics.percentile([2.0, 2.0, 2.0], 50) == pytest.approx(2.0)
    assert obs_metrics.percentile([2.0, 2.0, 2.0], 99) == pytest.approx(2.0)
    assert obs_metrics.percentile([1.0, 3.0], 50) == pytest.approx(2.0)


def test_engine_stats_latency_summary_before_any_completion():
    from repro.serving.scheduler import EngineStats
    s = EngineStats()
    assert s.ttft_p50 is None and s.ttft_p99 is None
    assert s.latency_p50 is None and s.latency_p99 is None
    summ = s.latency_summary()
    assert summ["requests"] == 0
    assert all(summ[k] is None for k in
               ("ttft_p50_s", "ttft_p99_s", "latency_p50_s",
                "latency_p99_s"))
    s.ttft_s.append(0.25)                 # singleton
    assert s.ttft_p50 == pytest.approx(0.25)
    assert s.ttft_p99 == pytest.approx(0.25)
    s.latency_s.extend([1.0, 1.0, 1.0])   # duplicates
    assert s.latency_p50 == pytest.approx(1.0)
    assert s.latency_p99 == pytest.approx(1.0)


def test_metrics_registry_types_and_snapshot():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(2.0)
    reg.gauge("g").set(7.0)
    h = reg.histogram("h")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["c"] == 3.0
    assert snap["gauges"]["g"] == 7.0
    hs = snap["histograms"]["h"]
    assert hs["count"] == 4 and hs["min"] == 1.0 and hs["max"] == 4.0
    assert hs["p50"] == pytest.approx(2.5)
    with pytest.raises(ValueError):
        reg.gauge("c")        # cross-type name collision
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_metrics_stamp_schema():
    obs_metrics.registry().counter("x").inc()
    doc = obs_metrics.stamp({"metric": "m"})
    assert doc["metric"] == "m"
    assert doc["obs"]["schema"] == obs_metrics.SNAPSHOT_SCHEMA_VERSION
    assert doc["obs"]["metrics"]["counters"]["x"] == 1.0


def test_obs_count_facade_feeds_both_sinks():
    t = obs_trace.Tracer()
    with obs_trace.use(t):
        obs.count("hits")
        obs.count("hits", 2.0)
    assert obs_metrics.registry().counter("hits").value == 3.0
    assert t.counter_value("hits") == 3.0


# --------------------------------------------------------------------------
# timing.measure — the shared timer
# --------------------------------------------------------------------------

def test_measure_returns_value_and_times():
    calls = []

    def fn(a, b=0):
        calls.append(a + b)
        return a + b

    m = measure(fn, 2, b=3, warmup=1, repeats=3)
    assert isinstance(m, Measurement)
    assert m.value == 5
    assert len(calls) == 4                    # 1 warmup + 3 timed
    assert len(m.times) == 3
    assert m.seconds == sorted(m.times)[1]    # median
    assert measure(fn, 1, warmup=0, repeats=1).seconds >= 0.0


def test_measure_stats_and_device_values():
    x = jnp.arange(8.0)
    m_min = measure(jnp.sum, x, warmup=1, repeats=3, stat="min")
    assert m_min.seconds == min(m_min.times)
    m_mean = measure(jnp.sum, x, warmup=0, repeats=2, stat="mean")
    assert m_mean.seconds == pytest.approx(sum(m_mean.times) / 2)
    assert float(m_mean.value) == 28.0


def test_measure_emits_span_when_traced():
    t = obs_trace.Tracer()
    with obs_trace.use(t):
        measure(lambda: 1, warmup=0, repeats=2, span="unit.timer")
    names = [r["name"] for r in t.records]
    assert "unit.timer" in names


# --------------------------------------------------------------------------
# flight recorder
# --------------------------------------------------------------------------

def test_recorder_ring_amend_and_trip(tmp_path):
    rec = obs_recorder.FlightRecorder(capacity=3, out_dir=str(tmp_path))
    for i in range(5):
        rec.note(tick=i)
    assert [e["tick"] for e in rec.window()] == [2, 3, 4]
    rec.amend(knob=0.1)
    assert rec.window()[-1] == {"tick": 4, "knob": 0.1}
    dump = rec.trip("fallback", request_class="batch")
    assert dump["schema"] == obs_recorder.DUMP_SCHEMA_VERSION
    assert dump["reason"] == "fallback"
    assert dump["context"] == {"request_class": "batch"}
    assert [e["tick"] for e in dump["ticks"]] == [2, 3, 4]
    files = sorted(os.listdir(tmp_path))
    assert len(files) == 1 and "fallback" in files[0]
    on_disk = json.load(open(tmp_path / files[0]))
    assert on_disk["ticks"] == dump["ticks"]
    # ring survives the trip (a second fault dumps overlapping context)
    assert len(rec.window()) == 3 and len(rec.dumps) == 1


def test_recorder_install_uninstall():
    assert obs_recorder.get_recorder() is None
    rec = obs_recorder.install(capacity=4)
    assert obs_recorder.get_recorder() is rec
    obs_recorder.uninstall()
    assert obs_recorder.get_recorder() is None


# --------------------------------------------------------------------------
# report CLI
# --------------------------------------------------------------------------

def test_report_renders_trace_and_metrics(tmp_path, capsys):
    t = obs_trace.Tracer()
    with obs_trace.use(t):
        with obs_trace.span("alpha"):
            pass
        obs_trace.event("beta", reason="r")
        obs_trace.counter("gamma", 2.0)
    path = str(tmp_path / "t.json")
    t.save(path)
    assert obs_report.main([path]) == 0
    out = capsys.readouterr().out
    assert "alpha" in out and "gamma" in out

    obs_metrics.registry().histogram("h").observe(1.0)
    mpath = str(tmp_path / "m.json")
    with open(mpath, "w") as f:
        json.dump(obs_metrics.stamp({"metric": "x"}), f)
    assert obs_report.main([mpath]) == 0
    assert "h" in capsys.readouterr().out


# --------------------------------------------------------------------------
# serving integration: typed knob moves + zero extra compiles
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def decode_setup():
    from repro.models import build
    cfg = qos.default_decode_cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _requests(cfg, n, gen=6, cls="default"):
    from repro.serving import Request
    rng = np.random.RandomState(0)
    return [Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab_size, 8)
                    .astype(np.int32),
                    max_new_tokens=gen, qos_class=cls)
            for i in range(n)]


def test_knob_reason_classification():
    from repro.serving.scheduler import ServingEngine
    import types
    eng = types.SimpleNamespace(qos=None)
    reason = ServingEngine._knob_reason
    assert reason(eng, 0.1, None) == "init"
    assert reason(eng, 0.0, 0.3) == "tighten"
    assert reason(eng, 0.3, 0.0) == "loosen"
    assert reason(eng, (0.1, 0.3), (0.3, 0.1)) == "mixed"
    assert reason(eng, (0.1,), (0.1, 0.3)) == "init"   # resharding edge
    fb = types.SimpleNamespace(in_fallback=True)
    eng_fb = types.SimpleNamespace(
        qos=types.SimpleNamespace(controllers={"default": fb}))
    assert reason(eng_fb, 0.0, 0.3) == "fallback"


def test_knob_events_typed_and_knob_log_compatible(decode_setup):
    from repro.serving import ServingEngine
    from repro.serving.scheduler import KnobMove
    cfg, model, params = decode_setup
    engine_qos = qos.QosEngine(
        serving_policy(), {"default": 0.5}, sample_fraction=1.0, window=4,
        config=qos.ControllerConfig(min_samples=1, hold_ticks=1))
    eng = ServingEngine(model, params, slots=2, max_len=32, prompt_len=8,
                        qos=engine_qos)
    for r in _requests(cfg, 2, gen=8):
        eng.submit(r)
    eng.run_until_drained()
    assert eng.knob_events, "the QoS loop must actuate at least once"
    assert all(isinstance(m, KnobMove) for m in eng.knob_events)
    assert eng.knob_events[0].reason == "init"
    assert eng.knob_events[0].previous is None
    for prev_m, m in zip(eng.knob_events, eng.knob_events[1:]):
        assert m.previous == prev_m.value
        assert m.reason in ("tighten", "loosen", "fallback", "mixed",
                            "init")
    # backward-compatible view: exactly the old (tick, value) tuples
    assert eng.knob_log == [(m.tick, m.value) for m in eng.knob_events]
    assert all(isinstance(t, int) for t, _ in eng.knob_log)


def serving_policy(metric="mape"):
    """Knob-backed ladder matching default_decode_cfg's structural params
    (hSize=2, pSize=4) without paying for a calibration sweep -- same
    shape as test_qos.py's helper."""
    from repro.core.harness import Record
    def rec(thresh, error, speedup):
        spec = {"technique": "taf", "level": "block", "hSize": 2,
                "pSize": 4, "thresh": thresh}
        return Record(app="toy", spec=spec, error=error, speedup=speedup,
                      modeled_speedup=speedup, approx_fraction=0.5,
                      wall_time_s=1.0, exact_time_s=1.0, extra={})
    return qos.QosPolicy.from_records(
        [rec(0.06, 0.02, 1.5), rec(0.3, 0.08, 3.0)],
        use_modeled=True, metric=metric)


def test_instrumented_tick_adds_zero_compiles(decode_setup):
    """The observability contract on the serving hot loop: spans, metrics
    and the flight recorder are host-side appends -- the jitted serve
    step's compile cache must not grow when tracing turns on/off."""
    from repro.serving import ServingEngine
    cfg, model, params = decode_setup
    eng = ServingEngine(model, params, slots=2, max_len=48, prompt_len=8)
    for r in _requests(cfg, 2, gen=24):
        eng.submit(r)
    eng.warmup()
    for _ in range(4):
        eng.tick()
    size0 = eng._serve._cache_size()

    t = obs_trace.Tracer()
    rec = obs_recorder.install(capacity=8)
    try:
        with obs_trace.use(t):
            for _ in range(4):
                eng.tick()
    finally:
        obs_recorder.uninstall()
    assert eng._serve._cache_size() == size0, \
        "tracing-enabled tick recompiled the serve step"
    names = {r_["name"] for r_ in t.records}
    assert "engine.tick" in names and "tick.serve" in names
    # no QoS plane -> nothing opens a flight note; the tick's amend() is
    # a no-op on the empty ring rather than inventing entries
    assert rec.window() == []

    for _ in range(4):                      # disabled again: still zero
        eng.tick()
    assert eng._serve._cache_size() == size0
    assert obs_metrics.registry().histogram("serving.tick_s") \
        .summary()["count"] == 4, "per-tick metrics only while tracing"


# --------------------------------------------------------------------------
# A008 instrumentation-safety lint
# --------------------------------------------------------------------------

def test_a008_catches_payload_tracer_leak():
    from repro.analysis.rules import check_instrumentation_safety

    def bad(x):
        obs_trace.event("knob", value=jnp.sum(x))   # traced value escapes
        return x * 2

    fs = check_instrumentation_safety(bad, (jnp.ones(4),), "unit.bad")
    assert any(f.severity.name == "ERROR" for f in fs)
    assert any("traced value" in f.message for f in fs)


def test_a008_catches_concretization():
    from repro.analysis.rules import check_instrumentation_safety

    def bad(x):
        obs_trace.event("knob", value=float(jnp.sum(x)))  # forced sync
        return x * 2

    fs = check_instrumentation_safety(bad, (jnp.ones(4),), "unit.sync")
    assert len(fs) == 1 and fs[0].severity.name == "ERROR"
    assert "concretizes" in fs[0].message


def test_a008_clean_function_passes():
    from repro.analysis.rules import check_instrumentation_safety

    def good(x):
        obs_trace.event("knob", value=0.1, reason="loosen")  # host scalars
        return x * 2

    assert check_instrumentation_safety(good, (jnp.ones(4),),
                                        "unit.good") == []


def test_a008_tree_lints_clean():
    """Meta-test: the repo's own instrumentation must satisfy its own
    lint (kernel targets; the decode target is covered by the full lint
    benchmark, which the regression baseline pins to zero findings)."""
    from repro.analysis.lint import run_lint
    rep = run_lint(apps=("kernels",), rules=("A008",))
    assert rep.errors == []
    bad = [f for f in rep.findings if f.severity.name == "ERROR"]
    assert bad == [], f"A008 findings on the tree: {bad}"
