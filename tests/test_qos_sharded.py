"""Sharded QoS serving regression suite (ISSUE 6).

Three invariants pinned under the 8-fake-device harness (subprocess, as in
``test_distributed.py``: the device count must be set before jax
initializes):

- **bit-parity**: the same engine config (8 logical shards) produces
  bit-identical decode outputs and canary error estimates on an 8-device
  and a 1-device mesh -- per-shard compute has no cross-shard collectives,
  so the device count must never change numerics;
- **zero recompiles**: per-shard TAF knob moves are traced-data writes
  into the cache pytree; the jitted sharded serve step's compile cache
  must not grow across them (``_cache_size()``, as in
  ``test_kernel_substrate.py``);
- **deterministic per-shard fallback**: the fault drill
  (``QosEngine.inject(error, shard=s)``) produces the same controller
  trajectories run-to-run and backs off only the drilled shard's classes.

Host-level tests (no subprocess) cover the per-shard control-plane
arithmetic: strictest-live-rung reduction, exposure attribution, and the
sharding-mode guard rails.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


# shared preamble: a smoke model + a 3-rung synthetic ladder, and a driver
# that serves a seeded trace on a (devices, shards) engine and returns the
# artifacts the tests compare
_PREAMBLE = r"""
import numpy as np, jax
from repro import qos
from repro.models import build
from repro.serving import Request, ServingEngine

cfg = qos.default_decode_cfg()
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
records = [
    {"app": "taf_decode", "spec": {"technique": "taf", "level": "block",
     "hSize": 2, "pSize": 4, "thresh": th}, "error": e, "speedup": s,
     "modeled_speedup": s, "workload": {}}
    for th, e, s in [(0.02, 0.005, 1.2), (0.06, 0.02, 1.5),
                     (0.3, 0.08, 2.0)]]
policy = qos.QosPolicy.from_records(records, metric="mcr")

def run(devices, shards, slots, *, seed=0, inject_at=None, inject_shard=None):
    engine_qos = qos.QosEngine(policy, {"default": 0.10, "batch": 0.5},
                               sample_fraction=0.5, window=8)
    eng = ServingEngine(model, params, slots=slots, max_len=48,
                        prompt_len=8, qos=engine_qos, devices=devices,
                        shards=shards)
    eng.warmup()
    rng = np.random.RandomState(seed)
    reqs = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 8),
                    max_new_tokens=6,
                    qos_class="default" if i % 2 == 0 else "batch")
            for i in range(slots * 2)]
    for r in reqs:
        eng.submit(r)
    for tick in range(200):
        if inject_at is not None and tick == inject_at:
            eng.qos.inject(10.0, shard=inject_shard)
        if eng.tick() == 0 and not eng.queue:
            break
    return eng, reqs
"""


class TestShardedParity:
    def test_device_count_invariance(self):
        """8 logical shards on an 8-device mesh vs the SAME 8 shards on a
        1-device mesh: decode outputs (per request, token for token),
        canary error estimates, and knob logs are bit-identical."""
        out = run_sub(_PREAMBLE + r"""
e8, r8 = run(8, 8, 8)
e1, r1 = run(1, 8, 8)
assert [r.output for r in r8] == [r.output for r in r1], "decode outputs"
s8, s1 = e8.qos.summary(), e1.qos.summary()
assert s8["estimate"] == s1["estimate"], (s8["estimate"], s1["estimate"])
assert s8["genuine_mean_error"] == s1["genuine_mean_error"]
assert e8.knob_log == e1.knob_log
assert e8.mesh_shape == (8, 1) and e1.mesh_shape == (1, 1)
assert e8.stats.tokens_out == e1.stats.tokens_out > 0
print("PARITY_OK", e8.stats.tokens_out)
""")
        assert "PARITY_OK" in out

    def test_sharded_vs_unsharded_outputs(self):
        """The sharded wrapper itself must not change numerics: one shard
        on a 1-device mesh reproduces the plain (unsharded) engine's
        outputs token for token."""
        out = run_sub(_PREAMBLE + r"""
es, rs = run(1, 1, 4)
ep, rp = run(None, None, 4)
assert ep.mesh_shape is None and es.mesh_shape == (1, 1)
assert [r.output for r in rs] == [r.output for r in rp], "decode outputs"
assert es.knob_log == ep.knob_log or (
    # unsharded knob entries are scalars, sharded are 1-tuples
    [(t, (v,) if not isinstance(v, tuple) else v) for t, v in ep.knob_log]
    == es.knob_log)
print("WRAP_OK")
""")
        assert "WRAP_OK" in out


class TestZeroRecompile:
    def test_per_shard_knob_moves_do_not_recompile(self):
        """The per-shard threshold vector is traced DATA: serving under a
        changing knob vector must not grow the serve step's compile
        cache, and the written thresholds must be live in the cache."""
        out = run_sub(_PREAMBLE + r"""
import jax.numpy as jnp
from repro.qos import set_decode_threshold
eng, reqs = run(8, 8, 8)   # compiles every signature serving hits
base = eng._serve._cache_size()
pos = jnp.int32(10)
vectors = [(0.3,) * 8,
           (0.0, 0.3) * 4,
           tuple(0.1 * s for s in range(8)),
           (0.0,) * 8]
for vec in vectors:
    eng.cache = eng._place_cache(set_decode_threshold(eng.cache, vec))
    eng.tokens, _, eng.cache = eng._serve(eng.params, eng.cache,
                                          eng.tokens, pos)
    th = np.asarray(eng.cache["taf"]["threshold"])
    np.testing.assert_allclose(th[:, 0], np.asarray(vec), rtol=1e-6)
assert eng._serve._cache_size() == base, (
    f"serve step recompiled: {eng._serve._cache_size()} vs {base}")
print("NORECOMPILE_OK", base)
""")
        assert "NORECOMPILE_OK" in out


class TestPerShardFallback:
    def test_fault_drill_deterministic_and_localized(self):
        """Injecting a spike into ONE shard's canary stream (a) backs off
        the classes live on that shard, (b) leaves the engine-wide
        estimate fault-free (inject is not a genuine canary), and (c) is
        deterministic run to run."""
        out = run_sub(_PREAMBLE + r"""
runs = []
for _ in range(2):
    eng, _ = run(8, 8, 16, inject_at=4, inject_shard=7)
    s = eng.qos.summary()
    traj = {cls: [(p.step, p.index, p.event)
                  for p in ctl.trajectory]
            for cls, ctl in eng.qos.controllers.items()}
    runs.append((eng.knob_log, traj, s["injected_faults"],
                 s["fallback_rate"]))
assert runs[0] == runs[1], "fault drill is nondeterministic"
knob_log, traj, faults, fb = runs[0]
assert faults >= 1
assert fb > 0.0, "drill never forced a fallback tick"
events = [e for t in traj.values() for (_, _, e) in t]
assert any(e == "fallback" for e in events), events
print("DRILL_OK", faults, sorted(set(events)))
""")
        assert "DRILL_OK" in out


# ---------------------------------------------------------------------------
# host-level control-plane arithmetic (fast: no subprocess, no mesh)
# ---------------------------------------------------------------------------

def _mk_engine(n_shards=4, targets=None):
    from repro import qos
    records = [
        {"app": "taf_decode", "spec": {"technique": "taf", "level": "block",
         "hSize": 2, "pSize": 4, "thresh": th}, "error": e, "speedup": s,
         "modeled_speedup": s, "workload": {}}
        for th, e, s in [(0.02, 0.005, 1.2), (0.06, 0.02, 1.5),
                         (0.3, 0.08, 2.0)]]
    policy = qos.QosPolicy.from_records(records, metric="mcr")
    eng = qos.QosEngine(policy, targets or {"default": 0.10, "batch": 0.5},
                        sample_fraction=1.0, window=8)
    if n_shards:
        eng.enable_sharding(n_shards)
    return eng


class TestShardPlanReduction:
    def test_strictest_live_rung_per_shard_and_global(self):
        eng = _mk_engine(4)
        # put the two class controllers on different rungs
        eng.controller("default").index = 1
        eng.controller("batch").index = 3
        plan = eng.plan_shards([["default"], ["batch"],
                                ["default", "batch"], []])
        assert plan.sharded and plan.shard_indices == (1, 3, 1, 1)
        # global = strictest across shards WITH live lanes (empty shard 3
        # follows the default controller, it must not loosen the plan)
        assert plan.index == 1
        assert len(plan.shard_knobs) == 4

    def test_empty_shards_follow_default(self):
        eng = _mk_engine(2)
        eng.controller("default").index = 2
        plan = eng.plan_shards([[], []])
        assert plan.shard_indices == (2, 2)
        assert plan.index == 2

    def test_plan_validates_shard_count(self):
        eng = _mk_engine(4)
        with pytest.raises(ValueError):
            eng.plan_shards([["default"]])

    def test_enable_sharding_idempotent_but_not_resizable(self):
        eng = _mk_engine(4)
        eng.enable_sharding(4)          # idempotent
        with pytest.raises(ValueError):
            eng.enable_sharding(8)


class TestShardExposure:
    def test_exposure_attributed_to_shard_and_class(self):
        eng = _mk_engine(2)
        eng.plan_shards([["default"], ["batch"]])
        same = np.zeros((1, 4), np.float32)
        diff = np.zeros((1, 4), np.float32)
        diff[:, 1] = 1.0                 # argmax flips: mcr error = 1
        eng.observe_shard(0, same, same, ["default"])
        eng.observe_shard(1, same, diff, ["batch"])
        exp = eng.summary()["shard_exposure"]
        assert exp[0]["exposed_mean_error"] == 0.0
        assert exp[1]["exposed_mean_error"] == 1.0
        s = eng.summary()
        assert s["classes"]["default"]["exposed_mean_error"] == 0.0
        assert s["classes"]["batch"]["exposed_mean_error"] == 1.0

    def test_shard_inject_hits_only_that_shards_classes(self):
        eng = _mk_engine(2)
        eng.plan_shards([["default"], ["batch"]])
        eng.inject(5.0, shard=1)
        assert eng.monitor.injected == 1
        assert eng.class_monitors["batch"].injected == 1
        assert eng.class_monitors["default"].injected == 0
