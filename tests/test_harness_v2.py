"""Harness v2 engine tests: resumable keyed-cache sweeps, parallel/batched
evaluation, and Pareto-front machinery (see docs/harness.md)."""
import json
import os

import numpy as np
import pytest

from repro.core import harness as harness_mod
from repro.core.harness import (AppResult, ApproxApp, ApproxSpec, Record,
                                db_index, load_db, record_from_row, save_db,
                                spec_from_dict, spec_hash, spec_key,
                                spec_to_dict, sweep, taf_grid, iact_grid,
                                perfo_grid)
from repro.core.pareto import (dominates, hypervolume, pareto_front,
                               propose_candidates, refine)
from repro.core.types import Level, TAFParams, Technique


def make_toy_app(counter=None):
    """Deterministic numpy-only app: error and wall time are pure functions
    of the TAF threshold, so parallel and serial sweeps must produce
    IDENTICAL records (timing included)."""
    def run(spec: ApproxSpec) -> AppResult:
        if counter is not None:
            counter.append(spec)
        t = spec.taf.rsd_threshold if spec.taf else 0.0
        qoi = np.array([1.0 + 0.1 * t, 2.0])
        return AppResult(qoi=qoi, wall_time_s=1.0 / (1.0 + t),
                         approx_fraction=t / (1.0 + t),
                         flop_fraction=1.0 / (1.0 + t))

    return ApproxApp("toy", run)


def taf_spec(thresh, h=3, p=8):
    return ApproxSpec(Technique.TAF, Level.ELEMENT,
                      taf=TAFParams(h, p, thresh))


GRID = [taf_spec(t) for t in (0.1, 0.5, 1.0, 2.0)]


# ---------------------------------------------------------------- spec keys

def test_spec_hash_roundtrips_through_json():
    for spec in taf_grid(h_sizes=(2,), p_sizes=(8,), thresholds=(0.5, 5)) + \
            iact_grid(t_sizes=(2,), thresholds=(0.3,), tables_per_block=(1,)) + \
            perfo_grid(skips=(4,), fractions=(0.25,)):
        d = json.loads(json.dumps(spec_to_dict(spec)))
        assert spec_hash(d) == spec_hash(spec)
        assert spec_hash(spec_from_dict(d)) == spec_hash(spec)


def test_spec_hash_normalizes_int_float():
    assert spec_hash(taf_spec(5)) == spec_hash(taf_spec(5.0))
    assert spec_key(taf_spec(5)) == spec_key(taf_spec(5.0))


# ------------------------------------------------------------------ resume

def test_resume_skips_cached_specs(tmp_path):
    db = str(tmp_path / "db.json")
    calls = []
    app = make_toy_app(calls)
    first = sweep(app, GRID, repeats=1, db_path=db)
    assert len(calls) == len(GRID) + 1  # grid + exact baseline
    second = sweep(app, GRID, repeats=1, db_path=db)
    assert len(calls) == len(GRID) + 1  # fully cached: ZERO new executions
    assert [r.to_json() for r in second] == [r.to_json() for r in first]


def test_resume_densifies_grid(tmp_path):
    db = str(tmp_path / "db.json")
    calls = []
    app = make_toy_app(calls)
    sweep(app, GRID, repeats=1, db_path=db)
    n0 = len(calls)
    denser = GRID + [taf_spec(0.25), taf_spec(0.75)]
    recs = sweep(app, denser, repeats=1, db_path=db)
    # only the 2 new specs (+ a fresh exact baseline) were executed
    assert len(calls) == n0 + 3
    assert len(recs) == len(denser)
    assert [r.spec_hash for r in recs] == [spec_hash(s) for s in denser]


def test_db_append_is_idempotent(tmp_path):
    db = str(tmp_path / "db.json")
    app = make_toy_app()
    sweep(app, GRID, repeats=1, db_path=db)
    rows0 = load_db(db)
    sweep(app, GRID, repeats=1, db_path=db)
    assert load_db(db) == rows0  # no duplicate rows, bit-identical file
    # explicit double-append of the same records also dedupes by cache key
    save_db([record_from_row(r) for r in rows0], db, append=True)
    assert len(load_db(db)) == len(rows0)


def test_resume_false_reevaluates_and_refreshes_db(tmp_path):
    db = str(tmp_path / "db.json")
    calls = []
    app = make_toy_app(calls)
    sweep(app, GRID, repeats=1, db_path=db)
    n0 = len(calls)
    # stamp the stored rows so we can tell old from re-measured
    rows = load_db(db)
    for r in rows:
        r["extra"] = {"stale": True}
    with open(db, "w") as f:
        json.dump(rows, f)
    sweep(app, GRID, repeats=1, db_path=db, resume=False)
    assert len(calls) == 2 * n0
    refreshed = load_db(db)
    assert len(refreshed) == len(GRID)  # replaced, not duplicated
    assert all(r["extra"] == {} for r in refreshed)  # stale rows overwritten


def test_v1_rows_without_spec_hash_are_cached(tmp_path):
    """Schema v1 databases (no spec_hash field) resume correctly."""
    db = str(tmp_path / "db.json")
    app = make_toy_app()
    sweep(app, GRID, repeats=1, db_path=db)
    rows = load_db(db)
    for r in rows:
        del r["spec_hash"]
    with open(db, "w") as f:
        json.dump(rows, f)
    calls = []
    app2 = make_toy_app(calls)
    sweep(app2, GRID, repeats=1, db_path=db)
    assert len(calls) == 0


def test_db_index_keys():
    app = make_toy_app()
    recs = sweep(app, GRID, repeats=1)
    idx = db_index([r.to_json() for r in recs])
    assert set(idx) == {("toy", spec_hash(s), "") for s in GRID}


def test_same_app_different_workload_not_shared(tmp_path):
    """The cache key includes the workload fingerprint: the same app name at
    a different problem size must not be served another size's rows."""
    db = str(tmp_path / "db.json")
    calls_big, calls_small = [], []
    big = make_toy_app(calls_big)
    big.workload = {"n": 512}
    small = make_toy_app(calls_small)
    small.workload = {"n": 256}
    sweep(big, GRID, repeats=1, db_path=db)
    sweep(small, GRID, repeats=1, db_path=db)
    assert len(calls_small) == len(GRID) + 1  # no cross-workload cache hits
    # but the same workload IS shared
    calls2 = []
    small2 = make_toy_app(calls2)
    small2.workload = {"n": 256}
    sweep(small2, GRID, repeats=1, db_path=db)
    assert len(calls2) == 0


# ---------------------------------------------------------------- parallel

def test_parallel_sweep_matches_serial():
    app = make_toy_app()
    serial = sweep(app, GRID, repeats=1, jobs=1)
    parallel = sweep(app, GRID, repeats=1, jobs=4)
    assert [r.to_json() for r in parallel] == [r.to_json() for r in serial]


def test_batched_runner_is_used_and_matches_serial():
    used = {"batches": 0}

    base = make_toy_app()

    def run_batch(specs):
        used["batches"] += 1
        return [base.run(s) for s in specs]

    app = ApproxApp("toy", base.run, run_batch=run_batch)
    serial = sweep(base, GRID, repeats=1, jobs=1)
    batched = sweep(app, GRID, repeats=1, jobs=2)
    assert used["batches"] == 2  # 4 specs in chunks of jobs=2
    assert [r.to_json() for r in batched] == [r.to_json() for r in serial]


def test_batched_runner_length_mismatch_raises():
    base = make_toy_app()
    app = ApproxApp("toy", base.run, run_batch=lambda specs: [])
    with pytest.raises(ValueError):
        sweep(app, GRID, repeats=1, jobs=2)


def test_duplicate_specs_in_grid_evaluated_once():
    calls = []
    app = make_toy_app(calls)
    recs = sweep(app, [taf_spec(0.5), taf_spec(0.5)], repeats=1)
    assert len(calls) == 2  # one eval + exact, not two evals
    assert len(recs) == 2 and recs[0].to_json() == recs[1].to_json()


# ------------------------------------------------------------------ pareto

def _rec(error, speedup, thresh=0.5):
    return Record(app="toy", spec=spec_to_dict(taf_spec(thresh)), error=error,
                  speedup=speedup, modeled_speedup=speedup,
                  approx_fraction=0.0, wall_time_s=1.0, exact_time_s=1.0,
                  extra={})


def test_pareto_front_hand_built():
    a = _rec(0.01, 1.2, 0.1)   # front: lowest error
    b = _rec(0.05, 2.0, 0.2)   # front: pays error for speed
    c = _rec(0.05, 1.5, 0.3)   # dominated by b (same error, slower)
    d = _rec(0.10, 1.8, 0.4)   # dominated by b (more error, slower)
    e = _rec(0.20, 3.0, 0.5)   # front: fastest
    f = _rec(float("inf"), 9.0, 0.6)  # non-finite error: excluded
    front = pareto_front([f, d, c, e, a, b])
    assert front == [a, b, e]
    assert dominates(b, c) and dominates(b, d)
    assert not dominates(a, e) and not dominates(e, a)


def test_pareto_front_on_dicts():
    rows = [_rec(0.01, 1.2).to_json(), _rec(0.5, 9.0).to_json(),
            _rec(0.01, 1.1).to_json()]
    front = pareto_front(rows)
    assert [(r["error"], r["speedup"]) for r in front] == [(0.01, 1.2),
                                                           (0.5, 9.0)]


def test_hypervolume():
    # single point: rectangle (ref_e - e) * (s - ref_s)
    assert hypervolume([_rec(0.2, 3.0)], ref_error=1.0) == \
        pytest.approx(0.8 * 2.0)
    # two-point staircase
    hv = hypervolume([_rec(0.1, 2.0), _rec(0.5, 4.0)], ref_error=1.0)
    assert hv == pytest.approx(0.9 * 1.0 + 0.5 * 2.0)
    # points at/beyond the reference contribute nothing
    assert hypervolume([_rec(2.0, 5.0), _rec(0.1, 0.5)], ref_error=1.0) == 0.0


def test_pareto_edge_cases_empty_and_singleton():
    # empty record set: empty front, zero dominated area
    assert pareto_front([]) == []
    assert hypervolume([]) == 0.0
    # all-non-finite set degenerates to empty too
    assert pareto_front([_rec(float("inf"), 2.0),
                         _rec(float("nan"), 3.0)]) == []
    # single point IS the front, whatever it is
    only = _rec(0.7, 0.4)
    assert pareto_front([only]) == [only]
    assert dominates(only, _rec(0.8, 0.4)) and not dominates(only, only)


def test_pareto_duplicate_objective_ties():
    # duplicate (error, speedup) points: one representative survives and
    # the dominated-area indicator counts the shared rectangle ONCE
    a1 = _rec(0.2, 3.0, thresh=0.1)
    a2 = _rec(0.2, 3.0, thresh=0.9)   # different spec, same objectives
    front = pareto_front([a1, a2])
    assert len(front) == 1
    assert hypervolume([a1, a2], ref_error=1.0) == \
        hypervolume([a1], ref_error=1.0) == pytest.approx(0.8 * 2.0)
    # a tie on ONE axis is not a tie: the faster of the pair dominates
    b_fast, b_slow = _rec(0.2, 3.0), _rec(0.2, 2.0)
    assert pareto_front([b_slow, b_fast]) == [b_fast]
    assert dominates(b_fast, b_slow) and not dominates(b_slow, b_fast)


def test_best_speedup_under_error_edges():
    recs = [_rec(0.05, 2.0), _rec(0.2, 4.0)]
    best = harness_mod.best_speedup_under_error(recs, max_error=0.10)
    assert best is not None and best.speedup == 2.0
    # strict bound: error == max_error does not qualify
    assert harness_mod.best_speedup_under_error(
        recs, max_error=0.05) is None
    # no spec under the bound -> None, not an exception
    assert harness_mod.best_speedup_under_error(recs, max_error=0.01) is None
    assert harness_mod.best_speedup_under_error([], max_error=0.5) is None
    # use_modeled ranks by the structural bound
    slow_but_modeled = Record(app="toy", spec=spec_to_dict(taf_spec(0.7)),
                              error=0.01, speedup=1.1, modeled_speedup=9.0,
                              approx_fraction=0.0, wall_time_s=1.0,
                              exact_time_s=1.0, extra={})
    got = harness_mod.best_speedup_under_error(
        [recs[0], slow_but_modeled], max_error=0.10, use_modeled=True)
    assert got is slow_but_modeled


def test_propose_candidates_subdivides_brackets():
    app = make_toy_app()
    recs = sweep(app, [taf_spec(t) for t in (0.1, 0.9)], repeats=1)
    cands = propose_candidates(recs)
    assert cands, "front members must spawn neighborhood candidates"
    values = {s.taf.rsd_threshold for s in cands if s.taf}
    assert 0.5 in values  # midpoint of the (0.1, 0.9) bracket
    hashes = {spec_hash(s) for s in cands}
    assert spec_hash(taf_spec(0.1)) not in hashes  # measured points excluded


def test_refine_respects_budget_and_caches(tmp_path):
    db = str(tmp_path / "db.json")
    calls = []
    app = make_toy_app(calls)
    coarse = sweep(app, GRID, repeats=1, db_path=db)
    n0 = len(calls)
    new = refine(app, coarse, budget=5, rounds=3, repeats=1, db_path=db)
    assert 0 < len(new) <= 5
    # every refined record was actually evaluated and persisted
    idx = db_index(load_db(db))
    assert all(("toy", r.spec_hash, "") in idx for r in new)
    # refinement is resumable: a re-run never re-executes a spec that was
    # already in the DB (cached candidates cost no budget, so the re-run may
    # spend its budget pushing the frontier further instead)
    n1 = len(calls)
    assert n1 > n0
    db_before = {r["spec_hash"] for r in load_db(db)}
    new2 = refine(app, coarse, budget=5, rounds=3, repeats=1, db_path=db)
    assert len(new2) <= 5
    assert {r.spec_hash for r in new2}.isdisjoint({r.spec_hash for r in new})
    executed2 = {spec_hash(s) for s in calls[n1:]
                 if s.technique != Technique.NONE}
    assert executed2.isdisjoint(db_before)


# ------------------------------------------------------- batching protocol

import os
import sys

import jax.numpy as jnp

from repro.core import batching
from repro.core.harness import run_specs
from repro.core.types import IACTParams, PerforationKind, PerforationParams

sys.path.insert(0, os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "examples"))


def iact_spec(thresh, size=2, tpb=4):
    return ApproxSpec(Technique.IACT, Level.ELEMENT,
                      iact=IACTParams(size, thresh, tpb))


def perfo_spec(kind, fraction):
    return ApproxSpec(Technique.PERFORATION, Level.ELEMENT,
                      perforation=PerforationParams(kind=kind,
                                                    fraction=fraction))


def test_static_key_groups_by_structure_only():
    # same structure, different traced scalar -> same key
    assert batching.static_key(taf_spec(0.1)) == \
        batching.static_key(taf_spec(0.9))
    assert batching.static_key(taf_spec(0.5, h=4)) != \
        batching.static_key(taf_spec(0.5))
    assert batching.static_key(iact_spec(0.3)) == \
        batching.static_key(iact_spec(0.9))
    assert batching.static_key(iact_spec(0.3, size=8)) != \
        batching.static_key(iact_spec(0.3))
    # fraction-kind perforation is batchable; skip-kind and NONE are not
    assert batching.static_key(perfo_spec(PerforationKind.INI, 0.3)) \
        is not None
    assert batching.static_key(ApproxSpec(
        Technique.PERFORATION,
        perforation=PerforationParams(kind=PerforationKind.SMALL,
                                      skip=4))) is None
    assert batching.static_key(ApproxSpec()) is None


def test_traced_param_per_technique():
    assert batching.traced_param(taf_spec(0.7)) == 0.7
    assert batching.traced_param(iact_spec(0.3)) == 0.3
    assert batching.traced_param(perfo_spec(PerforationKind.FINI, 0.4)) == 0.4
    with pytest.raises(ValueError):
        batching.traced_param(ApproxSpec())


def test_group_specs_demotes_small_groups():
    specs = [taf_spec(0.1), taf_spec(0.5), taf_spec(0.9),  # group of 3
             iact_spec(0.3),                               # singleton
             ApproxSpec()]                                 # unbatchable
    groups, serial = batching.group_specs(specs, min_group=2)
    assert list(groups.values()) == [[0, 1, 2]]
    assert serial == [3, 4]  # singleton + unbatchable both run serially


def test_run_batch_grouped_matches_run_one():
    app = make_toy_app()
    grid = GRID + [iact_spec(0.3)] + [ApproxSpec()]
    group_calls = []

    def make_group_fn(key):
        group_calls.append(key)
        if key[0] != Technique.TAF:
            return None  # decline: serial fallback

        def fn(ths):
            qois = jnp.stack([1.0 + 0.1 * ths,
                              jnp.full_like(ths, 2.0)], axis=1)
            return qois, ths / (1.0 + ths)

        return fn

    results = batching.run_batch_grouped(grid, app.run, make_group_fn)
    assert [k[0] for k in group_calls] == [Technique.TAF]
    for spec, got in zip(grid, results):
        want = app.run(spec)
        np.testing.assert_allclose(got.qoi, want.qoi, rtol=1e-6)
        assert abs(got.approx_fraction - want.approx_fraction) < 1e-6


def test_run_batch_grouped_rejects_bad_leading_dim():
    def make_group_fn(key):
        return lambda ths: (jnp.zeros((1, 2)), jnp.zeros((1,)))
    with pytest.raises(ValueError):
        batching.run_batch_grouped(GRID, make_toy_app().run, make_group_fn)


def test_batched_runner_failure_falls_back_to_serial():
    base = make_toy_app()
    attempts = {"n": 0}

    def bad_batch(specs):
        attempts["n"] += 1
        raise RuntimeError("device OOM")

    app = ApproxApp("toy", base.run, run_batch=bad_batch)
    serial = sweep(base, GRID, repeats=2, jobs=1)
    # The fallback contract is warn-once-per-app-per-process: capture the
    # warning (asserting it fires) instead of leaking it into the suite.
    harness_mod._WARNED_BATCH_FALLBACK.discard("toy")
    with pytest.warns(UserWarning,
                      match="falling back to the serial path"):
        recs = sweep(app, GRID, repeats=2, jobs=2)
    assert attempts["n"] == 2  # one failed attempt per chunk of jobs=2
    assert [r.to_json() for r in recs] == [r.to_json() for r in serial]


def test_batched_runner_mid_repeat_failure_discards_partials():
    """A chunk whose run_batch dies on repeat 2 of 3 falls back to the
    serial path with the FULL repeat count (batch-amortized and serial
    timings are not comparable best-of-N candidates)."""
    base = make_toy_app()
    state = {"calls": 0}

    def flaky_batch(specs):
        state["calls"] += 1
        if state["calls"] > 1:
            raise RuntimeError("flaky")
        return [base.run(s) for s in specs]

    app = ApproxApp("toy", base.run, run_batch=flaky_batch)
    serial = sweep(base, GRID, repeats=3, jobs=1)
    harness_mod._WARNED_BATCH_FALLBACK.discard("toy")
    with pytest.warns(UserWarning,
                      match="falling back to the serial path"):
        recs = sweep(app, GRID, repeats=3, jobs=len(GRID))
    assert [r.to_json() for r in recs] == [r.to_json() for r in serial]


# ------------------------------------------------- app run_batch parity


def _taf_iact_grid():
    return (taf_grid(h_sizes=(2,), p_sizes=(4,), thresholds=(0.1, 0.5, 1.5),
                     levels=(Level.ELEMENT,)) +
            iact_grid(t_sizes=(2,), thresholds=(0.3, 0.9, 5.0),
                      tables_per_block=(4,), levels=(Level.ELEMENT,)))


def _taf_perfo_grid():
    return (taf_grid(h_sizes=(2,), p_sizes=(4,), thresholds=(0.5, 1.5, 5.0),
                     levels=(Level.ELEMENT,)) +
            [perfo_spec(PerforationKind.INI, f) for f in (0.1, 0.3, 0.5)] +
            [perfo_spec(PerforationKind.FINI, f) for f in (0.25, 0.5)])


APP_PARITY_CASES = {
    "blackscholes": (
        lambda m: m.make_app(n_elements=32, steps=8), _taf_iact_grid),
    "binomial_options": (
        lambda m: m.make_app(n_elements=16, steps=6, tree_steps=16),
        _taf_iact_grid),
    "kmeans": (
        lambda m: m.make_app(n=128, d=4, k=6, max_iters=12), _taf_iact_grid),
    "lavamd": (lambda m: m.make_app(nx=2), _taf_iact_grid),
    "minife_cg": (lambda m: m.make_app(n=16, iters=8), _taf_perfo_grid),
}


def _diverged(err):
    return (not np.isfinite(err)) or err > 1.0


@pytest.mark.parametrize("name", sorted(APP_PARITY_CASES))
def test_app_run_batch_matches_run(name, monkeypatch):
    """Batched records must match serial records per spec: same error and
    approx fraction (up to XLA fusion noise), same iteration counts.
    MiniFE's divergent configurations (the paper's 593%..3.4e22% blow-up
    regime) are chaotic, so both paths must diverge together rather than
    agree to n digits."""
    import importlib
    mod = importlib.import_module(f"apps.{name}")
    make, grid_fn = APP_PARITY_CASES[name]
    app = make(mod)
    assert app.run_batch is not None, f"{name} must provide run_batch"
    grid = grid_fn()

    # spy on the engine's serial-fallback path: a spec reaching run_one
    # inside run_batch_grouped means it did NOT go through a vmapped group
    fallback_specs = []
    orig_rbg = batching.run_batch_grouped

    def spying_rbg(specs, run_one, make_group_fn, **kw):
        def counting_run_one(s):
            fallback_specs.append(s)
            return run_one(s)
        return orig_rbg(specs, counting_run_one, make_group_fn, **kw)

    monkeypatch.setattr(batching, "run_batch_grouped", spying_rbg)

    serial = sweep(app, grid, repeats=1, jobs=1)
    batched = sweep(app, grid, repeats=1, jobs=len(grid))
    assert fallback_specs == [], \
        f"{name}: specs fell back to serial instead of batching"

    for s, b in zip(serial, batched):
        assert s.spec == b.spec
        if _diverged(s.error):
            assert _diverged(b.error), (s.spec, s.error, b.error)
        else:
            assert abs(s.error - b.error) < 1e-5 or \
                abs(s.error - b.error) / max(abs(s.error), 1e-12) < 1e-3, \
                (s.spec, s.error, b.error)
        assert abs(s.approx_fraction - b.approx_fraction) < 1e-6, s.spec
        if "iters" in s.extra:
            assert s.extra["iters"] == b.extra["iters"], s.spec
