"""Continuous-batching serving engine tests."""
import dataclasses

import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core.types import parse_pragma
from repro.models import build
from repro.serving import Request, ServingEngine


def _engine(taf=False, slots=3):
    cfg = dataclasses.replace(get_smoke_config("qwen3-1.7b"), remat=False)
    if taf:
        cfg = dataclasses.replace(
            cfg, approx_decode=parse_pragma("memo(out:2:4:50.0) level(team)"))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, ServingEngine(model, params, slots=slots, max_len=48,
                              prompt_len=8)


def test_engine_drains_queue_and_respects_budgets():
    cfg, eng = _engine()
    rng = np.random.RandomState(0)
    reqs = [Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=5 + i) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.finished == 7
    for r in reqs:
        assert len(r.output) == r.max_new_tokens
        assert r.finished_at is not None and r.first_token_at is not None


def test_continuous_batching_overlaps_requests():
    """More requests than slots: later requests start before earlier long
    ones finish on other slots (no head-of-line blocking)."""
    cfg, eng = _engine(slots=2)
    rng = np.random.RandomState(1)
    long_req = Request(uid=0, prompt=rng.randint(0, cfg.vocab_size, 8)
                       .astype(np.int32), max_new_tokens=20)
    shorts = [Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 8)
                      .astype(np.int32), max_new_tokens=3)
              for i in range(1, 5)]
    eng.submit(long_req)
    for r in shorts:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.finished == 5
    assert max(s.finished_at for s in shorts) >= shorts[-1].first_token_at
    # at least one short request finished before the long one
    assert min(s.finished_at for s in shorts) < long_req.finished_at


def test_engine_reports_taf_skips():
    cfg, eng = _engine(taf=True)
    rng = np.random.RandomState(2)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=rng.randint(0, cfg.vocab_size, 8)
                           .astype(np.int32), max_new_tokens=12))
    stats = eng.run_until_drained()
    assert stats.finished == 3
    assert stats.taf_total > 0
    assert stats.taf_skip_fraction > 0.0  # huge threshold must trigger TAF
