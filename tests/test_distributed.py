"""Distributed semantics on 8 fake host devices (subprocess: the device
count must be set before jax initializes, and the main test process keeps
1 device per the brief)."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_sub(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_dp_tp_train_step_matches_single_device():
    """A (2 data x 4 model) sharded train step computes the same loss and
    parameter update as the unsharded single-device step."""
    out = run_sub(r"""
import numpy as np, jax, jax.numpy as jnp, dataclasses
from repro.configs import get_smoke_config
from repro.models import build
from repro.optim import adamw
from repro.launch import steps as steps_mod
from repro.runtime import sharding as shardlib

cfg = dataclasses.replace(get_smoke_config('deepseek-7b'), remat=False,
                          compute_dtype='float32')
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = adamw.init(params)
rng = np.random.RandomState(0)
batch = {'tokens': jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16))),
         'labels': jnp.asarray(rng.randint(0, cfg.vocab_size, (4, 16)))}
step = steps_mod.make_train_step(model, adamw.AdamWConfig(lr=1e-3))

# single device
p1, o1, m1 = jax.jit(step)(params, opt, batch)

# sharded
from repro.compat import make_mesh
mesh = make_mesh((2, 4), ('data', 'model'))
p_sh = shardlib.param_shardings(mesh, params)
o_sh = shardlib.opt_state_shardings(mesh, opt)
b_sh = {k: jax.NamedSharding(mesh, jax.sharding.PartitionSpec('data'))
        for k in batch}
params_s = jax.device_put(params, p_sh)
opt_s = jax.device_put(opt, o_sh)
batch_s = {k: jax.device_put(v, b_sh[k]) for k, v in batch.items()}
p2, o2, m2 = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None))(params_s, opt_s,
                                                       batch_s)
print('loss_single', float(m1['loss']))
print('loss_sharded', float(m2['loss']))
dl = abs(float(m1['loss']) - float(m2['loss']))
assert dl < 1e-3, dl
dp = max(float(jnp.abs(a - b).max())
         for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
assert dp < 1e-3, dp
print('OK')
""")
    assert "OK" in out


def test_checkpoint_reshard_across_meshes():
    """Save on a (4,2) mesh, restore onto (2,4): elastic reshape."""
    out = run_sub(r"""
import tempfile, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

tree = {'w': jnp.arange(64.0).reshape(8, 8)}
from repro.compat import make_mesh
mesh_a = make_mesh((4, 2), ('data', 'model'))
mesh_b = make_mesh((2, 4), ('data', 'model'))
sh_a = {'w': NamedSharding(mesh_a, P('data', 'model'))}
sh_b = {'w': NamedSharding(mesh_b, P('data', 'model'))}
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d)
    mgr.save(3, jax.device_put(tree, sh_a))
    restored, step = mgr.restore(tree, shardings=sh_b)
assert step == 3
assert restored['w'].sharding == sh_b['w']
np.testing.assert_array_equal(np.asarray(restored['w']),
                              np.asarray(tree['w']))
print('OK')
""")
    assert "OK" in out


def test_pipeline_parallel_matches_serial():
    """GPipe shard_map pipeline over 4 stages == serial layer application."""
    out = run_sub(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.runtime.pipeline import pipeline_apply

mesh = make_mesh((4,), ('stage',))
rng = np.random.RandomState(0)
n_stages, d = 4, 16
ws = jnp.asarray(rng.standard_normal((n_stages, d, d)) * 0.3,
                 jnp.float32)
x = jnp.asarray(rng.standard_normal((8, d)), jnp.float32)

def layer(w, h):
    return jnp.tanh(h @ w)

serial = x
for i in range(n_stages):
    serial = layer(ws[i], serial)

piped = pipeline_apply(layer, ws, x, mesh, axis='stage', n_microbatches=4)
err = float(jnp.abs(piped - serial).max())
assert err < 1e-5, err
print('OK')
""")
    assert "OK" in out


def test_production_shardings_are_valid_on_8dev():
    """Sharding rules produce loadable shardings for a smoke model on a
    small mesh (divisibility degradation path)."""
    out = run_sub(r"""
import jax, jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.models import build
from repro.compat import make_mesh
from repro.runtime import sharding as shardlib

mesh = make_mesh((2, 4), ('data', 'model'))
for arch in ('deepseek-7b', 'olmoe-1b-7b', 'rwkv6-1.6b', 'zamba2-7b'):
    cfg = get_smoke_config(arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sh = shardlib.param_shardings(mesh, params, fsdp=cfg.fsdp)
    placed = jax.device_put(params, sh)   # raises if any spec is invalid
    assert jax.tree.structure(placed) == jax.tree.structure(params)
print('OK')
""")
    assert "OK" in out


def test_compressed_gradient_allreduce():
    """int8-compressed DP gradient all-reduce via shard_map psum: the
    dequantized mean matches the exact mean within quantization error."""
    out = run_sub(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.optim import compress

mesh = make_mesh((8,), ('data',))
rng = np.random.RandomState(0)
g_global = jnp.asarray(rng.standard_normal((8, 128)), jnp.float32)

def reduce_compressed(g_local):
    q, scale = compress.quantize_tensor(g_local[0])
    g_hat = compress.dequantize_tensor(q, scale)
    return jax.lax.pmean(g_hat, 'data')[None]

fn = shard_map(reduce_compressed, mesh=mesh, in_specs=P('data'),
               out_specs=P('data'), check_replication=False)
out = fn(g_global)
exact = jnp.mean(g_global, axis=0)
err = float(jnp.abs(out[0] - exact).max())
assert err < 0.05, err
print('OK')
""")
    assert "OK" in out
