"""End-to-end behaviour tests for the full system."""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "examples"))


def test_training_reduces_loss(tmp_path):
    """Full driver: 30 steps on the synthetic pipeline reduce the loss."""
    from repro.launch import train as train_mod
    losses = train_mod.main([
        "--arch", "qwen3-1.7b", "--smoke", "--steps", "30", "--batch", "4",
        "--seq-len", "64", "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
        "--log-every", "100"])
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_checkpoint_resume_bit_exact(tmp_path):
    """Fault-tolerance contract: (20 steps) == (10 steps, 'crash', resume
    10 more) -- identical final loss, because data replay is deterministic
    and checkpoints capture (params, opt_state, step)."""
    from repro.launch import train as train_mod
    full = train_mod.main([
        "--arch", "deepseek-7b", "--smoke", "--steps", "20", "--batch", "4",
        "--seq-len", "32", "--log-every", "100"])

    train_mod.main([
        "--arch", "deepseek-7b", "--smoke", "--steps", "10", "--batch", "4",
        "--seq-len", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
        "--log-every", "100"])
    resumed = train_mod.main([
        "--arch", "deepseek-7b", "--smoke", "--steps", "20", "--batch", "4",
        "--seq-len", "32", "--ckpt-dir", str(tmp_path), "--resume",
        "--log-every", "100"])
    np.testing.assert_allclose(resumed[-1], full[-1], rtol=1e-4)


def test_serve_driver_with_taf():
    """Serving driver runs and TAF reports skipped layer-steps."""
    from repro.launch import serve as serve_mod
    gen = serve_mod.main(["--arch", "deepseek-7b", "--smoke", "--batch", "2",
                          "--prompt-len", "8", "--gen", "8",
                          "--taf", "memo(out:2:4:50.0)"])
    assert gen.shape == (2, 8)


def test_greedy_decode_deterministic():
    from repro.configs import get_smoke_config
    from repro.models import build
    from repro.launch import steps as steps_mod
    cfg = dataclasses.replace(get_smoke_config("qwen3-1.7b"), remat=False)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.RandomState(0).randint(
        0, cfg.vocab_size, (2, 8)), jnp.int32)
    outs = []
    for _ in range(2):
        prefill = jax.jit(steps_mod.make_prefill_step(model, 16))
        serve = jax.jit(steps_mod.make_serve_step(model))
        logits, cache = prefill(params, {"tokens": tokens})
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        seq = [np.asarray(tok)]
        for t in range(4):
            tok, _, cache = serve(params, cache, tok, jnp.int32(8 + t))
            seq.append(np.asarray(tok))
        outs.append(np.stack(seq))
    np.testing.assert_array_equal(outs[0], outs[1])


def test_paper_qualitative_claims():
    """Validate the paper's core claims on the app suite (EXPERIMENTS.md
    section Paper-validation):
      TAF reaches high approx fractions at <10% error on Blackscholes;
      MiniFE-class iterative implicit solvers blow up under AC."""
    from apps import blackscholes, minife_cg
    from repro.core import (ApproxSpec, IACTParams, Level, TAFParams,
                            Technique)
    from repro.core.harness import mape

    app = blackscholes.make_app(n_elements=256, steps=48)
    exact = app.exact()
    taf = app.run(ApproxSpec(Technique.TAF, Level.ELEMENT,
                             taf=TAFParams(3, 64, 0.5)))
    ia = app.run(ApproxSpec(Technique.IACT, Level.ELEMENT,
                            iact=IACTParams(4, 0.5, 0)))
    taf_err = mape(exact.qoi, taf.qoi)
    ia_err = mape(exact.qoi, ia.qoi)
    assert taf_err < 0.10 and ia_err < 0.10
    assert taf.approx_fraction > 0.5

    cg = minife_cg.make_app(n=32)
    cg_exact = cg.exact()
    cg_taf = cg.run(ApproxSpec(Technique.TAF, Level.ELEMENT,
                               taf=TAFParams(3, 8, 0.5)))
    cg_err = mape(cg_exact.qoi, cg_taf.qoi)
    assert not np.isfinite(cg_err) or cg_err > 0.10, \
        "MiniFE-class solvers must amplify AC error (paper section 4, MiniFE)"


def test_dryrun_single_cell_subprocess():
    """The dry-run entrypoint works end-to-end for one cheap cell (the full
    matrix runs via `python -m repro.launch.dryrun --all`; results for all
    80 cells are committed under results/)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "olmoe-1b-7b", "--shape", "decode_32k", "--single-pod"],
        env=env, capture_output=True, text=True, timeout=900, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    assert '"status": "ok"' in out.stdout


def test_dryrun_results_complete():
    """All 80 dry-run cells exist and none FAILED (40 cells x 2 meshes:
    the brief's multi-pod requirement)."""
    import glob
    import json
    d = os.path.join(REPO, "results", "dryrun")
    if not os.path.isdir(d):
        pytest.skip("dry-run matrix not yet generated")
    recs = []
    for p in glob.glob(os.path.join(d, "*.json")):
        with open(p) as f:
            recs.append(json.load(f))
    assert len(recs) == 80
    assert sum(r["status"] == "FAILED" for r in recs) == 0
    ok = sum(r["status"] == "ok" for r in recs)
    skipped = sum(r["status"] == "skipped" for r in recs)
    assert ok == 64 and skipped == 16  # 8 full-attn archs x long_500k x 2
