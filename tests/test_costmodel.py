"""Tests for the analytical cost/error predictor (repro.analysis.cost)
against MEASURED sweeps: rank correlation, bound conservatism, pruning
semantics, and (via hypothesis) knob monotonicity of the closed forms."""
import os
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, REPO)                            # benchmarks package
sys.path.insert(0, os.path.join(REPO, "examples"))  # apps package

from repro.analysis.cost import (AppCostModel, CostVector, Site,
                                 filter_specs, ladder_model, trace_cost)
from repro.core.harness import spec_from_dict, sweep, taf_grid
from repro.core.types import (ApproxSpec, Level, PerforationKind,
                              PerforationParams, TAFParams, Technique)


def _model():
    return ladder_model()


# ------------------------------------------------ measured validation

def test_blackscholes_rank_correlation_and_bounds():
    """The predictor must rank the blackscholes TAF grid like the measured
    structural speedups do, and its error bound must dominate every
    measured error (conservatism contract)."""
    from benchmarks import costmodel

    app = costmodel._make_app("blackscholes")
    model = costmodel.blackscholes_model(
        **costmodel._WORKLOADS["blackscholes"])
    grid = costmodel._validation_grid("blackscholes")
    recs = sweep(app, grid, repeats=1)
    preds = [model.predict(costmodel._spec_of(r)) for r in recs]

    rho = costmodel.spearman([p.speedup for p in preds],
                             [r.modeled_speedup for r in recs])
    assert rho >= 0.9, (rho, [p.speedup for p in preds],
                        [r.modeled_speedup for r in recs])
    for p, r in zip(preds, recs):
        assert p.error_bound >= r.error, (r.spec, p.error_bound, r.error)


def test_ffn_band_recovers_committed_front():
    """Acceptance statistic: the predicted front band (<= 1/5 of the grid)
    measured alone must recover the committed full-grid front's
    hypervolume within FRONT_TOLERANCE, and the predictor must rank the
    band like the measured structural speedups."""
    from apps import approx_ffn
    from benchmarks import approx_ffn_sweep, costmodel
    from repro.core import pareto

    grid = approx_ffn_sweep._grid()
    model = costmodel.ffn_model()
    budget = len(grid) // 5
    band = model.select_band(grid, budget=budget)
    assert 0 < len(band) <= budget

    app = approx_ffn.make_app(substrate="pallas")
    recs = sweep(app, band, repeats=1)
    fs = pareto.front_summary(recs, use_modeled=True)

    import json
    base = os.path.join(REPO, "benchmarks", "baselines", "BENCH_ffn.json")
    with open(base) as f:
        base_hv = json.load(f)["front"]["hypervolume"]
    assert fs["hypervolume"] >= costmodel.FRONT_TOLERANCE * base_hv

    rho = costmodel.spearman(
        [model.predict(costmodel._spec_of(r)).speedup for r in recs],
        [r.modeled_speedup for r in recs])
    assert rho >= 0.9, rho


# ------------------------------------------------ pruning semantics

def test_filter_specs_keeps_precise_and_unmodeled():
    """NONE specs and specs for techniques the model has no site for are
    never pruned -- the predictor only drops what it can actually model."""
    model = AppCostModel(
        name="taf_only", total=CostVector(4096.0, 8192.0),
        sites={Technique.TAF: Site(region=CostVector(16.0, 32.0),
                                   invocations=256.0)})
    specs = [ApproxSpec(Technique.NONE),
             ApproxSpec(Technique.IACT),                  # unmodeled
             ApproxSpec(Technique.TAF,
                        taf=TAFParams(2, 4, 0.5))]
    kept, dropped = filter_specs(model, specs, min_speedup=10.0)
    assert specs[0] in kept and specs[1] in kept
    assert specs[2] in dropped                            # can't reach 10x


def test_select_band_respects_budget():
    model = _model()
    grid = taf_grid(h_sizes=(2, 3), p_sizes=(2, 4),
                    thresholds=(0.05, 0.2, 1.0), levels=(Level.ELEMENT,))
    band = model.select_band(grid, budget=4)
    assert len(band) <= 4


def test_oversized_iact_table_predicts_sub_1x():
    """The A006 signal: an iACT rung whose table lookups cost more than
    the region they replace predicts a slowdown."""
    from repro.core.types import IACTParams
    model = _model()
    bad = ApproxSpec(Technique.IACT,
                     iact=IACTParams(table_size=4096, threshold=0.2))
    ok = ApproxSpec(Technique.IACT,
                    iact=IACTParams(table_size=2, threshold=0.2))
    assert model.predict(bad).speedup <= 1.0
    assert model.predict(ok).speedup > 1.0


def test_sweep_predict_prunes_and_autotune_threads(tmp_path):
    """harness.sweep(predict=...) measures only the kept specs."""
    from benchmarks import costmodel

    app = costmodel._make_app("blackscholes")
    model = costmodel.blackscholes_model(
        **costmodel._WORKLOADS["blackscholes"])
    grid = costmodel._validation_grid("blackscholes")
    # an impossible speedup floor prunes every modeled spec
    recs = sweep(app, grid, repeats=1, predict=model,
                 predict_min_speedup=1e9)
    assert recs == []
    kept = sweep(app, grid, repeats=1, predict=model)
    assert len(kept) == len(grid)       # all rungs are plausible here


# ---------------------------------------- closed-form monotonicity
# (deterministic grids; the hypothesis variants with randomized knob
# pairs live in tests/test_properties.py, which skips when hypothesis
# is not installed)

class TestMonotonicity:
    def test_perforation_speedup_monotone_in_fraction(self):
        model = _model()
        spds = [model.predict(ApproxSpec(
            Technique.PERFORATION,
            perforation=PerforationParams(kind=PerforationKind.INI,
                                          fraction=f))).speedup
                for f in (0.1, 0.25, 0.5, 0.75, 0.9)]
        assert all(b >= a - 1e-12 for a, b in zip(spds, spds[1:])), spds

    def test_taf_error_bound_monotone_in_threshold(self):
        model = _model()
        bounds = [model.predict(ApproxSpec(
            Technique.TAF, taf=TAFParams(2, 4, t))).error_bound
                  for t in (0.01, 0.05, 0.2, 1.0, 5.0)]
        assert all(b >= a - 1e-12 for a, b in zip(bounds, bounds[1:]))

    def test_taf_speedup_monotone_in_threshold(self):
        model = _model()
        spds = [model.predict(ApproxSpec(
            Technique.TAF, taf=TAFParams(2, 4, t))).speedup
                for t in (0.01, 0.05, 0.2, 1.0, 5.0)]
        assert all(b >= a - 1e-12 for a, b in zip(spds, spds[1:]))

    def test_predictions_finite_and_nonnegative(self):
        model = _model()
        for t in (0.01, 0.5, 5.0):
            p = model.predict(ApproxSpec(Technique.TAF,
                                         taf=TAFParams(2, 4, t)))
            assert p.error_bound >= 0.0
            assert np.isfinite(p.error_bound) and np.isfinite(p.speedup)
            assert 0.0 <= p.skip_fraction <= 1.0
