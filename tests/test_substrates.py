"""Substrate tests: data determinism, optimizer, checkpoint lifecycle,
straggler/preemption, elastic mesh math."""
import os
import time

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticLM
from repro.optim import adamw, compress
from repro.optim.schedule import warmup_cosine
from repro.runtime.elastic import accum_steps_for, best_mesh_shape
from repro.runtime.straggler import PreemptionGuard, StepMonitor


class TestData:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        a = SyntheticLM(cfg).batch(7)
        b = SyntheticLM(cfg).batch(7)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_sharding_partitions_batch(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=8)
        ds = SyntheticLM(cfg)
        full = ds.batch(3)
        parts = [ds.batch(3, shard_index=i, num_shards=4)["tokens"]
                 for i in range(4)]
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        b = SyntheticLM(cfg).batch(0)
        # token t's label is token t+1 of the underlying sequence
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])

    def test_learnable_structure(self):
        """Pattern-bank corpus: bigram entropy must be far below uniform."""
        cfg = DataConfig(vocab_size=64, seq_len=64, global_batch=16,
                         n_patterns=8, pattern_len=16)
        b = SyntheticLM(cfg).batch(0)
        toks = b["tokens"].ravel()
        pairs = set(zip(toks[:-1].tolist(), toks[1:].tolist()))
        assert len(pairs) < 0.2 * 64 * 64


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw.init(params)
        for _ in range(200):
            grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = adamw.update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.05

    def test_grad_clipping(self):
        g = {"w": jnp.asarray([3.0, 4.0])}  # norm 5
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert abs(float(norm) - 5.0) < 1e-5
        np.testing.assert_allclose(np.asarray(clipped["w"]),
                                   [0.6, 0.8], rtol=1e-5)

    def test_schedule_warmup_and_decay(self):
        assert float(warmup_cosine(0, warmup_steps=10, total_steps=100)) == 0
        mid = float(warmup_cosine(10, warmup_steps=10, total_steps=100))
        assert abs(mid - 1.0) < 1e-5
        end = float(warmup_cosine(100, warmup_steps=10, total_steps=100))
        assert end <= 0.11


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
                "b": {"c": jnp.ones((4,), jnp.int32)}}
        mgr.save(5, tree)
        restored, step = mgr.restore(tree)
        assert step == 5
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.asarray(tree["a"]))

    def test_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep_n=2)
        tree = {"a": jnp.zeros((2,))}
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        assert mgr.all_steps() == [3, 4]

    def test_async_save(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=True)
        tree = {"a": jnp.arange(1000.0)}
        mgr.save(1, tree)
        mgr.wait()
        restored, step = mgr.restore(tree)
        assert step == 1

    def test_restore_with_target_sharding(self, tmp_path):
        """Elastic path: restore device_puts with the TARGET sharding."""
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import make_mesh
        mesh = make_mesh((1,), ("data",))
        mgr = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(8.0)}
        mgr.save(1, tree)
        sh = {"w": NamedSharding(mesh, P("data"))}
        restored, _ = mgr.restore(tree, shardings=sh)
        assert restored["w"].sharding == sh["w"]

    def test_shape_mismatch_rejected(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.zeros((4,))})
        with pytest.raises(ValueError):
            mgr.restore({"w": jnp.zeros((5,))})

    def test_atomic_publish_no_tmp_left(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(1, {"w": jnp.zeros((4,))})
        assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


class TestStraggler:
    def test_flags_slow_step(self):
        mon = StepMonitor(window=8, threshold=2.0, warmup_steps=2)
        for _ in range(6):
            assert mon.record(1.0) is None
        ev = mon.record(5.0)
        assert ev is not None and ev.slowdown > 2.0

    def test_straggling_phase_does_not_mask_itself(self):
        mon = StepMonitor(window=8, threshold=2.0, warmup_steps=2)
        for _ in range(6):
            mon.record(1.0)
        events = [mon.record(5.0) for _ in range(4)]
        assert all(e is not None for e in events)

    def test_per_host_attribution(self):
        mon = StepMonitor(threshold=2.0)
        evs = mon.record_host_durations({0: 1.0, 1: 1.1, 2: 9.0, 3: 0.9})
        assert len(evs) == 1 and evs[0].host == 2

    def test_preemption_guard_flag(self):
        g = PreemptionGuard(install=False)
        assert not g.should_stop
        g.trigger()
        assert g.should_stop


class TestElastic:
    def test_best_mesh_prefers_tp_degree(self):
        assert best_mesh_shape(256, model_parallel=16) == (16, 16)
        assert best_mesh_shape(512, model_parallel=16) == (32, 16)

    def test_degrades_tp_when_needed(self):
        # 24 devices: 16 does not divide -> degrade to 8
        assert best_mesh_shape(24, model_parallel=16) == (3, 8)

    def test_accum_keeps_global_batch(self):
        assert accum_steps_for(256, per_device_batch=2, n_data_shards=16) == 8
        assert accum_steps_for(256, per_device_batch=2, n_data_shards=8) == 16
        with pytest.raises(ValueError):
            accum_steps_for(100, per_device_batch=3, n_data_shards=7)


class TestCompression:
    def test_int8_wire_format(self):
        g = jnp.asarray(np.random.RandomState(0).standard_normal((32,)))
        q, scale = compress.quantize_tensor(g)
        assert q.dtype == jnp.int8
        assert float(jnp.abs(q).max()) <= 127

    def test_ef_reduces_bias_over_steps(self):
        """With EF, the accumulated estimate converges to the true sum; the
        naive (no-EF) quantizer keeps a bias."""
        rng = np.random.RandomState(0)
        g = jnp.asarray(rng.standard_normal((64,)) * 1e-4 + 1e-3)
        ef = compress.init_ef(g)
        acc_ef = jnp.zeros_like(g)
        acc_naive = jnp.zeros_like(g)
        for _ in range(50):
            (_, _), g_hat, ef = compress.compress_grads(g, ef)
            acc_ef = acc_ef + g_hat
            q, s = compress.quantize_tensor(g)
            acc_naive = acc_naive + compress.dequantize_tensor(q, s)
        true = g * 50
        err_ef = float(jnp.abs(acc_ef - true).max())
        err_naive = float(jnp.abs(acc_naive - true).max())
        assert err_ef <= err_naive + 1e-9
