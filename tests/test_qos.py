"""QoS control plane tests (docs/qos.md): policy ladder construction and
selection, canary monitor parity with the offline metrics, deterministic
feedback control incl. the hard precise fallback, per-tick lane grouping,
and the closed loop through the continuous-batching serving engine."""
import dataclasses
import json

import numpy as np
import pytest

import jax

from repro import qos
from repro.core import batching
from repro.core.harness import Record, mape, mcr, sweep
from repro.core.types import (ApproxSpec, Level, PerforationKind,
                              PerforationParams, TAFParams, Technique)


def taf_record(thresh, error, speedup, modeled=None, h=2, p=4):
    spec = {"technique": "taf", "level": "block", "hSize": h, "pSize": p,
            "thresh": thresh}
    return Record(app="toy", spec=spec, error=error, speedup=speedup,
                  modeled_speedup=modeled if modeled is not None else speedup,
                  approx_fraction=0.5, wall_time_s=1.0, exact_time_s=1.0,
                  extra={})


LADDER_RECORDS = [
    taf_record(0.05, 0.002, 1.2),
    taf_record(0.10, 0.010, 1.5),
    taf_record(0.20, 0.040, 2.2),
    taf_record(0.40, 0.200, 3.0),
    taf_record(0.15, 0.050, 1.1),   # dominated (more error, less speedup)
    taf_record(0.30, 0.300, 0.8),   # slower than precise: never a rung
]


def make_policy(**kw):
    return qos.QosPolicy.from_records(LADDER_RECORDS, **kw)


# ------------------------------------------------------------------ policy

def test_ladder_starts_precise_and_ascends():
    pol = make_policy()
    assert pol.entries[0].precise
    assert pol.entries[0].error == 0.0 and pol.entries[0].speedup == 1.0
    errs = [e.error for e in pol.entries]
    spds = [e.speedup for e in pol.entries]
    assert errs == sorted(errs) and spds == sorted(spds)
    # dominated + slower-than-precise rows never become rungs
    assert len(pol) == 5
    assert all(e.speedup > 1.0 for e in pol.entries[1:])


def test_select_is_best_speedup_under_error():
    pol = make_policy()
    assert pol.select(qos.QosTarget(0.05)) == 3     # err 0.04 < 0.05
    assert pol.select(0.011) == 2                   # strict: 0.010 < 0.011
    assert pol.select(0.010) == 1                   # 0.010 not < 0.010
    assert pol.select(1e-9) == 0                    # nothing fits -> precise
    choice = pol.choose(0.05)
    assert choice.index == 3
    json.dumps(choice.to_json())  # serializable deployment artifact


def test_ladder_prunes_dominated_entries_on_direct_construction():
    """The ladder invariant holds on EVERY construction path: a merged or
    hand-edited entry list with mutually-dominated rows is pruned, so the
    controller can never loosen onto a strictly-worse rung."""
    worse = qos.PolicyEntry(spec={"technique": "taf", "level": "block",
                                  "hSize": 2, "pSize": 4, "thresh": 0.15},
                            error=0.02, speedup=1.5, modeled_speedup=1.5)
    better = qos.PolicyEntry(spec={"technique": "taf", "level": "block",
                                   "hSize": 2, "pSize": 4, "thresh": 0.05},
                             error=0.01, speedup=2.0, modeled_speedup=2.0)
    pol = qos.QosPolicy([worse, better])        # worse: more error, slower
    assert [e.spec_hash for e in pol.entries[1:]] == [better.spec_hash]
    # and load() re-normalizes too
    path_free = qos.QosPolicy(pol.entries)
    assert len(path_free) == len(pol)


def test_policy_metric_mismatch_raises():
    pol = make_policy(metric="mape")
    with pytest.raises(ValueError, match="metric"):
        pol.select(qos.QosTarget(0.1, metric="mcr"))


def test_target_rejects_zero_and_negative_bounds():
    # est >= max_error is the violation test, so a 0 bound would flag
    # even bit-exact precise canaries (error 0.0) as violations
    for bad in (0.0, -0.1):
        with pytest.raises(ValueError, match="max_error"):
            qos.QosTarget(bad)
    qos.QosTarget(1e-12)                  # tiny-but-positive is fine


def test_policy_save_load_roundtrip(tmp_path):
    pol = make_policy(app="toy", use_modeled=True)
    path = str(tmp_path / "policy.json")
    pol.save(path)
    back = qos.QosPolicy.load(path)
    assert [e.to_json() for e in back.entries] == \
        [e.to_json() for e in pol.entries]
    assert (back.metric, back.app, back.use_modeled) == ("mape", "toy", True)
    assert back.select(0.05) == pol.select(0.05)


def test_policy_from_db_scopes_app(tmp_path):
    db = str(tmp_path / "db.json")
    rows = [r.to_json() for r in LADDER_RECORDS]
    rows.append(dict(rows[0], app="other", error=9.9))
    with open(db, "w") as f:
        json.dump(rows, f)
    pol = qos.QosPolicy.from_db(db, app="toy")
    assert all(e.error < 9.0 for e in pol.entries)
    with pytest.raises(ValueError, match="no rows"):
        qos.QosPolicy.from_db(db, app="missing")


def test_validate_ladder_knobs_rejects_structural_specs():
    skip_spec = {"technique": "perfo", "level": "element", "kind": "small",
                 "skip": 4, "fraction": 0.25, "herded": True}
    bad = qos.QosPolicy([qos.PolicyEntry(spec=skip_spec, error=0.01,
                                         speedup=2.0, modeled_speedup=2.0)])
    with pytest.raises(ValueError, match="traced quality knob"):
        qos.validate_ladder_knobs(bad)
    qos.validate_ladder_knobs(make_policy())  # knob-backed ladder passes


def test_spec_knob():
    assert qos.spec_knob(None) is None
    assert qos.spec_knob(ApproxSpec()) is None
    taf = ApproxSpec(Technique.TAF, Level.BLOCK, taf=TAFParams(2, 4, 0.3))
    assert qos.spec_knob(taf) == pytest.approx(0.3)


# ----------------------------------------------------------------- monitor

def test_monitor_error_matches_offline_metrics_bitwise():
    rng = np.random.RandomState(0)
    mon = qos.QualityMonitor(metric="mape", sample_fraction=1.0, window=8)
    errs = []
    for _ in range(5):
        a, b = rng.randn(3, 7), rng.randn(3, 7)
        err = mon.observe(a, b)
        assert err == mape(a, b)          # bit-for-bit: SAME function
        errs.append(err)
    assert mon.estimate() == float(np.mean(np.asarray(errs[-8:], np.float64)))

    mon2 = qos.QualityMonitor(metric="mcr", sample_fraction=1.0, window=8)
    x = rng.randint(0, 5, 20)
    y = rng.randint(0, 5, 20)
    assert mon2.observe(x, y) == mcr(x, y)


def test_monitor_sampling_deterministic_and_exact_rate():
    mon = qos.QualityMonitor(sample_fraction=0.25, window=4)
    hits = [i for i in range(100) if mon.should_sample()]
    assert len(hits) == 25
    gaps = np.diff(hits)
    assert set(gaps.tolist()) == {4}      # floor-crossings: evenly spaced
    mon2 = qos.QualityMonitor(sample_fraction=0.25, window=4)
    assert [i for i in range(100) if mon2.should_sample()] == hits
    # edge rates
    always = qos.QualityMonitor(sample_fraction=1.0, window=4)
    assert all(always.should_sample() for _ in range(10))
    never = qos.QualityMonitor(sample_fraction=0.0, window=4)
    assert not any(never.should_sample() for _ in range(10))


def test_monitor_window_and_drift():
    mon = qos.QualityMonitor(sample_fraction=1.0, window=4)
    for e in (1.0, 1.0, 1.0, 1.0):
        mon.inject(e)
    assert mon.estimate() == 1.0
    assert mon.drift() == 0.0             # flat window: zero RSD
    mon.inject(9.0)                       # evicts one 1.0 (window=4)
    st = mon.stats()
    assert st.window_size == 4 and st.samples == 5
    assert st.estimate == float(np.mean([1.0, 1.0, 1.0, 9.0]))
    assert st.drift > 0.5                 # spiky window: high RSD
    assert st.mean_error == float(np.mean([1.0] * 4 + [9.0]))
    assert st.last == 9.0
    # everything above came through the fault hook: genuine mean excludes it
    assert st.injected == 5 and st.genuine_mean_error == 0.0
    mon.observe(np.ones(4), np.full(4, 1.5))     # one genuine pair (err 0.5)
    st2 = mon.stats()
    assert st2.injected == 5 and st2.samples == 6
    assert st2.genuine_mean_error == 0.5


# -------------------------------------------------------------- controller

def ctl_config(**kw):
    base = dict(headroom=0.8, backoff=0.5, min_samples=2, hold_ticks=2,
                fallback_hold=3, drift_limit=10.0)
    base.update(kw)
    return qos.ControllerConfig(**base)


def run_loop(errors_per_update, target=0.05, **cfg_kw):
    """Drive a controller with a scripted canary stream; returns it."""
    pol = make_policy()
    mon = qos.QualityMonitor(sample_fraction=1.0, window=4)
    ctl = qos.QosController(pol, mon, target, ctl_config(**cfg_kw))
    for e in errors_per_update:
        if e is not None:
            mon.inject(e)
        ctl.update()
    return ctl


def test_controller_loosen_recovers_to_offline_choice():
    """Pressure tightens off the offline rung; sustained headroom loosens
    back -- but with the offline prior trusted (default), never onto a rung
    whose sweep-time error already violates the bound."""
    stream = [0.045, 0.045] + [0.0005] * 10
    ctl = run_loop(stream)
    events = [p.event for p in ctl.trajectory]
    assert events[0] == "warmup"          # min_samples gate
    assert "tighten" in events and "loosen" in events
    assert ctl.index == 3                 # back AT the offline select choice
    assert max(p.index for p in ctl.trajectory) == 3   # never beyond it
    # hold_ticks hysteresis: no two moves closer than 2 updates
    moves = [p.step for p in ctl.trajectory
             if p.event in ("loosen", "tighten")]
    assert all(b - a >= 2 for a, b in zip(moves, moves[1:]))


def test_controller_explores_past_offline_prior_when_told():
    explorer = run_loop([0.0005] * 8, trust_offline=False)
    assert explorer.index == len(explorer.policy) - 1
    trusting = run_loop([0.0005] * 8)     # default: pinned at the prior
    assert trusting.index == 3
    assert all(p.event != "loosen" for p in trusting.trajectory)


def test_controller_tightens_under_pressure():
    # start at rung 3 (select 0.05 -> err 0.04), push estimate into the
    # headroom band (0.8*0.05=0.04 < est < 0.05): steps ONE rung precise
    ctl = run_loop([0.045] * 4)
    assert ctl.trajectory[0].event == "warmup"
    tighten = [p for p in ctl.trajectory if p.event == "tighten"]
    assert tighten and tighten[0].index == 2
    assert ctl.violations == 0            # never a hard violation


def test_controller_hard_fallback_and_recovery():
    # scripted spike: clean, VIOLATION, then clean canaries again
    stream = [0.001, 0.001, 10.0, 0.0, 0.0, 0.0, 0.0, None, None, None,
              None, None, None]
    ctl = run_loop(stream, target=0.05)
    events = [p.event for p in ctl.trajectory]
    ifall = events.index("fallback")
    assert ctl.trajectory[ifall].index == 0          # hard: straight to 0
    # pinned precise through the cooldown that follows the violation
    assert "cooldown" in events[ifall:]
    for p in ctl.trajectory[ifall:ifall + 4]:
        assert p.index == 0
    assert ctl.violations >= 1
    assert 0.0 < ctl.fallback_rate < 1.0
    # deterministic: replaying the stream reproduces the trajectory exactly
    ctl2 = run_loop(stream, target=0.05)
    assert ctl2.trajectory == ctl.trajectory


def test_controller_drift_gate_blocks_loosening():
    # alternating errors: tiny mean (far under backoff) but huge RSD --
    # the drift gate must refuse to loosen on an estimate that noisy
    # (trust_offline off so the drift gate is the ONLY thing blocking)
    stream = [0.0001, 0.004] * 6
    ctl = run_loop(stream, target=0.05, drift_limit=0.5,
                   trust_offline=False)
    assert all(p.event != "loosen" for p in ctl.trajectory)


# ------------------------------------------------------------- group_lanes

def test_group_lanes_partitions_by_structure():
    t1 = ApproxSpec(Technique.TAF, Level.BLOCK, taf=TAFParams(2, 4, 0.1))
    t2 = ApproxSpec(Technique.TAF, Level.BLOCK, taf=TAFParams(2, 4, 0.3))
    t3 = ApproxSpec(Technique.TAF, Level.BLOCK, taf=TAFParams(3, 4, 0.2))
    lanes = [t1, None, t2, ApproxSpec(), t3]
    groups, precise = batching.group_lanes(lanes)
    assert precise == [1, 3]
    key12 = batching.static_key(t1)
    assert groups[key12] == ([0, 2], [pytest.approx(0.1),
                                      pytest.approx(0.3)])
    assert groups[batching.static_key(t3)][0] == [4]  # singletons kept


def test_group_lanes_rejects_structural_knobless_spec():
    skip = ApproxSpec(Technique.PERFORATION, perforation=PerforationParams(
        kind=PerforationKind.SMALL, skip=4))
    with pytest.raises(ValueError, match="traced quality knob"):
        batching.group_lanes([skip])


# ------------------------------------------------------------------ engine

def test_qos_engine_plan_tick_strictest_live_rung():
    pol = make_policy()
    eng = qos.QosEngine(pol, {"default": 0.05, "batch": 1.0},
                        sample_fraction=0.0)
    assert eng.controller("default").index == 3
    assert eng.controller("batch").index == 4
    assert eng.controller("unknown-class").index == 3   # falls to default
    plan = eng.plan_tick(["batch", "default", "batch"])
    assert plan.index == 3                               # strictest live
    assert plan.knob == pytest.approx(
        pol.entries[3].spec["thresh"])
    plan_b = eng.plan_tick(["batch"])
    assert plan_b.index == 4
    assert plan_b.n_groups == 1
    # precise-only plan: no knob
    tight = qos.QosEngine(pol, 1e-9, sample_fraction=0.0)
    assert tight.plan_tick(["default"]).knob is None


def test_qos_engine_requires_default_class():
    with pytest.raises(ValueError, match="default"):
        qos.QosEngine(make_policy(), {"interactive": 0.05})


def test_plan_tick_regime_change_preserves_violation_evidence():
    """The knob-regime window reset must never discard VIOLATION evidence:
    a fault injected between ticks survives a simultaneous class-mix
    change, so the very next update still fires the hard fallback."""
    eng = qos.QosEngine(make_policy(), {"default": 0.05, "batch": 1.0},
                        sample_fraction=1.0, window=4,
                        config=ctl_config(min_samples=1, hold_ticks=1))
    eng.plan_tick(["batch"])              # actuate batch's (loosest) rung
    eng.monitor.inject(10.0)              # fault lands before the mix flips
    plan = eng.plan_tick(["default", "batch"])   # strictest rung changes
    assert plan.index == eng.controllers["default"].index
    assert eng.monitor.window_size == 1   # evidence kept, not reset
    eng.update(["default", "batch"])
    for cls in ("default", "batch"):
        assert eng.controllers[cls].violations == 1
    # sub-violation evidence IS dropped on a regime change (documented)
    eng2 = qos.QosEngine(make_policy(), {"default": 0.05, "batch": 1.0},
                         sample_fraction=1.0, window=4,
                         config=ctl_config(min_samples=1, hold_ticks=1))
    eng2.plan_tick(["batch"])
    eng2.monitor.inject(0.001)            # headroom, not a violation
    eng2.plan_tick(["default", "batch"])
    assert eng2.monitor.window_size == 0


def test_qos_engine_concurrent_violation_not_swallowed():
    """Evidence is snapshotted once per update: the first class's fallback
    resets the shared window, but the OTHER live classes still judge the
    same tick's estimate -- a concurrent violation of their bound must
    register, whatever the class iteration order."""
    eng = qos.QosEngine(make_policy(), {"default": 0.05, "batch": 1.0},
                        sample_fraction=1.0, window=4,
                        config=ctl_config(min_samples=1, hold_ticks=1))
    eng.monitor.inject(5.0)               # violates BOTH bounds
    eng.update(["default", "batch"])
    for cls in ("default", "batch"):
        ctl = eng.controllers[cls]
        assert ctl.violations == 1 and ctl.index == 0
        assert ctl.trajectory[-1].event == "fallback"


def test_qos_engine_observe_decode_metrics():
    pol_mcr = qos.QosPolicy(make_policy().entries, metric="mcr")
    eng = qos.QosEngine(pol_mcr, 0.5, sample_fraction=1.0)
    logits_a = np.array([[0.1, 0.9], [0.8, 0.2]])
    logits_b = np.array([[0.2, 0.8], [0.1, 0.9]])   # one argmax differs
    err = eng.observe_decode(logits_a, logits_b)
    assert err == mcr(np.argmax(logits_a, -1), np.argmax(logits_b, -1))
    assert err == 0.5


# --------------------------------------------- closed loop through serving

@pytest.fixture(scope="module")
def decode_setup():
    from repro.models import build
    cfg = qos.default_decode_cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def serving_policy(metric="mape"):
    """Knob-backed ladder matching default_decode_cfg's structural params
    (hSize=2, pSize=4) without paying for a calibration sweep."""
    return qos.QosPolicy.from_records(
        [taf_record(0.06, 0.02, 1.5), taf_record(0.3, 0.08, 3.0)],
        use_modeled=True, metric=metric)


def _requests(cfg, n, gen=6, cls="default"):
    rng = np.random.RandomState(7)
    from repro.serving import Request
    return [Request(uid=i,
                    prompt=rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=gen, qos_class=cls) for i in range(n)]


def test_serving_closed_loop_backs_off_and_recompiles_nothing(decode_setup):
    """The acceptance demo: seeded trace, injected error spike -> the
    controller provably falls back to precise (threshold AND in-flight
    predictions zeroed) and the end-to-end measured canary error stays
    under the configured target; knob moves never recompile the step."""
    from repro.serving import ServingEngine
    cfg, model, params = decode_setup
    # mcr canaries are bounded by 1.0, so a bound of 2.0 is unreachable by
    # genuine traffic: the injected fault is the ONLY violation source and
    # the trajectory is deterministic
    target = 2.0
    engine_qos = qos.QosEngine(
        serving_policy(metric="mcr"), target, sample_fraction=1.0, window=4,
        config=ctl_config(min_samples=1, hold_ticks=1, fallback_hold=3))
    eng = ServingEngine(model, params, slots=2, max_len=32, prompt_len=8,
                        qos=engine_qos)
    for r in _requests(cfg, 2, gen=10):
        eng.submit(r)
    ctl = engine_qos.controllers["default"]
    for _ in range(6):
        eng.tick()
    assert ctl.index > 0, "under a loose bound the approx knob stays open"
    engine_qos.monitor.inject(10.0)               # deterministic spike
    eng.tick()
    assert ctl.index == 0                         # hard precise fallback
    assert ctl.trajectory[-1].event == "fallback"
    eng.tick()                                    # fallback knob actuated
    taf = eng.cache["taf"]
    assert float(np.max(np.asarray(taf["threshold"]))) == 0.0
    assert int(np.asarray(taf["remaining"]).sum()) == 0
    stats = eng.run_until_drained()
    assert stats.finished == 2
    assert stats.canary_ticks == stats.ticks      # sample_fraction=1.0
    assert stats.knob_moves >= 2                  # opened, then fell back
    # ONE compiled serve step despite every knob move (traced threshold)
    assert eng._serve._cache_size() == 1
    # end-to-end measured error under the bound (spike included via mean)
    assert engine_qos.summary()["mean_error"] < target


def test_serving_precise_canaries_are_bit_exact(decode_setup):
    """With the knob pinned precise, the approx decode step and the exact
    oracle are the SAME computation: every canary error is exactly 0.0."""
    from repro.serving import ServingEngine
    cfg, model, params = decode_setup
    engine_qos = qos.QosEngine(serving_policy(), 1e-9, sample_fraction=1.0,
                               window=8)
    eng = ServingEngine(model, params, slots=2, max_len=32, prompt_len=8,
                        qos=engine_qos)
    for r in _requests(cfg, 2, gen=5):
        eng.submit(r)
    stats = eng.run_until_drained()
    ms = engine_qos.monitor.stats()
    assert stats.canary_ticks > 0 and ms.samples == stats.canary_ticks
    assert ms.mean_error == 0.0 and ms.estimate == 0.0
    assert stats.taf_skipped == 0


def test_serving_qos_requires_taf_decode(decode_setup):
    from repro.models import build
    from repro.serving import ServingEngine
    cfg, _, params = decode_setup
    plain = build(dataclasses.replace(cfg, approx_decode=ApproxSpec()))
    with pytest.raises(ValueError, match="decode-time TAF"):
        ServingEngine(plain, params, qos=qos.QosEngine(
            serving_policy(), 0.1))


def test_serving_qos_rejects_structurally_mismatched_ladder(decode_setup):
    """The online actuator writes only the threshold scalar, so a ladder
    calibrated under different TAF structural params (a different
    stability detector) must be rejected up front."""
    from repro.serving import ServingEngine
    cfg, model, params = decode_setup      # model runs (hSize=2, pSize=4)
    mismatched = qos.QosPolicy.from_records(
        [taf_record(0.1, 0.02, 1.5, h=5, p=9)], use_modeled=True)
    with pytest.raises(ValueError, match="structural"):
        ServingEngine(model, params, qos=qos.QosEngine(mismatched, 0.1))


def test_serving_latency_stats(decode_setup):
    from repro.serving import ServingEngine
    cfg, model, params = decode_setup
    eng = ServingEngine(model, params, slots=2, max_len=32, prompt_len=8)
    reqs = _requests(cfg, 4, gen=4)
    for r in reqs:
        eng.submit(r)
    stats = eng.run_until_drained()
    assert stats.finished == 4
    assert len(stats.ttft_s) == 4 and len(stats.latency_s) == 4
    lat = stats.latency_summary()
    assert lat["requests"] == 4
    assert lat["ttft_p99_s"] >= lat["ttft_p50_s"] >= 0.0
    assert lat["latency_p99_s"] >= lat["latency_p50_s"] >= 0.0
    # latency includes queueing: never below time-to-first-token
    assert all(l >= t for l, t in zip(sorted(stats.latency_s),
                                      sorted(stats.ttft_s)))
    fresh = ServingEngine(model, params, slots=2, max_len=32, prompt_len=8)
    assert fresh.stats.latency_summary()["ttft_p50_s"] is None


# -------------------------------------------------------------- calibration

def test_decode_calibration_sweeps_through_harness(decode_setup, tmp_path):
    cfg, _, _ = decode_setup
    app = qos.make_decode_app(cfg, gen=4, batch=1)
    db = str(tmp_path / "db.json")
    grid = qos.threshold_grid(cfg, [0.02, 0.3])
    recs = sweep(app, grid, repeats=1, db_path=db)
    assert len(recs) == 2
    assert all(np.isfinite(r.error) for r in recs)
    assert recs[1].approx_fraction >= recs[0].approx_fraction
    # threshold 0.0 (precise) reproduces the exact baseline bit for bit
    exact = app.exact()
    again = app.run(ApproxSpec())
    np.testing.assert_array_equal(exact.qoi, again.qoi)
    assert exact.approx_fraction == 0.0
    # structural mismatch fails fast
    bad = ApproxSpec(Technique.TAF, Level.BLOCK, taf=TAFParams(5, 9, 0.1))
    with pytest.raises(ValueError, match="structural"):
        app.run(bad)
    # the sweep DB feeds the policy loader
    pol = qos.QosPolicy.from_db(db, app="taf_decode", use_modeled=True)
    assert pol.entries[0].precise
