"""Per-kernel validation: shape/dtype sweeps, kernel (interpret) vs the
pure-jnp oracle in ref.py."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.types import PerforationKind, PerforationParams
from repro.kernels import ops, ref


def _stableish(rng, m, k, noise=0.02):
    """Row-block-correlated inputs: exercises TAF/iACT state transitions."""
    base = rng.randn(1, k).astype(np.float32)
    return np.tile(base, (m, 1)) + noise * rng.randn(m, k).astype(np.float32)


class TestTAFMatmul:
    @pytest.mark.parametrize("m,k,n,bm,bn", [
        (128, 32, 64, 32, 32),
        (256, 64, 128, 64, 64),
        (64, 16, 32, 16, 16),
    ])
    def test_matches_oracle_shapes(self, m, k, n, bm, bn):
        rng = np.random.RandomState(m + k + n)
        x = jnp.asarray(_stableish(rng, m, k))
        w = jnp.asarray(rng.randn(k, n).astype(np.float32))
        y, mask = ops.taf_matmul(x, w, block_m=bm, block_n=bn,
                                 history_size=3, prediction_size=4,
                                 rsd_threshold=0.5)
        yr, mr = ref.taf_matmul_ref(x, w, block_m=bm, block_n=bn,
                                    history_size=3, prediction_size=4,
                                    rsd_threshold=0.5)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
        assert np.array_equal(np.asarray(mask), np.asarray(mr))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.RandomState(0)
        x = jnp.asarray(_stableish(rng, 64, 32)).astype(dtype)
        w = jnp.asarray(rng.randn(32, 32).astype(np.float32)).astype(dtype)
        y, mask = ops.taf_matmul(x, w, block_m=32, block_n=32,
                                 out_dtype=jnp.float32)
        yr, mr = ref.taf_matmul_ref(x, w, block_m=32, block_n=32,
                                    history_size=3, prediction_size=8,
                                    rsd_threshold=0.5)
        atol = 1e-3 if dtype == jnp.float32 else 0.5
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=atol)

    @pytest.mark.parametrize("h,p,t", [(1, 2, 0.1), (5, 16, 2.0),
                                       (2, 512, 20.0)])
    def test_param_sweep(self, h, p, t):
        rng = np.random.RandomState(42)
        x = jnp.asarray(_stableish(rng, 128, 32, noise=0.1))
        w = jnp.asarray(rng.randn(32, 32).astype(np.float32))
        y, mask = ops.taf_matmul(x, w, block_m=32, block_n=32,
                                 history_size=h, prediction_size=p,
                                 rsd_threshold=t)
        yr, mr = ref.taf_matmul_ref(x, w, block_m=32, block_n=32,
                                    history_size=h, prediction_size=p,
                                    rsd_threshold=t)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
        assert np.array_equal(np.asarray(mask), np.asarray(mr))

    def test_zero_threshold_never_approximates(self):
        rng = np.random.RandomState(1)
        x = jnp.asarray(rng.randn(128, 32).astype(np.float32) * 10)
        w = jnp.asarray(rng.randn(32, 32).astype(np.float32))
        y, mask = ops.taf_matmul(x, w, block_m=32, block_n=32,
                                 rsd_threshold=0.0)
        assert not np.asarray(mask).any()
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(x @ w), rtol=2e-4, atol=1e-3)


class TestIACTKernel:
    @pytest.mark.parametrize("n,din,dh,dout,br,ts", [
        (128, 16, 32, 8, 32, 4),
        (256, 32, 64, 16, 64, 2),
        (64, 8, 16, 8, 16, 8),
    ])
    def test_matches_oracle(self, n, din, dh, dout, br, ts):
        rng = np.random.RandomState(n + din)
        # repeat values across consecutive blocks so hits occur
        distinct = rng.randn(max(n // (2 * br), 1), din).astype(np.float32)
        x = jnp.asarray(np.repeat(distinct, 2 * br, axis=0)[:n])
        w1 = jnp.asarray(rng.randn(din, dh).astype(np.float32) * 0.1)
        w2 = jnp.asarray(rng.randn(dh, dout).astype(np.float32) * 0.1)
        y, mask = ops.iact_rowfn(x, w1, w2, block_rows=br, table_size=ts,
                                 threshold=0.5)
        yr, mr = ref.iact_rowfn_ref(x, w1, w2, block_rows=br, table_size=ts,
                                    threshold=0.5)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)
        assert np.array_equal(np.asarray(mask), np.asarray(mr))
        assert np.asarray(mask).any()  # some blocks must hit

    def test_tiny_threshold_all_accurate(self):
        rng = np.random.RandomState(3)
        x = jnp.asarray(rng.randn(64, 16).astype(np.float32))
        w1 = jnp.asarray(rng.randn(16, 32).astype(np.float32) * 0.1)
        w2 = jnp.asarray(rng.randn(32, 8).astype(np.float32) * 0.1)
        y, mask = ops.iact_rowfn(x, w1, w2, block_rows=32, threshold=1e-9)
        assert not np.asarray(mask).any()


class TestPerforatedMatmul:
    @pytest.mark.parametrize("kind,arg", [
        (PerforationKind.SMALL, 2), (PerforationKind.SMALL, 4),
        (PerforationKind.LARGE, 4), (PerforationKind.INI, 0.5),
        (PerforationKind.FINI, 0.25),
    ])
    def test_matches_oracle(self, kind, arg):
        rng = np.random.RandomState(5)
        x = jnp.asarray(rng.randn(64, 256).astype(np.float32))
        w = jnp.asarray(rng.randn(256, 64).astype(np.float32))
        if kind in (PerforationKind.SMALL, PerforationKind.LARGE):
            p = PerforationParams(kind=kind, skip=arg)
        else:
            p = PerforationParams(kind=kind, fraction=arg)
        y = ops.perforated_matmul(x, w, block_m=32, block_n=32, block_k=32,
                                  perfo=p)
        yr = ref.perforated_matmul_ref(x, w, block_k=32, perfo=p)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=1e-3)

    def test_no_perforation_is_exact(self):
        rng = np.random.RandomState(6)
        x = jnp.asarray(rng.randn(64, 128).astype(np.float32))
        w = jnp.asarray(rng.randn(128, 64).astype(np.float32))
        y = ops.perforated_matmul(x, w, block_m=32, block_n=32, block_k=32)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                   rtol=2e-4, atol=1e-3)

    def test_rescale(self):
        rng = np.random.RandomState(7)
        x = jnp.asarray(np.ones((32, 128), np.float32))
        w = jnp.asarray(np.ones((128, 32), np.float32))
        p = PerforationParams(kind=PerforationKind.SMALL, skip=2)
        y = ops.perforated_matmul(x, w, block_m=32, block_n=32, block_k=32,
                                  perfo=p, rescale=True)
        np.testing.assert_allclose(np.asarray(y), 128.0, rtol=1e-5)


class TestPerforatedAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
        (1, 2, 2, 64, 64, 32),
        (2, 4, 2, 64, 128, 32),   # GQA + decode offset
        (1, 8, 1, 32, 96, 16),    # MQA
    ])
    def test_full_matches_oracle(self, b, hq, hkv, sq, skv, d):
        rng = np.random.RandomState(b + hq + sq)
        q = jnp.asarray(rng.randn(b, hq, sq, d).astype(np.float32))
        k = jnp.asarray(rng.randn(b, hkv, skv, d).astype(np.float32))
        v = jnp.asarray(rng.randn(b, hkv, skv, d).astype(np.float32))
        o = ops.flash_attention(q, k, v, block_q=32, block_kv=32)
        rep = hq // hkv
        orf = ref.attention_ref(q, jnp.repeat(k, rep, 1),
                                jnp.repeat(v, rep, 1), causal=True)
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=1e-4)

    @pytest.mark.parametrize("kind,arg", [
        (PerforationKind.INI, 0.5), (PerforationKind.FINI, 0.25),
        (PerforationKind.SMALL, 2), (PerforationKind.LARGE, 2),
    ])
    def test_perforated_matches_oracle(self, kind, arg):
        rng = np.random.RandomState(11)
        q = jnp.asarray(rng.randn(1, 2, 64, 32).astype(np.float32))
        k = jnp.asarray(rng.randn(1, 2, 128, 32).astype(np.float32))
        v = jnp.asarray(rng.randn(1, 2, 128, 32).astype(np.float32))
        if kind in (PerforationKind.SMALL, PerforationKind.LARGE):
            p = PerforationParams(kind=kind, skip=arg)
        else:
            p = PerforationParams(kind=kind, fraction=arg)
        o = ops.perforated_attention(q, k, v, block_q=32, block_kv=32,
                                     perfo=p)
        orf = ref.attention_ref(q, k, v, causal=True, block_kv=32, perfo=p)
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=1e-4)

    def test_non_causal(self):
        rng = np.random.RandomState(12)
        q = jnp.asarray(rng.randn(1, 2, 32, 16).astype(np.float32))
        k = jnp.asarray(rng.randn(1, 2, 64, 16).astype(np.float32))
        v = jnp.asarray(rng.randn(1, 2, 64, 16).astype(np.float32))
        o = ops.perforated_attention(q, k, v, block_q=32, block_kv=32,
                                     causal=False)
        orf = ref.attention_ref(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=1e-4)

    def test_bf16(self):
        rng = np.random.RandomState(13)
        q = jnp.asarray(rng.randn(1, 2, 32, 16), jnp.bfloat16)
        k = jnp.asarray(rng.randn(1, 2, 32, 16), jnp.bfloat16)
        v = jnp.asarray(rng.randn(1, 2, 32, 16), jnp.bfloat16)
        o = ops.flash_attention(q, k, v, block_q=32, block_kv=32)
        orf = ref.attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(orf, np.float32), atol=0.05)
