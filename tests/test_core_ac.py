"""Unit tests for the core AC programming model (paper semantics)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ApproxRegion, ApproxSpec, IACTParams, Level,
                        PerforationKind, PerforationParams, TAFParams,
                        Technique, parse_pragma, perforated_loop)
from repro.core import hierarchy, iact, perforation, taf
from repro.core.rsd import rsd


class TestPragmaParsing:
    def test_memo_in(self):
        s = parse_pragma("memo(in:2:0.5:4) level(warp)")
        assert s.technique == Technique.IACT
        assert s.level == Level.TILE
        assert s.iact == IACTParams(2, 0.5, 4)

    def test_memo_out(self):
        s = parse_pragma("memo(out:3:5:1.5) level(thread)")
        assert s.technique == Technique.TAF
        assert s.taf == TAFParams(3, 5, 1.5)

    def test_perfo(self):
        s = parse_pragma("perfo(small:4)")
        assert s.perforation.kind == PerforationKind.SMALL
        assert s.perforation.skip == 4
        s = parse_pragma("perfo(ini:0.3) level(team)")
        assert s.perforation.kind == PerforationKind.INI
        assert s.level == Level.BLOCK

    def test_bad_pragma(self):
        with pytest.raises(ValueError):
            parse_pragma("approximate(everything)")


class TestTAF:
    def test_state_machine_cycle(self):
        """Window fill (h) -> stable -> p approximations -> accurate again."""
        params = TAFParams(history_size=3, prediction_size=4,
                           rsd_threshold=0.5)
        state = taf.init(params, 1)
        outs = []
        masks = []
        for t in range(12):
            out, state, mask = taf.step(
                state, lambda: jnp.asarray([1.0]), params)
            outs.append(float(out[0]))
            masks.append(bool(mask[0]))
        # steps 0-2 accurate (fill window), step 2 triggers stable,
        # steps 3-6 approximate, step 7 accurate, 8-11 approximate
        assert masks[:3] == [False, False, False]
        assert masks[3:7] == [True] * 4
        assert masks[7] is False
        assert masks[8:12] == [True] * 4
        assert all(o == 1.0 for o in outs)

    def test_noisy_never_stabilizes(self):
        params = TAFParams(3, 4, 0.01)
        rng = np.random.RandomState(0)
        xs = jnp.asarray(rng.standard_normal((30, 8, 4)) * 100)
        _, _, frac = taf.run_sequence(params, xs,
                                      lambda x: jnp.sum(x, -1))
        assert float(frac) < 0.05

    def test_memo_returns_last_accurate(self):
        params = TAFParams(2, 2, 10.0)  # huge threshold: stable asap
        state = taf.init(params, 1)
        out0, state, _ = taf.step(state, lambda: jnp.asarray([5.0]), params)
        out1, state, _ = taf.step(state, lambda: jnp.asarray([7.0]), params)
        # now stable; next 2 approximate with the LAST accurate value (7)
        out2, state, m2 = taf.step(state, lambda: jnp.asarray([9.0]), params)
        assert bool(m2[0]) and float(out2[0]) == 7.0

    def test_block_level_skips_whole_batch(self):
        params = TAFParams(2, 4, 10.0)
        state = taf.init(params, 8)
        calls = []

        def accurate():
            calls.append(1)
            return jnp.ones((8,))

        for _ in range(4):
            out, state, mask = taf.step(state, accurate, params, Level.BLOCK)
        # traced twice at most (cond branches), but mask shows block skips
        assert bool(mask.all())


class TestIACT:
    def test_exact_reuse(self):
        params = IACTParams(table_size=4, threshold=0.5, tables_per_block=0)
        xs = jnp.tile(jnp.arange(6.0)[None, :, None], (10, 1, 3))
        ys, state, frac = iact.run_sequence(params, xs,
                                            lambda x: jnp.sum(x, -1))
        assert float(frac) > 0.8
        np.testing.assert_allclose(np.asarray(ys),
                                   np.asarray(jnp.sum(xs, -1)), atol=1e-5)

    def test_threshold_zero_never_hits_noise(self):
        params = IACTParams(4, 1e-9, 0)
        rng = np.random.RandomState(0)
        xs = jnp.asarray(rng.standard_normal((10, 8, 3)))
        _, _, frac = iact.run_sequence(params, xs, lambda x: jnp.sum(x, -1))
        assert float(frac) == 0.0

    def test_round_robin_replacement(self):
        """Table of 2: inserting 3 distinct values evicts the oldest."""
        params = IACTParams(table_size=2, threshold=0.1, tables_per_block=1)
        state = iact.init(params, 1, 2)
        f = lambda x: jnp.sum(x, -1)
        for v in (0.0, 10.0, 20.0):
            x = jnp.full((1, 2), v)
            _, state, _ = iact.step(state, x, f, params)
        keys = np.asarray(state.keys)[0]
        assert 0.0 not in keys[:, 0] or np.allclose(keys[0, 0], 20.0)
        # the oldest (0.0) was evicted by 20.0 at cursor 0
        assert sorted(keys[:, 0].tolist()) == [10.0, 20.0]

    def test_table_sharing_counts(self):
        assert iact.n_tables_for(IACTParams(4, 0.5, 0), 64) == 64
        assert iact.n_tables_for(IACTParams(4, 0.5, 8), 64) == 8
        assert iact.n_tables_for(IACTParams(4, 0.5, 100), 64) == 64


class TestPerforation:
    def test_small_pattern(self):
        p = PerforationParams(kind=PerforationKind.SMALL, skip=4)
        m = perforation.execute_mask(8, p)
        assert m.tolist() == [True, True, True, False] * 2

    def test_large_pattern(self):
        p = PerforationParams(kind=PerforationKind.LARGE, skip=4)
        m = perforation.execute_mask(8, p)
        assert m.tolist() == [True, False, False, False] * 2

    def test_ini_fini_bounds(self):
        p = PerforationParams(kind=PerforationKind.INI, fraction=0.25)
        assert perforation.perforated_bounds(16, p) == (4, 16)
        p = PerforationParams(kind=PerforationKind.FINI, fraction=0.25)
        assert perforation.perforated_bounds(16, p) == (0, 12)

    def test_herded_identical_rows(self):
        p = PerforationParams(kind=PerforationKind.SMALL, skip=4, herded=True)
        m = perforation.element_masks(16, 8, p)
        assert (m == m[0]).all()

    def test_non_herded_divergent_rows(self):
        p = PerforationParams(kind=PerforationKind.SMALL, skip=4,
                              herded=False)
        m = perforation.element_masks(16, 8, p)
        assert not (m == m[0]).all()
        # every row still drops exactly 1/4
        np.testing.assert_allclose(m.mean(axis=1), 0.75)

    def test_perforated_loop_structural(self):
        spec = ApproxSpec(Technique.PERFORATION,
                          perforation=PerforationParams(
                              kind=PerforationKind.SMALL, skip=4))
        total, frac = perforated_loop(
            spec, 8, lambda i, acc: acc + jnp.float32(i), jnp.float32(0))
        # executed iterations: 0,1,2,4,5,6 -> 18
        assert float(total) == 18.0
        assert frac == 0.75


class TestHierarchy:
    def test_majority_rules_tie_is_accurate(self):
        mask = jnp.asarray([True, False, True, False])
        assert not bool(hierarchy.block_majority(mask))

    def test_majority_forces_minority(self):
        """Paper: group votes can FORCE non-activated elements to
        approximate (LavaMD discussion)."""
        mask = jnp.asarray([True, True, True, False])
        voted = hierarchy.vote(mask, Level.BLOCK)
        assert bool(voted.all())

    def test_tile_vote_groups(self):
        mask = jnp.asarray([True] * 3 + [False] + [False] * 3 + [True])
        voted = hierarchy.vote(mask, Level.TILE, tile_size=4)
        assert voted.tolist() == [True] * 4 + [False] * 4

    def test_element_level_identity(self):
        mask = jnp.asarray([True, False, True])
        assert (hierarchy.vote(mask, Level.ELEMENT) == mask).all()


class TestRSD:
    def test_constant_is_zero(self):
        assert float(rsd(jnp.ones((5,)))) == 0.0

    def test_matches_paper_definition(self):
        x = jnp.asarray([1.0, 2.0, 3.0])
        expected = float(np.std([1, 2, 3]) / np.mean([1, 2, 3]))
        np.testing.assert_allclose(float(rsd(x)), expected, rtol=1e-6)


class TestTracedHooks:
    """The traced-parameter hooks behind the batched-runner protocol:
    a traced scalar must reproduce the static parameter's results exactly
    and be vmappable over a parameter stack."""

    def test_iact_traced_threshold_matches_static(self):
        params = IACTParams(table_size=2, threshold=0.5, tables_per_block=4)
        rng = np.random.RandomState(0)
        xs = jnp.asarray(rng.standard_normal((5, 8, 3)).astype(np.float32))
        fn = lambda x: jnp.sum(x * x, axis=-1)
        ys_s, _, fr_s = iact.run_sequence(params, xs, fn)
        ys_t, _, fr_t = jax.jit(
            lambda th: iact.run_sequence(params, xs, fn, threshold=th)
        )(jnp.float32(0.5))
        np.testing.assert_allclose(np.asarray(ys_s), np.asarray(ys_t))
        assert float(fr_s) == float(fr_t)

    def test_iact_threshold_vmaps(self):
        params = IACTParams(table_size=2, threshold=0.5, tables_per_block=4)
        rng = np.random.RandomState(0)
        xs = jnp.asarray(rng.standard_normal((6, 8, 3)).astype(np.float32))
        fn = lambda x: jnp.sum(x * x, axis=-1)
        vb = jax.jit(jax.vmap(
            lambda th: iact.run_sequence(params, xs, fn, threshold=th)[2]))
        fracs = np.asarray(vb(jnp.asarray([0.0, 0.5, 50.0], jnp.float32)))
        # a zero threshold never hits; a huge one hits more than a moderate
        assert fracs[0] == 0.0
        assert fracs[2] >= fracs[1]

    def test_traced_execute_mask_matches_static(self):
        # 0.58 * 50 sits just below an integer in float64 but just above in
        # float32 -- both paths must agree (they compute in float32)
        for kind, frac, n in ((PerforationKind.INI, 0.25, 16),
                              (PerforationKind.INI, 0.58, 50),
                              (PerforationKind.FINI, 0.4, 16),
                              (PerforationKind.FINI, 0.58, 50),
                              (PerforationKind.RANDOM, 0.3, 16)):
            p = PerforationParams(kind=kind, fraction=frac)
            static = perforation.execute_mask(n, p)
            traced = np.asarray(jax.jit(
                lambda fr, p=p, n=n: perforation.traced_execute_mask(n, p,
                                                                     fr)
            )(jnp.float32(frac)))
            np.testing.assert_array_equal(static, traced)

    def test_traced_execute_mask_rejects_structural_kinds(self):
        p = PerforationParams(kind=PerforationKind.SMALL, skip=4)
        with pytest.raises(ValueError):
            perforation.traced_execute_mask(16, p, 0.5)

    def test_perforated_loop_traced_fraction(self):
        spec = ApproxSpec(Technique.PERFORATION,
                          perforation=PerforationParams(
                              kind=PerforationKind.INI, fraction=0.25,
                              herded=False))
        body = lambda i, acc: acc + jnp.float32(i)
        out_s, frac_s = perforated_loop(spec, 8, body, jnp.float32(0))
        out_t, frac_t = jax.jit(lambda fr: perforated_loop(
            spec, 8, body, jnp.float32(0), fraction=fr))(jnp.float32(0.25))
        assert float(out_s) == float(out_t)
        assert float(frac_s) == float(frac_t)
        # vmapped over a fraction stack: one compiled masked loop
        vm = jax.jit(jax.vmap(lambda fr: perforated_loop(
            spec, 8, body, jnp.float32(0), fraction=fr)[0]))
        outs = np.asarray(vm(jnp.asarray([0.0, 0.25, 0.5], jnp.float32)))
        assert outs[0] == 28.0 and outs[1] == float(out_s)

    def test_region_hooks_pass_through(self):
        n = 8
        spec = ApproxSpec(Technique.TAF, taf=TAFParams(2, 4, 0.5))
        region = ApproxRegion(spec, lambda x: x * 2.0, n_elements=n)
        xs = jnp.ones((5, n), jnp.float32)
        ys_s, frac_s = region.run(xs)
        ys_t, frac_t = region.run(xs, rsd_threshold=jnp.float32(0.5))
        np.testing.assert_allclose(np.asarray(ys_s), np.asarray(ys_t))
        assert float(frac_s) == float(frac_t)

    def test_region_rejects_unsupported_hooks(self):
        n = 8
        taf_region = ApproxRegion(ApproxSpec(Technique.TAF),
                                  lambda x: x, n_elements=n)
        iact_region = ApproxRegion(ApproxSpec(Technique.IACT),
                                   lambda x: x, n_elements=n, in_dim=1)
        xs = jnp.ones((3, n), jnp.float32)
        with pytest.raises(ValueError):
            taf_region.run(xs, threshold=0.5)      # iACT hook on a TAF region
        with pytest.raises(ValueError):
            iact_region.run(xs, rsd_threshold=0.5)  # TAF hook on iACT
        with pytest.raises(ValueError):
            taf_region.step(taf_region.init_state(), xs[0], threshold=0.5)
