"""Regression tests for the jax version-compatibility layer (repro.compat).

The repo must import and run against the *installed* jax: 0.4.x lacks
`jax.sharding.AxisType`, the top-level `jax.shard_map` export, the
`check_vma` kwarg, and returns `cost_analysis()` as a list. These tests
pin the portability surface so an API drift in either direction fails
loudly here instead of nine tests deep in the distributed suite.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import compat


def test_mesh_modules_import_under_installed_jax():
    """The original regression: importing + calling the mesh constructors
    raised AttributeError on jax 0.4.37 (`jax.sharding.AxisType`)."""
    from repro.launch import mesh as mesh_mod
    from repro.runtime import elastic

    assert callable(mesh_mod.make_production_mesh)
    assert callable(mesh_mod.make_debug_mesh)
    # elastic degrades to whatever devices exist (1 in the test process)
    m = elastic.make_mesh_for(n_devices=1, model_parallel=4)
    assert tuple(m.axis_names) == ("data", "model")
    assert m.devices.size == 1


def test_compat_make_mesh_single_device():
    m = compat.make_mesh((1,), ("data",))
    assert tuple(m.axis_names) == ("data",)


def test_compat_shard_map_runs():
    from jax.sharding import PartitionSpec as P
    mesh = compat.make_mesh((1,), ("data",))
    fn = compat.shard_map(lambda x: x * 2.0, mesh=mesh, in_specs=P("data"),
                          out_specs=P("data"), check_replication=False)
    y = fn(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(y), np.arange(4.0) * 2.0)


def test_compat_cost_analysis_is_flat_dict():
    compiled = jax.jit(lambda x: x + 1.0).lower(jnp.zeros((4,))).compile()
    cost = compat.cost_analysis(compiled)
    assert isinstance(cost, dict)
    # flat scalar entries, whatever the jax version returned
    assert all(np.isscalar(v) for v in cost.values())
