"""Per-architecture smoke tests (reduced configs; brief requirement) +
model math correctness (decode==forward, mamba2 SSD parity, TAF decode)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.core.types import ApproxSpec, Level, TAFParams, Technique
from repro.models import build, mamba2

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, rng, s=S):
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, s)),
                                   jnp.int32)}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_patch_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.frontend == "audio_frames":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.max_source_positions, cfg.d_model))
            * 0.02, jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        """Brief: instantiate reduced config, one forward/train step on CPU,
        assert output shapes + no NaNs."""
        cfg = get_smoke_config(arch)
        model = build(cfg)
        params = model.init(KEY)
        rng = np.random.RandomState(0)
        batch = _batch(cfg, rng)

        loss, metrics = jax.jit(model.loss)(params, batch)
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss))

        grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
        gleaves = jax.tree.leaves(grads)
        assert all(np.isfinite(np.asarray(g, np.float32)).all()
                   for g in gleaves)
        # shapes of grads mirror params
        for g, p in zip(gleaves, jax.tree.leaves(params)):
            assert g.shape == p.shape

    def test_hidden_shape(self, arch):
        cfg = get_smoke_config(arch)
        model = build(cfg)
        params = model.init(KEY)
        rng = np.random.RandomState(1)
        batch = _batch(cfg, rng)
        h = model.hidden(params, batch)
        expect_s = S + (cfg.n_patch_tokens
                        if cfg.frontend == "vision_patches" else 0)
        assert h.shape == (B, expect_s, cfg.d_model)
        assert np.isfinite(np.asarray(h, np.float32)).all()


@pytest.mark.parametrize("arch", ["deepseek-7b", "qwen3-1.7b",
                                  "starcoder2-3b", "qwen1.5-4b",
                                  "rwkv6-1.6b", "zamba2-7b",
                                  "whisper-large-v3"])
def test_decode_matches_forward(arch):
    """Greedy decode with KV cache == teacher-forced forward (f32)."""
    cfg = dataclasses.replace(get_smoke_config(arch), remat=False,
                              compute_dtype="float32")
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.RandomState(2)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S + 4)),
                         jnp.int32)
    batch = {"tokens": tokens[:, :S], "max_len": S + 4}
    if cfg.frontend == "audio_frames":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.max_source_positions, cfg.d_model))
            * 0.02, jnp.float32)
    _, cache = model.prefill(params, batch)
    for t in range(3):
        logits, cache = model.decode_step(params, cache, tokens[:, S + t],
                                          jnp.int32(S + t))
        fb = {"tokens": tokens[:, :S + t + 2]}
        if cfg.frontend == "audio_frames":
            fb["frames"] = batch["frames"]
        h = model.hidden(params, fb)
        head = (params["embed"].T if cfg.tie_embeddings else params["head"])
        ref = h[:, S + t] @ head
        scale = float(jnp.abs(ref).max()) + 1e-6
        err = float(jnp.abs(logits - ref).max()) / scale
        assert err < 0.02, f"{arch}: decode diverges {err:.4f}"


def test_moe_decode_matches_forward_high_capacity():
    """With generous capacity (no token drops) MoE decode == forward."""
    cfg = get_smoke_config("olmoe-1b-7b")
    cfg = dataclasses.replace(
        cfg, remat=False, compute_dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.RandomState(3)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S + 2)),
                         jnp.int32)
    _, cache = model.prefill(params, {"tokens": tokens[:, :S],
                                      "max_len": S + 2})
    logits, _ = model.decode_step(params, cache, tokens[:, S], jnp.int32(S))
    h = model.hidden(params, {"tokens": tokens[:, :S + 2]})
    ref = h[:, S] @ params["head"]
    scale = float(jnp.abs(ref).max()) + 1e-6
    assert float(jnp.abs(logits - ref).max()) / scale < 0.02


def test_mamba2_chunked_equals_recurrent():
    """SSD chunked scan == stepwise recurrence, bit-tight in f32."""
    cfg = dataclasses.replace(get_smoke_config("zamba2-7b"),
                              compute_dtype="float32")
    p = mamba2.init_params(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 20, cfg.d_model)) * 0.5
    y_full, state = mamba2.forward(p, cfg, x, return_state=True)
    cache = mamba2.init_cache(cfg, 2, jnp.float32)
    ys = []
    for t in range(20):
        yt, cache = mamba2.decode_step(p, cfg, x[:, t:t + 1], cache)
        ys.append(yt)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_step),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(state["ssm"]),
                               np.asarray(cache["ssm"]), atol=1e-5)


def test_taf_decode_skips_and_stays_finite():
    """Decode-time TAF (the paper's technique as a serving feature):
    stable decoding skips layer-steps; logits stay finite."""
    cfg = dataclasses.replace(
        get_smoke_config("deepseek-7b"), remat=False,
        compute_dtype="float32",
        approx_decode=ApproxSpec(Technique.TAF, Level.BLOCK,
                                 taf=TAFParams(2, 4, 50.0)))
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.RandomState(5)
    tokens = jnp.asarray(rng.randint(0, cfg.vocab_size, (B, 8)), jnp.int32)
    _, cache = model.prefill(params, {"tokens": tokens, "max_len": 24})
    tok = tokens[:, -1]
    skipped = 0
    for t in range(12):
        logits, cache = model.decode_step(params, cache, tok,
                                          jnp.int32(8 + t))
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        skipped += int((np.asarray(cache["taf"]["remaining"]) > 0).sum())
    assert skipped > 0, "huge threshold must trigger TAF skips"


def test_vocab_padding():
    cfg = get_smoke_config("whisper-large-v3")
    assert cfg.padded_vocab_size % cfg.vocab_pad_multiple == 0
    assert cfg.padded_vocab_size >= cfg.vocab_size


def test_param_counts_match_targets():
    """Analytic counts line up with the briefs' model sizes."""
    from repro.configs import get_config
    targets = {"deepseek-v3-671b": (600e9, 750e9),
               "olmoe-1b-7b": (6e9, 8e9),
               "pixtral-12b": (10e9, 14e9),
               "deepseek-7b": (6e9, 8e9),
               "starcoder2-3b": (2.5e9, 4e9)}
    for arch, (lo, hi) in targets.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.1f}B outside [{lo},{hi}]"
    assert 30e9 < get_config("deepseek-v3-671b").active_param_count() < 45e9
