"""Property-based tests (hypothesis) for system invariants.

`hypothesis` is an optional test dependency (see pyproject.toml
``[project.optional-dependencies] test``); the module skips cleanly when it
is not installed so the tier-1 suite still collects.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (IACTParams, Level, PerforationKind,
                        PerforationParams, TAFParams)
from repro.core import hierarchy, iact, perforation, taf
from repro.core.rsd import rsd
from repro.models import common
from repro.models.lm import chunked_xent
from repro.optim import compress

SET = settings(max_examples=25, deadline=None)


class TestRSDProperties:
    @SET
    @given(st.lists(st.floats(0.1, 100.0), min_size=2, max_size=8),
           st.floats(0.01, 50.0))
    def test_scale_invariance(self, xs, c):
        x = jnp.asarray(xs)
        r1 = float(rsd(x))
        r2 = float(rsd(c * x))
        np.testing.assert_allclose(r1, r2, rtol=1e-3, atol=1e-5)

    @SET
    @given(st.lists(st.floats(-100, 100), min_size=2, max_size=8))
    def test_nonnegative_finite(self, xs):
        r = float(rsd(jnp.asarray(xs)))
        assert r >= 0.0 and np.isfinite(r)


class TestPerforationProperties:
    @SET
    @given(st.integers(2, 32), st.integers(1, 8))
    def test_small_density(self, skip, mult):
        """Small perforation over a whole number of periods drops EXACTLY
        1/skip of iterations."""
        n = skip * mult
        p = PerforationParams(kind=PerforationKind.SMALL, skip=skip)
        m = perforation.execute_mask(n, p)
        assert m.sum() == n - mult

    @SET
    @given(st.integers(2, 32), st.integers(1, 8))
    def test_large_density(self, skip, mult):
        n = skip * mult
        p = PerforationParams(kind=PerforationKind.LARGE, skip=skip)
        assert perforation.execute_mask(n, p).sum() == mult

    @SET
    @given(st.integers(4, 64),
           st.floats(0.0, 0.99, exclude_max=False))
    def test_ini_fini_complementary_counts(self, n, frac):
        pi = PerforationParams(kind=PerforationKind.INI, fraction=frac)
        pf = PerforationParams(kind=PerforationKind.FINI, fraction=frac)
        mi = perforation.execute_mask(n, pi)
        mf = perforation.execute_mask(n, pf)
        assert mi.sum() == mf.sum() == n - int(np.floor(frac * n))

    @SET
    @given(st.integers(2, 16), st.integers(2, 8))
    def test_kept_indices_sorted_unique(self, skip, mult):
        p = PerforationParams(kind=PerforationKind.SMALL, skip=skip)
        k = perforation.kept_indices(skip * mult, p)
        assert (np.diff(k) > 0).all()


class TestHierarchyProperties:
    @SET
    @given(st.lists(st.booleans(), min_size=1, max_size=64))
    def test_block_vote_is_constant(self, bits):
        voted = hierarchy.vote(jnp.asarray(bits), Level.BLOCK)
        v = np.asarray(voted)
        assert (v == v[0]).all()

    @SET
    @given(st.lists(st.booleans(), min_size=8, max_size=64).filter(
        lambda b: len(b) % 4 == 0))
    def test_tile_vote_idempotent(self, bits):
        m = jnp.asarray(bits)
        v1 = hierarchy.vote(m, Level.TILE, tile_size=4)
        v2 = hierarchy.vote(v1, Level.TILE, tile_size=4)
        assert (np.asarray(v1) == np.asarray(v2)).all()

    @SET
    @given(st.integers(1, 6))
    def test_unanimous_approximates(self, log2n):
        n = 2 ** log2n
        m = jnp.ones((n,), bool)
        for level in (Level.ELEMENT, Level.TILE, Level.BLOCK):
            assert bool(hierarchy.vote(m, level, tile_size=min(n, 4)).all())


class TestTAFProperties:
    @SET
    @given(st.integers(1, 5), st.integers(1, 16), st.floats(0.0, 5.0))
    def test_outputs_always_finite(self, h, p, t):
        params = TAFParams(h, p, t)
        rng = np.random.RandomState(42)
        xs = jnp.asarray(rng.standard_normal((10, 4, 3)))
        ys, _, frac = taf.run_sequence(params, xs, lambda x: jnp.sum(x, -1))
        assert np.isfinite(np.asarray(ys)).all()
        assert 0.0 <= float(frac) <= 1.0

    @SET
    @given(st.integers(1, 4), st.integers(1, 8))
    def test_threshold_zero_no_approx_on_noise(self, h, p):
        params = TAFParams(h, p, 0.0)
        rng = np.random.RandomState(7)
        xs = jnp.asarray(rng.standard_normal((12, 4, 3)) * 10)
        _, _, frac = taf.run_sequence(params, xs, lambda x: jnp.sum(x, -1))
        assert float(frac) == 0.0


class TestIACTProperties:
    @SET
    @given(st.integers(1, 8), st.floats(0.01, 2.0))
    def test_identical_inputs_always_hit_after_first(self, tsize, thresh):
        params = IACTParams(tsize, thresh, 0)
        xs = jnp.ones((6, 4, 3))
        ys, _, frac = iact.run_sequence(params, xs, lambda x: jnp.sum(x, -1))
        # first invocation misses; the rest hit
        np.testing.assert_allclose(float(frac), 5 / 6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(ys), 3.0, atol=1e-5)


class TestCompressionProperties:
    @SET
    @given(st.integers(0, 2 ** 31 - 1))
    def test_error_feedback_exact_accumulation(self, seed):
        """Sum of dequantized grads + final residual == sum of true grads
        (EF makes compression unbiased in accumulation)."""
        rng = np.random.RandomState(seed)
        g_true = [jnp.asarray(rng.standard_normal((8,)) * 10 ** rng.uniform(
            -3, 3)) for _ in range(4)]
        ef = compress.init_ef(g_true[0])
        acc_hat = jnp.zeros((8,))
        acc_true = jnp.zeros((8,))
        for g in g_true:
            (q, scale), g_hat, ef = compress.compress_grads(g, ef)
            acc_hat = acc_hat + g_hat
            acc_true = acc_true + g
        total_err = np.abs(np.asarray(
            acc_true - acc_hat - ef.residual)).max()
        assert total_err < 1e-3 * max(1.0, float(jnp.abs(acc_true).max()))

    @SET
    @given(st.integers(0, 2 ** 31 - 1))
    def test_quantize_bounded_error(self, seed):
        rng = np.random.RandomState(seed)
        g = jnp.asarray(rng.standard_normal((64,)))
        q, scale = compress.quantize_tensor(g)
        err = np.abs(np.asarray(compress.dequantize_tensor(q, scale) - g))
        assert err.max() <= float(scale) * 0.5 + 1e-7


class TestModelMathProperties:
    @SET
    @given(st.integers(1, 3), st.integers(2, 5), st.integers(1, 3))
    def test_chunked_attention_matches_full(self, b, s_mult, h):
        rng = np.random.RandomState(b * 100 + s_mult * 10 + h)
        sq = 8 * s_mult
        q = jnp.asarray(rng.standard_normal((b, h, sq, 16)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, h, sq, 16)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, h, sq, 16)), jnp.float32)
        out_c = common.chunked_attention(q, k, v, causal=True, q_chunk=8,
                                         kv_chunk=8)
        out_f = common.full_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_f),
                                   atol=2e-5)

    @SET
    @given(st.integers(1, 3), st.integers(1, 4))
    def test_chunked_xent_matches_direct(self, b, nc):
        rng = np.random.RandomState(b * 7 + nc)
        s, d, v = nc * 4, 8, 16
        h = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((d, v)), jnp.float32)
        y = jnp.asarray(rng.randint(0, v, (b, s)))
        total, count = chunked_xent(h, w, y, chunk=4)
        logits = h @ w
        logz = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, y[..., None], -1)[..., 0]
        np.testing.assert_allclose(float(total),
                                   float(jnp.sum(logz - gold)), rtol=1e-4)
        assert float(count) == b * s


# ---------------------------------------------------------------------------
# QoS ladder / sharded control-plane invariants (ISSUE 6)
# ---------------------------------------------------------------------------

def _ladder_records(pairs):
    """Records in the shape `QosPolicy.from_records` consumes: one TAF
    decode rung per (error, speedup) pair, distinct thresholds."""
    return [
        {"app": "taf_decode",
         "spec": {"technique": "taf", "level": "block", "hSize": 2,
                  "pSize": 4, "thresh": 0.01 * (i + 1)},
         "error": float(e), "speedup": float(s),
         "modeled_speedup": float(s), "workload": {}}
        for i, (e, s) in enumerate(pairs)]


def _policy(pairs):
    from repro import qos
    return qos.QosPolicy.from_records(_ladder_records(pairs), metric="mcr")


class TestQosLadderProperties:
    @SET
    @given(st.lists(st.tuples(st.floats(1e-4, 0.5), st.floats(1.01, 4.0)),
                    min_size=1, max_size=6),
           st.floats(1e-4, 0.6), st.floats(0.0, 0.4))
    def test_selection_monotone_in_error_bound(self, pairs, bound, delta):
        """Loosening the error bound can only hold or advance the chosen
        rung -- never retreat it (in index OR in speedup): the qualifying
        set grows monotonically with the bound."""
        pol = _policy(pairs)
        lo, hi = pol.select(bound), pol.select(bound + delta)
        assert lo <= hi
        assert pol.entries[lo].speedup <= pol.entries[hi].speedup

    @SET
    @given(st.lists(st.tuples(st.floats(1e-4, 0.5), st.floats(0.5, 4.0)),
                    min_size=1, max_size=8))
    def test_pareto_front_idempotent(self, pairs):
        """`pareto_front` is a closure operator: re-running it on its own
        output is the identity."""
        from repro.core import pareto
        recs = _ladder_records(pairs)
        front = pareto.pareto_front(recs)
        again = pareto.pareto_front(front)
        key = lambda r: (r["error"], r["speedup"])
        assert sorted(map(key, again)) == sorted(map(key, front))

    @SET
    @given(st.integers(2, 6), st.data())
    def test_strictest_reduction_order_independent(self, n_shards, data):
        """The strictest-live-rung reduction commutes with any permutation
        of the shard list: per-shard indices permute along, the global
        rung is invariant -- min over live shards is order-free."""
        from repro import qos
        idx = {c: data.draw(st.integers(0, 3), label=f"idx[{c}]")
               for c in ("default", "batch")}
        shard_classes = [
            data.draw(st.lists(st.sampled_from(["default", "batch"]),
                               max_size=3), label=f"shard{s}")
            for s in range(n_shards)]
        perm = data.draw(st.permutations(range(n_shards)))

        def plan(sc):
            eng = qos.QosEngine(
                _policy([(0.005, 1.2), (0.02, 1.5), (0.08, 2.0)]),
                {"default": 0.10, "batch": 0.5}, sample_fraction=1.0,
                window=8)
            eng.enable_sharding(len(sc))
            for c, i in idx.items():
                eng.controller(c).index = i
            return eng.plan_shards(sc)

        p1 = plan(shard_classes)
        p2 = plan([shard_classes[p] for p in perm])
        assert p1.index == p2.index
        assert tuple(p1.shard_indices[p] for p in perm) == p2.shard_indices
        assert tuple(p1.shard_knobs[p] for p in perm) == p2.shard_knobs


class TestCostModelProperties:
    """Closed-form invariants of the analytical predictor
    (repro.analysis.cost): knob monotonicity and bound sanity, over
    randomized knob values rather than the fixed grids in
    tests/test_costmodel.py."""

    def _model(self):
        from repro.analysis.cost import ladder_model
        return ladder_model()

    @SET
    @given(st.floats(0.05, 0.9), st.floats(0.05, 0.9))
    def test_perforation_speedup_monotone_in_fraction(self, f1, f2):
        from repro.core.types import ApproxSpec, Technique
        lo, hi = sorted((f1, f2))
        model = self._model()

        def spd(f):
            return model.predict(ApproxSpec(
                Technique.PERFORATION,
                perforation=PerforationParams(kind=PerforationKind.INI,
                                              fraction=f))).speedup

        assert spd(hi) >= spd(lo) - 1e-12

    @SET
    @given(st.floats(0.01, 5.0), st.floats(0.01, 5.0))
    def test_taf_error_bound_monotone_in_threshold(self, t1, t2):
        from repro.core.types import ApproxSpec, Technique
        lo, hi = sorted((t1, t2))
        model = self._model()

        def bound(t):
            return model.predict(ApproxSpec(
                Technique.TAF, taf=TAFParams(2, 4, t))).error_bound

        assert bound(hi) >= bound(lo) - 1e-12

    @SET
    @given(st.floats(0.01, 5.0), st.floats(0.01, 5.0))
    def test_taf_speedup_monotone_in_threshold(self, t1, t2):
        from repro.core.types import ApproxSpec, Technique
        lo, hi = sorted((t1, t2))
        model = self._model()

        def spd(t):
            return model.predict(ApproxSpec(
                Technique.TAF, taf=TAFParams(2, 4, t))).speedup

        assert spd(hi) >= spd(lo) - 1e-12

    @SET
    @given(st.integers(1, 12), st.floats(0.01, 5.0))
    def test_predictions_finite_and_skip_fraction_bounded(self, h, t):
        from repro.core.types import ApproxSpec, Technique
        model = self._model()
        p = model.predict(ApproxSpec(Technique.TAF,
                                     taf=TAFParams(h, 4, t)))
        assert p.error_bound >= 0.0
        assert np.isfinite(p.error_bound) and np.isfinite(p.speedup)
        assert 0.0 <= p.skip_fraction <= 1.0
