"""Block-shape autotuner (`kernels/tuning.py`): search-space validity,
pipelined-variant bit parity, cache determinism, compile-count guarantees,
and the A002 tuning-cache audit."""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.types import PerforationKind, PerforationParams
from repro.kernels import ops, tuning


@pytest.fixture(autouse=True)
def _isolated_cache():
    """No test may read or write the committed tuning cache: pin an empty
    in-memory cache as the ambient default and restore lazy-loading after."""
    tuning.set_default_cache(tuning.TuningCache())
    yield
    tuning.set_default_cache(None)


def _arrays(kernel, seed=0):
    rng = np.random.RandomState(seed)

    def f32(*shape):
        return jnp.asarray(rng.randn(*shape).astype(np.float32))

    if kernel == "taf_matmul":
        return (f32(128, 32), f32(32, 32))
    if kernel == "iact_rowfn":
        return (f32(128, 32), f32(32, 64), f32(64, 32))
    if kernel == "perforated_matmul":
        return (f32(64, 64), f32(64, 64))
    if kernel == "perforated_attention":
        q = f32(1, 2, 128, 16)
        return (q, q, q)
    raise ValueError(kernel)


class TestSearchSpace:
    @pytest.mark.parametrize("kernel", tuning.KERNELS)
    def test_all_candidates_divisor_valid(self, kernel):
        shapes = tuning.operand_shapes(_arrays(kernel))
        space = tuning.search_space(kernel, shapes)
        assert space
        for cfg in space:
            assert tuning.validate_config(kernel, shapes, cfg) is None
        # deterministic enumeration (the pre-prune tie-break relies on it)
        assert space == tuning.search_space(kernel, shapes)

    def test_rejects_non_divisors_and_unknowns(self):
        shapes = ((128, 32), (32, 32))
        assert "does not divide" in tuning.validate_config(
            "taf_matmul", shapes, {"block_m": 48, "block_n": 32})
        assert "missing" in tuning.validate_config(
            "taf_matmul", shapes, {"block_m": 32})
        assert "unknown to" in tuning.validate_config(
            "taf_matmul", shapes,
            {"block_m": 32, "block_n": 32, "block_k": 32})
        assert "unknown kernel" in tuning.validate_config(
            "nope", shapes, {})

    def test_vmem_budget_bounds_the_space(self):
        shapes = tuning.operand_shapes(_arrays("perforated_matmul"))
        for cfg in tuning.search_space("perforated_matmul", shapes):
            assert tuning.vmem_bytes("perforated_matmul", shapes,
                                     cfg) <= tuning.VMEM_BUDGET_BYTES

    def test_non_pow2_axis_gets_the_full_axis(self):
        # 96 has no pow2 divisor above 32 in range; 8/16/32 divide it
        space = tuning.search_space("iact_rowfn",
                                    ((96, 32), (32, 64), (64, 32)))
        assert {c["block_rows"] for c in space} == {8, 16, 32}


class TestWrapperErrors:
    def test_taf_block_mismatch(self):
        x, w = _arrays("taf_matmul")
        with pytest.raises(ValueError, match="does not divide"):
            ops.taf_matmul(x, w, block_m=48, block_n=32)

    def test_taf_contraction_mismatch(self):
        x, _ = _arrays("taf_matmul")
        with pytest.raises(ValueError, match="contraction"):
            ops.taf_matmul(x, jnp.zeros((16, 32)), block_m=32, block_n=32)

    def test_iact_block_mismatch(self):
        x, w1, w2 = _arrays("iact_rowfn")
        with pytest.raises(ValueError, match="does not divide"):
            ops.iact_rowfn(x, w1, w2, block_rows=48)

    def test_pmm_block_mismatch(self):
        x, w = _arrays("perforated_matmul")
        with pytest.raises(ValueError, match="does not divide"):
            ops.perforated_matmul(x, w, block_m=48, block_n=32, block_k=32)

    def test_attention_block_mismatch(self):
        q, k, v = _arrays("perforated_attention")
        with pytest.raises(ValueError, match="does not divide"):
            ops.flash_attention(q, k, v, block_q=48, block_kv=32)


class TestPipelineParity:
    """pipeline=True adds parallel dimension_semantics on the state-free
    grid axes; outputs and approx masks must stay BIT-equal."""

    def _check(self, out_t, out_f):
        for a, b in zip(jax.tree_util.tree_leaves(out_t),
                        jax.tree_util.tree_leaves(out_f)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    @pytest.mark.parametrize("th", [0.0, 0.5, 5.0])
    def test_taf(self, th):
        x, w = _arrays("taf_matmul")
        self._check(
            ops.taf_matmul(x, w, block_m=16, block_n=16, rsd_threshold=th,
                           pipeline=True),
            ops.taf_matmul(x, w, block_m=16, block_n=16, rsd_threshold=th,
                           pipeline=False))

    @pytest.mark.parametrize("perfo", [
        None,
        PerforationParams(kind=PerforationKind.SMALL, skip=2),
        PerforationParams(kind=PerforationKind.INI, fraction=0.5),
    ])
    def test_pmm(self, perfo):
        x, w = _arrays("perforated_matmul")
        self._check(
            ops.perforated_matmul(x, w, block_m=16, block_n=16, block_k=16,
                                  perfo=perfo, pipeline=True),
            ops.perforated_matmul(x, w, block_m=16, block_n=16, block_k=16,
                                  perfo=perfo, pipeline=False))

    @pytest.mark.parametrize("fr", [None, 0.5])
    def test_attention(self, fr):
        q, k, v = _arrays("perforated_attention")
        perfo = (None if fr is None else
                 PerforationParams(kind=PerforationKind.INI, fraction=0.0))
        self._check(
            ops.perforated_attention(q, k, v, block_q=32, block_kv=32,
                                     perfo=perfo, fraction=fr,
                                     pipeline=True),
            ops.perforated_attention(q, k, v, block_q=32, block_kv=32,
                                     perfo=perfo, fraction=fr,
                                     pipeline=False))

    def test_iact_has_no_pipeline_arg(self):
        # its single grid axis is sequential (memo table carries across
        # every block): offering pipeline= would promise a variant that
        # cannot exist
        import inspect
        assert "pipeline" not in inspect.signature(
            ops.iact_rowfn).parameters


class TestAutotune:
    def test_deterministic_winner_and_hit_skips_measurement(self):
        x, w = _arrays("taf_matmul")
        calls = []

        def fake_timer(fn, args):
            calls.append(1)
            # deterministic: larger blocks "faster" (fewer grid steps)
            return 1.0 / float(np.asarray(fn(*args)).size or 1)

        c1, c2 = tuning.TuningCache(), tuning.TuningCache()
        cfg1 = tuning.autotune("taf_matmul", x, w, cache=c1,
                               measure_fn=fake_timer)
        n_after_first = len(calls)
        cfg2 = tuning.autotune("taf_matmul", x, w, cache=c2,
                               measure_fn=fake_timer)
        assert cfg1 == cfg2  # same inputs -> same winner
        # cache hit: no new measurements, same config back
        cfg3 = tuning.autotune("taf_matmul", x, w, cache=c1,
                               measure_fn=fake_timer)
        assert cfg3 == cfg1
        assert len(calls) == 2 * n_after_first

    def test_measure_false_uses_cost_model_ranking(self):
        x, w = _arrays("taf_matmul")
        cache = tuning.TuningCache()
        cfg = tuning.autotune("taf_matmul", x, w, cache=cache,
                              measure=False)
        assert tuning.validate_config(
            "taf_matmul", tuning.operand_shapes((x, w)), cfg) is None
        (entry,) = cache.entries.values()
        assert entry["measured"] == 0

    def test_cache_roundtrip_and_entry_validity(self, tmp_path):
        x, w = _arrays("taf_matmul")
        path = str(tmp_path / "cache.json")
        cache = tuning.TuningCache(path=path)
        tuning.autotune("taf_matmul", x, w, cache=cache, measure=False)
        loaded = tuning.TuningCache.load(path)
        assert loaded.entries == cache.entries
        for key, entry in loaded.entries.items():
            assert tuning.validate_entry(key, entry) is None

    def test_attention_key_uses_canonical_operands(self):
        # v mirrors k: the cache key must be (q, k) so `ops` lookups
        # (which pass two operands) hit entries tuned from three
        q, k, v = _arrays("perforated_attention")
        cache = tuning.TuningCache()
        cfg = tuning.autotune("perforated_attention", q, k, v, cache=cache,
                              measure=False)
        hit = tuning.tuned_config(
            "perforated_attention", tuning.operand_shapes((q, k)),
            cache=cache)
        assert hit == cfg


class TestOpsResolution:
    def test_none_blocks_resolve_from_ambient_cache(self):
        x, w = _arrays("taf_matmul")
        cache = tuning.TuningCache()
        key = tuning.cache_key("taf_matmul", ((128, 32), (32, 32)),
                               "float32", tuning.current_machine_name(),
                               tuning.current_substrate())
        cache.put(key, {"config": {"block_m": 64, "block_n": 16}})
        tuning.set_default_cache(cache)
        b = ops._resolve_blocks("taf_matmul", (x, w), x.dtype,
                                block_m=None, block_n=None)
        assert b == {"block_m": 64, "block_n": 16}
        # explicit ints always win over the cache
        b = ops._resolve_blocks("taf_matmul", (x, w), x.dtype,
                                block_m=32, block_n=32)
        assert b == {"block_m": 32, "block_n": 32}

    def test_miss_falls_back_to_historical_defaults(self):
        x = jnp.zeros((256, 256), jnp.float32)
        b = ops._resolve_blocks("perforated_matmul", (x, x), x.dtype,
                                block_m=None, block_n=None, block_k=None)
        assert b == tuning.FALLBACK_BLOCKS["perforated_matmul"]

    def test_zero_recompiles_across_threshold_sweep_with_tuned_blocks(self):
        # tuned geometry must not break the one-compile-per-structural-
        # group contract: 16 thresholds through cache-resolved blocks
        from repro.kernels.taf_matmul import taf_matmul as taf_jit
        x, w = _arrays("taf_matmul")
        cache = tuning.TuningCache()
        key = tuning.cache_key("taf_matmul", ((128, 32), (32, 32)),
                               "float32", tuning.current_machine_name(),
                               tuning.current_substrate())
        cache.put(key, {"config": {"block_m": 32, "block_n": 16}})
        tuning.set_default_cache(cache)
        jax.block_until_ready(ops.taf_matmul(x, w, rsd_threshold=0.1)[0])
        before = taf_jit._cache_size()
        for th in np.linspace(0.05, 2.0, 16):
            jax.block_until_ready(
                ops.taf_matmul(x, w, rsd_threshold=float(th))[0])
        assert taf_jit._cache_size() == before


class TestTuningCacheAudit:
    """Lint rule A002 over committed tuning caches."""

    def _audit(self, monkeypatch, path):
        from repro.analysis import rules
        monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))
        return rules._check_tuning_cache()

    def _write(self, path, entries):
        with open(path, "w") as f:
            json.dump({"version": 1, "entries": entries}, f)

    def _entry(self, **over):
        e = {"kernel": "taf_matmul", "shapes": [[128, 32], [32, 32]],
             "dtype": "float32", "machine": "host-sim",
             "substrate": "interpret",
             "config": {"block_m": 32, "block_n": 32}, "us": 1.0}
        e.update(over)
        return e

    def _key(self, e):
        return tuning.cache_key(e["kernel"], e["shapes"], e["dtype"],
                                e["machine"], e["substrate"])

    def test_valid_cache_is_clean(self, monkeypatch, tmp_path):
        p = tmp_path / "cache.json"
        e = self._entry()
        self._write(p, {self._key(e): e})
        assert self._audit(monkeypatch, p) == []

    def test_non_dividing_block_is_a_finding(self, monkeypatch, tmp_path):
        p = tmp_path / "cache.json"
        e = self._entry(config={"block_m": 48, "block_n": 32})
        self._write(p, {self._key(e): e})
        (f,) = self._audit(monkeypatch, p)
        assert f.rule == "A002" and "does not divide" in f.message

    def test_stale_machine_key_is_a_finding(self, monkeypatch, tmp_path):
        p = tmp_path / "cache.json"
        for machine in ("old-gpu", "measured"):
            e = self._entry(machine=machine)
            self._write(p, {self._key(e): e})
            (f,) = self._audit(monkeypatch, p)
            assert f.rule == "A002" and "no substrate maps" in f.message

    def test_hand_edited_key_is_a_finding(self, monkeypatch, tmp_path):
        p = tmp_path / "cache.json"
        e = self._entry()
        self._write(p, {self._key(e).replace("128", "256", 1): e})
        (f,) = self._audit(monkeypatch, p)
        assert "stale or hand-edited" in f.message

    def test_unreadable_cache_is_a_finding(self, monkeypatch, tmp_path):
        p = tmp_path / "cache.json"
        p.write_text("{not json")
        (f,) = self._audit(monkeypatch, p)
        assert "unreadable" in f.message

    def test_missing_cache_is_silent(self, monkeypatch, tmp_path):
        assert self._audit(monkeypatch, tmp_path / "absent.json") == []

    def test_committed_cache_passes_its_own_audit(self, monkeypatch):
        import os
        from repro.analysis import rules
        monkeypatch.delenv("REPRO_TUNING_CACHE", raising=False)
        path = tuning.default_cache_path()
        if path is None or not os.path.exists(path):
            pytest.skip("no committed tuning cache")
        assert rules._check_tuning_cache() == []
