"""Tests for the section-Perf optimization features (EXPERIMENTS.md):
int8 KV cache, causally-exact herded KV perforation, grouped-GQA decode,
shard_hint no-mesh fallback, expert perforation."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core.types import parse_pragma
from repro.models import build, common

KEY = jax.random.PRNGKey(0)


def test_int8_kv_cache_decode_close_to_exact():
    base = dataclasses.replace(get_smoke_config("qwen3-1.7b"), remat=False,
                               compute_dtype="float32")
    cfg8 = dataclasses.replace(base, kv_cache_dtype="int8")
    m0, m8 = build(base), build(cfg8)
    params = m0.init(KEY)
    rng = np.random.RandomState(0)
    tokens = jnp.asarray(rng.randint(0, base.vocab_size, (2, 12)), jnp.int32)
    batch = {"tokens": tokens[:, :8], "max_len": 12}
    _, c0 = m0.prefill(params, batch)
    _, c8 = m8.prefill(params, batch)
    assert c8["dense"]["k"].dtype == jnp.int8 and "k_scale" in c8["dense"]
    for t in range(3):
        l0, c0 = m0.decode_step(params, c0, tokens[:, 8 + t], jnp.int32(8 + t))
        l8, c8 = m8.decode_step(params, c8, tokens[:, 8 + t], jnp.int32(8 + t))
        scale = float(jnp.abs(l0).max()) + 1e-6
        assert float(jnp.abs(l0 - l8).max()) / scale < 0.05


def test_int8_cache_is_half_the_bytes():
    cfg8 = dataclasses.replace(get_smoke_config("qwen3-1.7b"),
                               kv_cache_dtype="int8")
    m8 = build(cfg8)
    c8 = jax.eval_shape(lambda: m8.init_cache(4, 64))
    cbf = jax.eval_shape(lambda: build(get_smoke_config("qwen3-1.7b"))
                         .init_cache(4, 64))
    bytes8 = sum(np.prod(l.shape) * l.dtype.itemsize
                 for l in jax.tree.leaves(c8) if l.dtype in
                 (jnp.int8, jnp.bfloat16))
    bytesbf = sum(np.prod(l.shape) * l.dtype.itemsize
                  for l in jax.tree.leaves(cbf))
    assert bytes8 < 0.7 * bytesbf


def test_herded_kv_perforation_is_causally_exact():
    """Kept-position masking == full attention with dropped blocks masked."""
    rng = np.random.RandomState(1)
    q = jnp.asarray(rng.randn(1, 2, 32, 16), jnp.float32)
    k = jnp.asarray(rng.randn(1, 2, 64, 16), jnp.float32)
    v = jnp.asarray(rng.randn(1, 2, 64, 16), jnp.float32)
    kv_pos = np.concatenate([np.arange(0, 32), np.arange(48, 64)])
    kk = jnp.take(k, jnp.asarray(kv_pos), 2)
    vv = jnp.take(v, jnp.asarray(kv_pos), 2)
    out = common.chunked_attention(q, kk, vv, causal=True, q_chunk=8,
                                   kv_chunk=8, kv_positions=kv_pos)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) / 4.0
    qi = jnp.arange(32)[:, None] + 32
    ki = jnp.arange(64)[None, :]
    mask = (ki <= qi) & ((ki < 32) | (ki >= 48))
    probs = jax.nn.softmax(jnp.where(mask[None, None], logits, -1e30), -1)
    ref = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_perforated_training_runs_and_shrinks_compute():
    cfg = dataclasses.replace(
        get_smoke_config("deepseek-7b"), remat=False,
        approx_attention=parse_pragma("perfo(ini:0.5)"),
        approx_ffn=parse_pragma("perfo(small:4)"))
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.RandomState(2)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 256))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 256)))}
    loss, _ = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    assert all(np.isfinite(np.asarray(x, np.float32)).all()
               for x in jax.tree.leaves(g))


def test_expert_perforation_uses_fewer_experts():
    cfg = dataclasses.replace(
        get_smoke_config("olmoe-1b-7b"), remat=False,
        approx_ffn=parse_pragma("perfo(small:2)"))
    model = build(cfg)
    params = model.init(KEY)
    rng = np.random.RandomState(3)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 64))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (2, 64)))}
    loss, _ = model.loss(params, batch)
    assert bool(jnp.isfinite(loss))


def test_shard_hint_identity_without_mesh():
    x = jnp.ones((8, 4))
    y = common.shard_hint(x, "data", "model")
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    # inside jit without mesh context: still fine
    z = jax.jit(lambda a: common.shard_hint(a * 2, ("pod", "data"), None))(x)
    np.testing.assert_allclose(np.asarray(z), 2.0)


def test_grouped_gqa_decode_matches_repeat_form():
    rng = np.random.RandomState(4)
    q = jnp.asarray(rng.randn(2, 8, 1, 16), jnp.float32)
    k = jnp.asarray(rng.randn(2, 2, 32, 16), jnp.float32)
    v = jnp.asarray(rng.randn(2, 2, 32, 16), jnp.float32)
    out = common.decode_attention(q, k, v, valid_len=20)
    kr = jnp.repeat(k, 4, axis=1)
    vr = jnp.repeat(v, 4, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, kr) / 4.0
    mask = jnp.arange(32)[None, None, None, :] < 20
    probs = jax.nn.softmax(jnp.where(mask, logits, -1e30), -1)
    ref = jnp.einsum("bhqk,bhkd->bhqd", probs, vr)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_grad_accumulation_matches_full_batch():
    """accum_steps=4 == full-batch step (same grads up to fp tolerance)."""
    from repro.launch import steps as steps_mod
    from repro.optim import adamw
    cfg = dataclasses.replace(get_smoke_config("qwen3-1.7b"), remat=False,
                              compute_dtype="float32")
    model = build(cfg)
    params = model.init(KEY)
    opt = adamw.init(params)
    rng = np.random.RandomState(7)
    batch = {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32))),
             "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (8, 32)))}
    full = steps_mod.make_train_step(model, adamw.AdamWConfig(lr=1e-3))
    acc = steps_mod.make_train_step_accum(model, adamw.AdamWConfig(lr=1e-3),
                                          accum_steps=4)
    p1, _, m1 = jax.jit(full)(params, opt, batch)
    p2, _, m2 = jax.jit(acc)(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    dmax = max(float(jnp.abs(a - b).max())
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert dmax < 1e-4, dmax
