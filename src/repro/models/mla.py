"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and KV are low-rank compressed; the KV cache stores ONLY the
compressed latent (kv_lora_rank) plus the shared rope key (qk_rope_head_dim)
per position -- 576 floats/token for dsv3 instead of 2*128*128: the reason
decode_32k fits. Decode recomputes k/v from the cached latent (the
"naive" expansion; the absorbed-matmul variant is a hillclimb candidate
recorded in EXPERIMENTS.md section Perf).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import common


def init_params(key, cfg: ModelConfig, dtype) -> Dict:
    m = cfg.mla
    d = cfg.d_model
    h = cfg.n_heads
    ks = jax.random.split(key, 7)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": common.dense_init(ks[0], (d, m.q_lora_rank), dtype=dtype),
        "q_norm": common.rmsnorm_params(m.q_lora_rank, dtype),
        "w_uq": common.dense_init(ks[1], (m.q_lora_rank, h * qk_head),
                                  dtype=dtype),
        "w_dkv": common.dense_init(
            ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), dtype=dtype),
        "kv_norm": common.rmsnorm_params(m.kv_lora_rank, dtype),
        "w_uk": common.dense_init(ks[3], (m.kv_lora_rank,
                                          h * m.qk_nope_head_dim), dtype=dtype),
        "w_uv": common.dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim),
                                  dtype=dtype),
        "wo": common.dense_init(ks[5], (h * m.v_head_dim, d), dtype=dtype),
    }


def _queries(p, cfg: ModelConfig, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    w_dq = common.shard_hint(p["w_dq"], None, "model")
    cq = common.rmsnorm(p["q_norm"],
                        jnp.einsum("bsd,dr->bsr", x, w_dq.astype(x.dtype)),
                        cfg.norm_eps)
    w_uq = common.shard_hint(p["w_uq"], None, "model")
    q = jnp.einsum("bsr,rh->bsh", cq, w_uq.astype(x.dtype))
    q = q.reshape(b, s, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q = q.transpose(0, 2, 1, 3)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = common.apply_rope(q[..., m.qk_nope_head_dim:], positions,
                               cfg.rope_theta)
    return jnp.concatenate([q_nope, q_rope], axis=-1)


def _latent(p, cfg: ModelConfig, x, positions):
    """Compressed latent ckv (B,S,R) + shared rope key (B,1,S,rope_d)."""
    m = cfg.mla
    w_dkv = common.shard_hint(p["w_dkv"], None, "model")
    dkv = jnp.einsum("bsd,dr->bsr", x, w_dkv.astype(x.dtype))
    ckv = common.rmsnorm(p["kv_norm"], dkv[..., :m.kv_lora_rank], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:][:, None]              # (B,1,S,rd)
    k_rope = common.apply_rope(k_rope, positions, cfg.rope_theta)
    return ckv, k_rope


def _expand_kv(p, cfg: ModelConfig, ckv, k_rope):
    """Expand latent to per-head K (nope||rope) and V."""
    m = cfg.mla
    b, s, _ = ckv.shape
    h = cfg.n_heads
    w_uk = common.shard_hint(p["w_uk"], None, "model")
    k_nope = jnp.einsum("bsr,rh->bsh", ckv, w_uk.astype(ckv.dtype))
    k_nope = k_nope.reshape(b, s, h, m.qk_nope_head_dim).transpose(0, 2, 1, 3)
    w_uv = common.shard_hint(p["w_uv"], None, "model")
    v = jnp.einsum("bsr,rh->bsh", ckv, w_uv.astype(ckv.dtype))
    v = v.reshape(b, s, h, m.v_head_dim).transpose(0, 2, 1, 3)
    k_rope_b = jnp.broadcast_to(k_rope, (b, h, s, m.qk_rope_head_dim))
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return k, v


def forward(p, cfg: ModelConfig, x: jnp.ndarray, positions,
            causal: bool = True, approx=None) -> jnp.ndarray:
    b, s, _ = x.shape
    q = _queries(p, cfg, x, positions)
    ckv, k_rope = _latent(p, cfg, x, positions)
    k, v = _expand_kv(p, cfg, ckv, k_rope)
    ctx = common.chunked_attention(q, k, v, causal=causal)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", ctx, p["wo"].astype(x.dtype))


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, 1, max_len, m.qk_rope_head_dim), dtype),
    }


def prefill(p, cfg: ModelConfig, x, cache, approx=None) -> Tuple[jnp.ndarray, Dict]:
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q = _queries(p, cfg, x, positions)
    ckv, k_rope = _latent(p, cfg, x, positions)
    k, v = _expand_kv(p, cfg, ckv, k_rope)
    ctx = common.chunked_attention(q, k, v, causal=True)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, -1)
    out = jnp.einsum("bsh,hd->bsd", ctx, p["wo"].astype(x.dtype))
    cache = {
        "ckv": jax.lax.dynamic_update_slice(
            cache["ckv"], ckv.astype(cache["ckv"].dtype), (0, 0, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype),
            (0, 0, 0, 0)),
    }
    return out, cache


def decode_step(p, cfg: ModelConfig, x, cache, pos,
                approx=None) -> Tuple[jnp.ndarray, Dict]:
    """ABSORBED MLA decode (section Perf iteration B6, the DeepSeek serving form):

      logits[s] = (q_nope W_uk) . ckv[s] + q_rope . k_rope[s]
      ctx       = (softmax . ckv) W_uv

    K/V are never expanded: per layer the step reads the (B,S,R) latent
    cache once (dsv3: 268 MB/dev) instead of materializing (B,H,S,192+128)
    expansions (~26 GB/dev). More latent-side FLOPs (R=512 vs 192 per
    score), the right trade for a memory-bound decode.
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    positions = jnp.full((1,), pos, jnp.int32)
    q = _queries(p, cfg, x, positions)                       # (B,H,1,qk)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = q[..., m.qk_nope_head_dim:]
    ckv_t, k_rope_t = _latent(p, cfg, x, positions)
    cache = {
        "ckv": jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_t.astype(cache["ckv"].dtype), (0, pos, 0)),
        "k_rope": jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_t.astype(cache["k_rope"].dtype),
            (0, 0, pos, 0)),
    }
    ckv = cache["ckv"].astype(x.dtype)                       # (B,S,R)
    k_rope = cache["k_rope"].astype(x.dtype)[:, 0]           # (B,S,rd)
    skv = ckv.shape[1]
    da = common.data_axes_hint()
    # absorb W_uk into the query: (R, H*nope) -> (H, nope, R)
    w_uk = common.shard_hint(p["w_uk"], None, "model").astype(x.dtype)
    w_uk = w_uk.reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhqd,rhd->bhqr", q_nope, w_uk)       # (B,H,1,R)
    logits = jnp.einsum("bhqr,bsr->bhqs", q_lat, ckv,
                        preferred_element_type=jnp.float32)
    logits = logits + jnp.einsum("bhqd,bsd->bhqs", q_rope, k_rope,
                                 preferred_element_type=jnp.float32)
    logits = logits / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    logits = common.shard_hint(logits, da, None, None, "model")
    mask = jnp.arange(skv)[None, None, None, :] <= pos
    logits = jnp.where(mask, logits, -1e30)
    mx = jnp.max(logits, axis=-1, keepdims=True)
    pr = jnp.exp(logits - mx)
    l = jnp.sum(pr, axis=-1, keepdims=True)
    ctx_lat = jnp.einsum("bhqs,bsr->bhqr", pr.astype(x.dtype), ckv,
                         preferred_element_type=jnp.float32)
    ctx_lat = (ctx_lat / jnp.maximum(l, 1e-30)).astype(x.dtype)
    # absorb W_uv on the way out: (R, H*dv) -> (H, R, dv)
    w_uv = common.shard_hint(p["w_uv"], None, "model").astype(x.dtype)
    w_uv = w_uv.reshape(m.kv_lora_rank, h, m.v_head_dim)
    ctx = jnp.einsum("bhqr,rhd->bhqd", ctx_lat, w_uv)        # (B,H,1,dv)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    return jnp.einsum("bsh,hd->bsd", ctx, p["wo"].astype(x.dtype)), cache
