"""Model zoo: pure-functional JAX models for all assigned architectures."""
from . import (attention, blocks, common, lm, mamba2, mla, mlp, moe, rwkv6,
               whisper)
from .lm import Model, build

__all__ = ["attention", "blocks", "common", "lm", "mamba2", "mla", "mlp",
           "moe", "rwkv6", "whisper", "Model", "build"]
