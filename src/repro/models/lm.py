"""Causal LM assembly for all decoder-only families:

  dense / vlm  -- GQA (or MLA) transformer, optionally with patch-embedding
                  prefix (pixtral: frontend stubbed per the brief)
  moe          -- transformer with MoE FFN (+ leading dense layers, MTP)
  hybrid       -- zamba2: Mamba2 backbone + shared attention block
  ssm          -- rwkv6 (attention-free)

Layer loops are lax.scan over STACKED block params (compile-time O(1) in
depth; remat via jax.checkpoint when cfg.remat). The head loss is computed
in sequence chunks so the (B, S, V) logits tensor is never materialized.

Decode-time TAF (paper section 3.1.3 as a serving feature): with
cfg.approx_decode = TAF, each transformer layer carries a TAF state machine
across decode steps; when a layer's recent output deltas are RSD-stable the
whole layer's compute is SKIPPED (block-level lax.cond -- the hierarchy
insight) and the memoized delta + stale K/V are reused.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.types import Level, Technique
from . import attention, blocks, common, mamba2, mlp, moe, rwkv6

PyTree = Any


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def _stack_init(init_fn, key, n: int):
    """vmap an init function over n split keys -> stacked params."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


def chunked_xent(h: jnp.ndarray, head_w: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None,
                 chunk: int = 512) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-entropy without materializing (B, S, V). Returns (sum_nll, count)."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    while s % chunk != 0:
        chunk //= 2
    nc = s // chunk
    hc = h.reshape(b, nc, chunk, d).transpose(1, 0, 2, 3)
    yc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = (mask if mask is not None else
          jnp.ones_like(labels, jnp.float32)).reshape(
              b, nc, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        h_i, y_i, m_i = inp
        logits = jnp.einsum("bcd,dv->bcv", h_i,
                            head_w.astype(h_i.dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, y_i[..., None].astype(jnp.int32), axis=-1)[..., 0]
        nll = (logz - gold) * m_i
        s_nll, s_cnt = carry
        return (s_nll + jnp.sum(nll), s_cnt + jnp.sum(m_i)), None

    (total, count), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)),
                                     (hc, yc, mc))
    return total, count


@dataclasses.dataclass
class Model:
    """Bound functional interface for one architecture."""

    cfg: ModelConfig
    init: Any
    hidden: Any          # (params, batch) -> (B, S, d) final hidden states
    loss: Any            # (params, batch) -> (loss, metrics)
    init_cache: Any      # (batch_size, max_len) -> cache pytree
    prefill: Any         # (params, batch) -> (last_logits, cache)
    decode_step: Any     # (params, cache, tokens(B,), pos) -> (logits, cache)


# ============================================================================
# decode-time TAF sharding (the serving data plane's per-shard knob layout)
# ============================================================================

# The TAF detector-state leaves of `_taf_init_cache`: per-layer scalars or
# small vectors with NO batch dim. These are the leaves that become
# PER-SHARD under a sharded serving engine -- each logical shard runs its
# own stability detector (window/filled/remaining) and its own traced
# threshold knob, so a QoS controller can tighten one shard while another
# keeps approximating, without recompiling. The memo_* leaves already carry
# the batch dim and shard along it like the KV cache.
TAF_SHARD_STATE = ("threshold", "window", "filled", "remaining")


def shard_taf_state(cache, n_shards: int):
    """Return `cache` with the TAF detector state replicated per shard.

    Each `TAF_SHARD_STATE` leaf (n_layers, ...) gains a LEADING shard dim:
    (n_shards, n_layers, ...). `make_sharded_serve_step` vmaps the decode
    step over that dim, so every shard evolves an independent detector --
    the batch-global stability statistic (`jnp.mean(delta)` in
    `_decode_layer_taf`) becomes a per-shard statistic over the shard's own
    lanes. A no-op for caches without a "taf" entry (precise models).
    """
    if "taf" not in cache:
        return cache
    taf = dict(cache["taf"])
    for key in TAF_SHARD_STATE:
        leaf = taf[key]
        taf[key] = jnp.broadcast_to(leaf[None], (n_shards,) + leaf.shape)
    return dict(cache, taf=taf)


# ============================================================================
# transformer families: dense / vlm / moe
# ============================================================================

def _build_transformer(cfg: ModelConfig) -> Model:
    pdt = _dtype(cfg.param_dtype)
    cdt = _dtype(cfg.compute_dtype)
    n_dense = cfg.moe.n_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0
    if cfg.moe is None:
        n_dense = cfg.n_layers

    def init(key) -> PyTree:
        k_embed, k_dense, k_moe, k_norm, k_head, k_mtp = jax.random.split(key, 6)
        p: Dict = {
            "embed": common.embed_init(k_embed, (cfg.padded_vocab_size, cfg.d_model),
                                       pdt),
            "final_norm": common.norm_params(cfg.norm, cfg.d_model, pdt),
        }
        if not cfg.tie_embeddings:
            p["head"] = common.dense_init(k_head, (cfg.d_model, cfg.padded_vocab_size),
                                          dtype=pdt)
        if n_dense:
            p["dense_blocks"] = _stack_init(
                lambda k: blocks.init_block(k, cfg, pdt, use_moe=False),
                k_dense, n_dense)
        if n_moe:
            p["moe_blocks"] = _stack_init(
                lambda k: blocks.init_block(k, cfg, pdt, use_moe=True),
                k_moe, n_moe)
        if cfg.mtp:
            km1, km2 = jax.random.split(k_mtp)
            p["mtp"] = {
                "proj": common.dense_init(km1, (2 * cfg.d_model, cfg.d_model),
                                          dtype=pdt),
                "block": blocks.init_block(km2, cfg, pdt, use_moe=False),
            }
        return p

    def _embed(params, batch) -> jnp.ndarray:
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        if cfg.frontend == "vision_patches":
            patches = batch["patch_embeds"].astype(cdt)  # (B, P, d) stub
            x = jnp.concatenate([patches, x], axis=1)
        return x

    def _stack_scan(params, params_key: str, use_moe: bool, x, positions):
        def body(carry, layer_p):
            h, aux = carry
            h, a = blocks.block_forward(
                layer_p, cfg, h, positions, use_moe,
                approx_attn=cfg.approx_attention, approx_ffn=cfg.approx_ffn)
            return (h, aux + a), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = common.scan_layers(cfg.unroll_layers, body_fn,
                                         (x, jnp.float32(0)),
                                         params[params_key])
        return x, aux

    def hidden(params, batch):
        x = _embed(params, batch)
        positions = jnp.arange(x.shape[1])
        aux = jnp.float32(0)
        if n_dense:
            x, a = _stack_scan(params, "dense_blocks", False, x, positions)
            aux = aux + a
        if n_moe:
            x, a = _stack_scan(params, "moe_blocks", True, x, positions)
            aux = aux + a
        x = common.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        return x, aux

    def _head_w(params):
        return (params["embed"].T if cfg.tie_embeddings else params["head"])

    def loss(params, batch):
        x, aux = hidden(params, batch)
        if cfg.frontend == "vision_patches":
            x = x[:, batch["patch_embeds"].shape[1]:]  # text positions only
        total, count = chunked_xent(x, _head_w(params), batch["labels"])
        out = total / jnp.maximum(count, 1.0)
        metrics = {"xent": out, "aux_loss": aux}
        if cfg.mtp:
            # MTP: h'_t = block(W[h_t ; emb(token_{t+1})]) predicts t+2
            emb_next = jnp.take(params["embed"], batch["tokens"],
                                axis=0).astype(cdt)
            cat = jnp.concatenate(
                [x[:, :-1], emb_next[:, 1:]], axis=-1)
            hm = jnp.einsum("bsd,dk->bsk", cat,
                            params["mtp"]["proj"].astype(cdt))
            positions = jnp.arange(hm.shape[1])
            hm, _ = blocks.block_forward(params["mtp"]["block"], cfg, hm,
                                         positions, use_moe=False)
            mtp_labels = batch["labels"][:, 1:]
            t2, c2 = chunked_xent(hm, _head_w(params), mtp_labels)
            mtp_loss = t2 / jnp.maximum(c2, 1.0)
            metrics["mtp_loss"] = mtp_loss
            out = out + cfg.mtp_loss_coef * mtp_loss
        return out + aux, metrics

    def init_cache(batch_size: int, max_len: int):
        cache: Dict = {}
        if n_dense:
            cache["dense"] = jax.vmap(
                lambda _: blocks.init_block_cache(cfg, batch_size, max_len,
                                                  cdt))(jnp.arange(n_dense))
        if n_moe:
            cache["moe"] = jax.vmap(
                lambda _: blocks.init_block_cache(cfg, batch_size, max_len,
                                                  cdt))(jnp.arange(n_moe))
        if _taf_decode_enabled():
            cache["taf"] = _taf_init_cache(batch_size, cfg.n_layers)
        return cache

    def _prefill_stack(params_key, cache_key, use_moe, x, cache, params):
        def body(h, inp):
            layer_p, layer_c = inp
            h, new_c = blocks.block_prefill(
                layer_p, cfg, h, layer_c, use_moe,
                approx_attn=cfg.approx_attention, approx_ffn=cfg.approx_ffn)
            return h, new_c

        x, new_cache = common.scan_layers(
            cfg.unroll_layers, body, x,
            (params[params_key], cache[cache_key]))
        return x, new_cache

    def prefill(params, batch):
        x = _embed(params, batch)
        cache = init_cache(x.shape[0], batch["max_len"])
        if n_dense:
            x, cache["dense"] = _prefill_stack("dense_blocks", "dense", False,
                                               x, cache, params)
        if n_moe:
            x, cache["moe"] = _prefill_stack("moe_blocks", "moe", True,
                                             x, cache, params)
        x = common.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1],
                            _head_w(params).astype(cdt))
        return logits.astype(jnp.float32), cache

    # ----- decode-time TAF (the paper's technique as a serving feature) ----
    def _taf_decode_enabled() -> bool:
        return (cfg.approx_decode.technique == Technique.TAF
                and not cfg.use_mla and cfg.moe is None)

    def _taf_init_cache(batch_size: int, n_layers: int):
        t = cfg.approx_decode.taf
        hd = cfg.resolved_head_dim
        return {
            # The RSD threshold rides in the cache pytree (one scalar per
            # layer) rather than closing over the config float: it is a
            # TRACED input to the jitted decode step, so a controller (the
            # QoS plane, repro.qos) can move the knob between ticks without
            # recompiling -- the same static-vs-traced split the Pallas
            # kernels use for their quality knobs.
            "threshold": jnp.full((n_layers,), t.rsd_threshold, jnp.float32),
            "window": jnp.zeros((n_layers, t.history_size), jnp.float32),
            "filled": jnp.zeros((n_layers,), jnp.int32),
            "remaining": jnp.zeros((n_layers,), jnp.int32),
            "memo_delta": jnp.zeros((n_layers, batch_size, cfg.d_model),
                                    jnp.float32),
            "memo_k": jnp.zeros((n_layers, batch_size, cfg.n_kv_heads, 1, hd),
                                cdt),
            "memo_v": jnp.zeros((n_layers, batch_size, cfg.n_kv_heads, 1, hd),
                                cdt),
        }

    def _decode_layer_taf(layer_p, layer_c, taf_c, x, pos):
        """Block-level TAF around one layer's decode step: skip the whole
        layer (reuse memoized delta + stale K/V) while RSD-stable."""
        t = cfg.approx_decode.taf

        def approx_branch(op):
            x, layer_c, taf_c = op
            ck = jax.lax.dynamic_update_slice(
                layer_c["k"], taf_c["memo_k"], (0, 0, pos, 0))
            cv = jax.lax.dynamic_update_slice(
                layer_c["v"], taf_c["memo_v"], (0, 0, pos, 0))
            new_x = x + taf_c["memo_delta"][:, None, :].astype(x.dtype)
            new_taf = dict(taf_c)
            new_taf["remaining"] = jnp.maximum(taf_c["remaining"] - 1, 0)
            return new_x, {"k": ck, "v": cv}, new_taf

        def accurate_branch(op):
            x, layer_c, taf_c = op
            new_x, new_c = blocks.block_decode(
                layer_p, cfg, x, layer_c, pos, use_moe=False,
                approx_attn=cfg.approx_attention, approx_ffn=cfg.approx_ffn)
            delta = (new_x - x)[:, 0, :].astype(jnp.float32)
            s = jnp.mean(delta)
            win = jnp.roll(taf_c["window"], -1).at[-1].set(s)
            filled = jnp.minimum(taf_c["filled"] + 1, t.history_size)
            mu = jnp.mean(win)
            sd = jnp.std(win)
            stable = (sd / jnp.maximum(jnp.abs(mu), 1e-12) <
                      taf_c["threshold"]) & (filled >= t.history_size)
            k_t = jax.lax.dynamic_slice(
                new_c["k"], (0, 0, pos, 0),
                (new_c["k"].shape[0], new_c["k"].shape[1], 1,
                 new_c["k"].shape[3]))
            v_t = jax.lax.dynamic_slice(
                new_c["v"], (0, 0, pos, 0),
                (new_c["v"].shape[0], new_c["v"].shape[1], 1,
                 new_c["v"].shape[3]))
            new_taf = {
                "threshold": taf_c["threshold"],
                "window": win, "filled": filled,
                "remaining": jnp.where(stable, t.prediction_size, 0)
                .astype(jnp.int32),
                "memo_delta": delta, "memo_k": k_t, "memo_v": v_t,
            }
            return new_x, new_c, new_taf

        return jax.lax.cond(taf_c["remaining"] > 0, approx_branch,
                            accurate_branch, (x, layer_c, taf_c))

    def _decode_stack(params_key, cache_key, use_moe, x, cache, pos, params):
        if _taf_decode_enabled():
            def body(h, inp):
                layer_p, layer_c, taf_c = inp
                h, new_c, new_taf = _decode_layer_taf(layer_p, layer_c,
                                                      taf_c, h, pos)
                return h, (new_c, new_taf)

            x, (new_cache, new_taf) = common.scan_layers(
                cfg.unroll_layers, body, x,
                (params[params_key], cache[cache_key], cache["taf"]))
            return x, new_cache, new_taf

        def body(h, inp):
            layer_p, layer_c = inp
            h, new_c = blocks.block_decode(
                layer_p, cfg, h, layer_c, pos, use_moe,
                approx_attn=cfg.approx_attention, approx_ffn=cfg.approx_ffn)
            return h, new_c

        x, new_cache = common.scan_layers(
            cfg.unroll_layers, body, x,
            (params[params_key], cache[cache_key]))
        return x, new_cache, None

    def decode_step(params, cache, tokens, pos):
        """tokens: (B,) -> (logits (B, V), new cache)."""
        x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cdt)
        new_cache = dict(cache)
        if n_dense:
            x, nc, ntaf = _decode_stack("dense_blocks", "dense", False,
                                        x, cache, pos, params)
            new_cache["dense"] = nc
            if ntaf is not None:
                new_cache["taf"] = ntaf
        if n_moe:
            x, nc, _ = _decode_stack("moe_blocks", "moe", True,
                                     x, cache, pos, params)
            new_cache["moe"] = nc
        x = common.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, 0], _head_w(params).astype(cdt))
        return logits.astype(jnp.float32), new_cache

    return Model(cfg=cfg, init=init, hidden=lambda p, b: hidden(p, b)[0],
                 loss=loss, init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step)


# ============================================================================
# hybrid (zamba2)
# ============================================================================

def _build_hybrid(cfg: ModelConfig) -> Model:
    pdt = _dtype(cfg.param_dtype)
    cdt = _dtype(cfg.compute_dtype)
    n_groups, mpg, tail = blocks.hybrid_layout(cfg)

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "embed": common.embed_init(k1, (cfg.padded_vocab_size, cfg.d_model), pdt),
            "layers": blocks.init_hybrid(k2, cfg, pdt),
            "final_norm": common.norm_params(cfg.norm, cfg.d_model, pdt),
            "head": common.dense_init(k3, (cfg.d_model, cfg.padded_vocab_size),
                                      dtype=pdt),
        }

    def hidden(params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        positions = jnp.arange(x.shape[1])
        shared = params["layers"]["shared_attn"]

        def group_body(h, group_p):
            def mamba_body(hh, mp):
                return blocks.mamba_sublayer(mp, cfg, hh,
                                             approx_ffn=cfg.approx_ffn), None
            mb = jax.checkpoint(mamba_body) if cfg.remat else mamba_body
            h, _ = common.scan_layers(cfg.unroll_layers, mb, h, group_p)
            h, _ = blocks.block_forward(shared, cfg, h, positions,
                                        use_moe=False,
                                        approx_attn=cfg.approx_attention,
                                        approx_ffn=cfg.approx_ffn)
            return h, None

        x, _ = common.scan_layers(cfg.unroll_layers, group_body, x,
                                  params["layers"]["main"])
        if tail:
            def mamba_body(hh, mp):
                return blocks.mamba_sublayer(mp, cfg, hh,
                                             approx_ffn=cfg.approx_ffn), None
            x, _ = common.scan_layers(cfg.unroll_layers, mamba_body, x,
                                      params["layers"]["tail"])
        return common.apply_norm(cfg.norm, params["final_norm"], x,
                                 cfg.norm_eps)

    def loss(params, batch):
        x = hidden(params, batch)
        total, count = chunked_xent(x, params["head"], batch["labels"])
        out = total / jnp.maximum(count, 1.0)
        return out, {"xent": out}

    def init_cache(batch_size: int, max_len: int):
        def one_mamba(_):
            return mamba2.init_cache(cfg, batch_size, cdt)
        return {
            "mamba_main": jax.vmap(
                lambda i: jax.vmap(one_mamba)(jnp.arange(mpg)))(
                    jnp.arange(n_groups)),
            "mamba_tail": (jax.vmap(one_mamba)(jnp.arange(tail))
                           if tail else None),
            # one KV cache per shared-attn APPLICATION (weights shared,
            # caches distinct)
            "attn": jax.vmap(
                lambda _: blocks.init_block_cache(cfg, batch_size, max_len,
                                                  cdt))(jnp.arange(n_groups)),
        }

    def prefill(params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        cache = init_cache(x.shape[0], batch["max_len"])
        shared = params["layers"]["shared_attn"]

        def group_body(h, inp):
            group_p, attn_c = inp

            def mamba_body(hh, mp):
                return blocks.mamba_sublayer_prefill(mp, cfg, hh)
            h, mamba_states = common.scan_layers(cfg.unroll_layers,
                                                 mamba_body, h, group_p)
            h, new_attn_c = blocks.block_prefill(shared, cfg, h, attn_c,
                                                 use_moe=False)
            return h, (mamba_states, new_attn_c)

        x, (new_mamba, new_attn) = common.scan_layers(
            cfg.unroll_layers, group_body, x,
            (params["layers"]["main"], cache["attn"]))
        cache["attn"] = new_attn
        cache["mamba_main"] = jax.tree.map(
            lambda a, b: a.astype(b.dtype), new_mamba, cache["mamba_main"])
        if tail:
            def mamba_body(hh, mp):
                return blocks.mamba_sublayer_prefill(mp, cfg, hh)
            x, new_tail = common.scan_layers(cfg.unroll_layers, mamba_body,
                                             x, params["layers"]["tail"])
            cache["mamba_tail"] = jax.tree.map(
                lambda a, b: a.astype(b.dtype), new_tail, cache["mamba_tail"])
        x = common.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1], params["head"].astype(cdt))
        return logits.astype(jnp.float32), cache

    def decode_step(params, cache, tokens, pos):
        x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cdt)
        shared = params["layers"]["shared_attn"]

        def group_body(h, inp):
            group_p, mamba_c, attn_c = inp

            def mamba_body(hh, inp2):
                mp, mc = inp2
                hh, new_mc = blocks.mamba_sublayer_decode(mp, cfg, hh, mc)
                return hh, new_mc
            h, new_mamba_c = common.scan_layers(cfg.unroll_layers,
                                                mamba_body, h,
                                                (group_p, mamba_c))
            h, new_attn_c = blocks.block_decode(shared, cfg, h, attn_c, pos,
                                                use_moe=False,
                                                approx_attn=cfg.approx_attention)
            return h, (new_mamba_c, new_attn_c)

        x, (new_mamba, new_attn) = common.scan_layers(
            cfg.unroll_layers, group_body, x,
            (params["layers"]["main"], cache["mamba_main"], cache["attn"]))
        new_cache = dict(cache)
        new_cache["mamba_main"] = new_mamba
        new_cache["attn"] = new_attn
        if tail:
            def mamba_body(hh, inp2):
                mp, mc = inp2
                hh, new_mc = blocks.mamba_sublayer_decode(mp, cfg, hh, mc)
                return hh, new_mc
            x, new_tail = common.scan_layers(cfg.unroll_layers, mamba_body,
                                             x, (params["layers"]["tail"],
                                                 cache["mamba_tail"]))
            new_cache["mamba_tail"] = new_tail
        x = common.apply_norm(cfg.norm, params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, 0], params["head"].astype(cdt))
        return logits.astype(jnp.float32), new_cache

    return Model(cfg=cfg, init=init, hidden=hidden, loss=loss,
                 init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step)


# ============================================================================
# ssm (rwkv6)
# ============================================================================

def _build_rwkv(cfg: ModelConfig) -> Model:
    pdt = _dtype(cfg.param_dtype)
    cdt = _dtype(cfg.compute_dtype)

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "embed": common.embed_init(k1, (cfg.padded_vocab_size, cfg.d_model), pdt),
            "ln_in": common.norm_params("ln", cfg.d_model, pdt),
            "layers": _stack_init(
                lambda k: rwkv6.init_layer(k, cfg, pdt), k2, cfg.n_layers),
            "final_norm": common.norm_params("ln", cfg.d_model, pdt),
            "head": common.dense_init(k3, (cfg.d_model, cfg.padded_vocab_size),
                                      dtype=pdt),
        }

    def init_cache(batch_size: int, max_len: int = 0):
        return jax.vmap(lambda _: rwkv6.init_layer_cache(cfg, batch_size, cdt)
                        )(jnp.arange(cfg.n_layers))

    def _run(params, x, cache):
        def body(h, inp):
            layer_p, layer_c = inp
            h, new_c = rwkv6.layer_forward(layer_p, cfg, h, layer_c,
                                           approx=cfg.approx_ffn)
            return h, new_c

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, new_cache = common.scan_layers(cfg.unroll_layers, body_fn, x,
                                          (params["layers"], cache))
        return x, new_cache

    def hidden(params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        x = common.layernorm(params["ln_in"], x, cfg.norm_eps)
        cache = init_cache(x.shape[0])
        x, _ = _run(params, x, cache)
        return common.layernorm(params["final_norm"], x, cfg.norm_eps)

    def loss(params, batch):
        x = hidden(params, batch)
        total, count = chunked_xent(x, params["head"], batch["labels"])
        out = total / jnp.maximum(count, 1.0)
        return out, {"xent": out}

    def prefill(params, batch):
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        x = common.layernorm(params["ln_in"], x, cfg.norm_eps)
        cache = init_cache(x.shape[0])
        x, cache = _run(params, x, cache)
        x = common.layernorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1], params["head"].astype(cdt))
        return logits.astype(jnp.float32), cache

    def decode_step(params, cache, tokens, pos):
        del pos  # state-space: position is implicit in the state
        x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cdt)
        x = common.layernorm(params["ln_in"], x, cfg.norm_eps)
        x, new_cache = _run(params, x, cache)
        x = common.layernorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, 0], params["head"].astype(cdt))
        return logits.astype(jnp.float32), new_cache

    return Model(cfg=cfg, init=init, hidden=hidden, loss=loss,
                 init_cache=init_cache, prefill=prefill,
                 decode_step=decode_step)


# ============================================================================
# factory
# ============================================================================

def build(cfg: ModelConfig) -> Model:
    if cfg.family in ("dense", "vlm", "moe"):
        return _build_transformer(cfg)
    if cfg.family == "hybrid":
        return _build_hybrid(cfg)
    if cfg.family == "ssm":
        return _build_rwkv(cfg)
    if cfg.family == "audio":
        from . import whisper
        return whisper.build(cfg)
    raise ValueError(f"unknown family {cfg.family}")
