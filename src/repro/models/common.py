"""Shared model components: norms, RoPE, init, chunked attention math.

Pure-functional: params are nested dicts of jnp arrays; every module is a
pair of functions (init_params, apply). No flax -- pytrees all the way down,
which keeps pjit/shard_map sharding rules trivial to express.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init (maxtext-style default)."""
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------

def rmsnorm_params(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def layernorm_params(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


def norm_params(kind: str, d: int, dtype=jnp.float32):
    return rmsnorm_params(d, dtype) if kind == "rms" else layernorm_params(d, dtype)


def apply_norm(kind: str, p, x, eps: float = 1e-5):
    return rmsnorm(p, x, eps) if kind == "rms" else layernorm(p, x, eps)


# ----------------------------------------------------------------------------
# rotary position embeddings
# ----------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float = 10000.0) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10000.0) -> jnp.ndarray:
    """x: (..., S, D) with D even; positions: (S,) or broadcastable."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (S, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., ::2], x[..., 1::2]
    xr1 = x1 * cos - x2 * sin
    xr2 = x2 * cos + x1 * sin
    out = jnp.stack([xr1, xr2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq_len, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq_len, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def shard_hint(x: jnp.ndarray, *spec) -> jnp.ndarray:
    """with_sharding_constraint when an ambient mesh exists, else identity.

    Spec entries may name axes ('data', 'model', ('pod','data')); axes not
    present in the ambient mesh are dropped, and a dim whose size does not
    divide the axis size falls back to unconstrained. Lets model code carry
    production sharding hints while remaining runnable on a single device.
    """
    from jax._src import mesh as mesh_lib
    env = mesh_lib.thread_resources.env.physical_mesh
    if env.empty:
        return x
    names = set(env.axis_names)

    def axis_size(a):
        if isinstance(a, tuple):
            n = 1
            for el in a:
                n *= env.shape[el]
            return n
        return env.shape[a]

    out = []
    for dim, s in enumerate(spec):
        if s is None:
            out.append(None)
            continue
        if isinstance(s, tuple):
            s = tuple(a for a in s if a in names)
            s = s if s else None
        elif s not in names:
            s = None
        if s is not None and x.shape[dim] % axis_size(s) != 0:
            s = None
        out.append(s)
    from jax.sharding import PartitionSpec
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*out))


def data_axes_hint():
    """('pod','data') subset present in the ambient mesh (or 'data')."""
    return ("pod", "data")


def scan_layers(unroll: bool, body, carry, xs):
    """lax.scan over stacked layer params, or a python unroll when `unroll`.

    Unrolling exists for the roofline marginal-cost artifacts: XLA's cost
    analysis counts a while-loop body ONCE regardless of trip count, so
    per-layer costs must come from unrolled small-L lowerings.
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        stacked = None
    return carry, stacked


# ----------------------------------------------------------------------------
# attention math: memory-efficient chunked softmax attention (pure jnp)
# ----------------------------------------------------------------------------

def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, q_chunk: int = 512,
                      kv_chunk: int = 512,
                      scale: Optional[float] = None,
                      kv_positions: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Flash-style online-softmax attention in pure jnp (scan over chunks).

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D), Hq % Hkv == 0. Queries sit at
    the END of the KV timeline. Memory is O(q_chunk * kv_chunk) per (B, H)
    instead of O(Sq * Skv) -- this is the differentiable jnp twin of
    kernels/perforated_attention.py (use that on TPU), and what the 32k/500k
    shape cells lower.

    kv_positions: original timeline positions of each KV row (used by herded
    KV-block perforation, where the KV sequence is a gathered subset); the
    causal mask compares against these instead of contiguous indices.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]   # v head dim may differ from qk head dim (MLA)
    assert hq % hkv == 0
    rep = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    if kv_positions is None:
        kv_positions_arr = jnp.arange(skv)
        offset = skv - sq
    else:
        # kept-index set is STATIC (host numpy) -- herded perforation
        import numpy as _np
        kv_np = _np.asarray(kv_positions)
        kv_positions_arr = jnp.asarray(kv_np)
        offset = int(kv_np.max()) + 1 - sq

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    nq = -(-sq // q_chunk)
    nkv = -(-skv // kv_chunk)
    # pad to whole chunks
    sq_p, skv_p = nq * q_chunk, nkv * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skv_p - skv), (0, 0)))
    kvpos_p = jnp.pad(kv_positions_arr, (0, skv_p - skv),
                      constant_values=2 ** 30)  # padding: always masked
    if rep > 1:
        kp = jnp.repeat(kp, rep, axis=1)
        vp = jnp.repeat(vp, rep, axis=1)

    qs = qp.reshape(b, hq, nq, q_chunk, d).transpose(2, 0, 1, 3, 4)
    ks = kp.reshape(b, hq, nkv, kv_chunk, d).transpose(2, 0, 1, 3, 4)
    vs = vp.reshape(b, hq, nkv, kv_chunk, dv).transpose(2, 0, 1, 3, 4)

    def q_block(iq, qc):
        # online softmax over kv chunks
        m0 = jnp.full((b, hq, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hq, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, hq, q_chunk, dv), jnp.float32)

        def kv_block(carry, inp):
            m_prev, l_prev, acc = carry
            ikv, kc, vc = inp
            logits = jnp.einsum("bhqd,bhkd->bhqk", qc, kc,
                                preferred_element_type=jnp.float32) * scale
            qi = iq * q_chunk + jnp.arange(q_chunk) + offset
            ki = jax.lax.dynamic_slice(kvpos_p, (ikv * kv_chunk,),
                                       (kv_chunk,))
            mask = ki[None, :] < 2 ** 30  # mask KV padding
            if causal:
                mask = mask & (ki[None, :] <= qi[:, None])
            logits = jnp.where(mask[None, None], logits, -1e30)
            row_max = jnp.max(logits, axis=-1)
            m_new = jnp.maximum(m_prev, row_max)
            # single masked materialization: exp(-1e30 - m) underflows to 0,
            # so the second where is only needed for fully-masked rows,
            # which the final l>0.5 guard already handles
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bhkd->bhqd", p.astype(vc.dtype), vc,
                preferred_element_type=jnp.float32)
            return (m_new, l_new, acc), None

        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, a0),
            (jnp.arange(nkv), ks, vs))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.where((l > 0.5)[..., None], out, 0.0)
        return out.astype(q.dtype)

    outs = jax.lax.map(lambda args: q_block(*args),
                       (jnp.arange(nq), qs))              # (nq, B, H, qc, Dv)
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq_p, dv)
    return out[:, :, :sq]


def full_attention(q, k, v, *, causal=True, scale=None):
    """Quadratic reference attention (small sequences / tests)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    rep = hq // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        offset = skv - sq
        qi = jnp.arange(sq)[:, None] + offset
        ki = jnp.arange(skv)[None, :]
        logits = jnp.where((ki <= qi)[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      v.astype(jnp.float32)).astype(q.dtype)


def decode_attention(q, k, v, *, valid_len, scale=None, keep_mask=None):
    """Single-token decode attention against a (possibly oversized) cache.

    q: (B, Hq, 1, D); k/v: (B, Hkv, S_cache, D); positions >= valid_len are
    masked; `keep_mask` (S_cache,) additionally masks perforated KV blocks
    (herded: the same mask for every batch/head). Linear in cache length.

    Distribution-aware form (section Perf iteration A1/A2): GQA is a grouped
    einsum -- the KV cache is NEVER head-repeated -- and the logits are
    constrained to stay sharded along the cache sequence axis, so a
    sequence-sharded cache is consumed locally (flash-decoding style) and
    only the tiny (B, Hkv, G) softmax partials and the (B, Hkv, G, Dv)
    context cross chips, instead of an all-gather of the whole cache.
    """
    b, hq, _, d = q.shape
    _, hkv, skv, _ = k.shape
    dv = v.shape[-1]
    group = hq // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    da = data_axes_hint()
    qg = q.reshape(b, hkv, group, d)                         # (B,Hkv,G,D)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg, k,
                        preferred_element_type=jnp.float32) * scale
    logits = shard_hint(logits, da, None, None, "model")
    mask = jnp.arange(skv)[None, None, None, :] < valid_len
    if keep_mask is not None:
        mask = mask & keep_mask[None, None, None, :]
    logits = jnp.where(mask, logits, -1e30)
    # stable softmax over the (sharded) S axis: partial max/sum reductions
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = jnp.where(mask, p, 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    ctx = jnp.einsum("bhgs,bhsd->bhgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    ctx = ctx / jnp.maximum(l, 1e-30)
    return ctx.reshape(b, hq, 1, dv).astype(q.dtype)
