"""RWKV-6 "Finch" (attention-free) -- data-dependent decay time-mix +
channel-mix.

Faithful pieces: per-channel data-dependent decay w_t = exp(-exp(lora(x)))
(the Finch hallmark), token-shift mixing, per-head wkv state recurrence with
bonus `u` for the current token, squared-ReLU channel mix. Simplification
(recorded): the token-shift mix coefficients are learned-static (RWKV-5
style) rather than data-dependent LoRA-interpolated -- the recurrence
structure and state shapes (the systems-relevant parts) are unchanged.

Training uses a time-chunked scan (chunk the sequence, recur across chunks
with within-chunk unrolled matmul form); decode is the O(1) recurrent step.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import common


def _dims(cfg: ModelConfig):
    r = cfg.rwkv
    n_heads = cfg.d_model // r.head_dim
    return r, n_heads


def init_time_mix(key, cfg: ModelConfig, dtype) -> Dict:
    r, nh = _dims(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    return {
        "mix_r": jnp.full((d,), 0.5, dtype),
        "mix_k": jnp.full((d,), 0.5, dtype),
        "mix_v": jnp.full((d,), 0.5, dtype),
        "mix_w": jnp.full((d,), 0.5, dtype),
        "mix_g": jnp.full((d,), 0.5, dtype),
        "w_r": common.dense_init(ks[0], (d, d), dtype=dtype),
        "w_k": common.dense_init(ks[1], (d, d), dtype=dtype),
        "w_v": common.dense_init(ks[2], (d, d), dtype=dtype),
        "w_g": common.dense_init(ks[3], (d, d), dtype=dtype),
        "w_o": common.dense_init(ks[4], (d, d), dtype=dtype),
        # data-dependent decay LoRA (Finch): w = exp(-exp(w0 + tanh(xA)B))
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "decay_A": common.dense_init(ks[5], (d, r.decay_lora_rank),
                                     dtype=dtype),
        "decay_B": common.dense_init(ks[6], (r.decay_lora_rank, d),
                                     scale=0.01, dtype=dtype),
        "u": common.dense_init(ks[7], (nh, r.head_dim), scale=0.5,
                               dtype=jnp.float32),
        "ln_x": common.norm_params("ln", d, dtype),
    }


def init_channel_mix(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    ks = jax.random.split(key, 2)
    return {
        "mix_k": jnp.full((d,), 0.5, dtype),
        "w_k": common.dense_init(ks[0], (d, cfg.d_ff), dtype=dtype),
        "w_v": common.dense_init(ks[1], (cfg.d_ff, d), dtype=dtype),
    }


def _token_shift(x, x_prev):
    """shifted[t] = x[t-1]; x_prev seeds t=0. x: (B,S,d), x_prev: (B,d)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _wkv_scan(r, k, v, w, u, state):
    """Recurrent WKV. r,k,v: (B,S,H,P); w: (B,S,H,P) decay in (0,1);
    u: (H,P) bonus; state: (B,H,P,P). Scans over S.

    state S_t[h, i, j] accumulates k_i v_j; y_t = r_t . (S_{t-1} + u k v)."""

    def step(s, inp):
        rt, kt, vt, wt = inp                                 # (B,H,P) each
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)             # (B,H,P,P)
        y = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s_new = s * wt[:, :, :, None] + kv
        return s_new, y

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    final, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3), final                   # (B,S,H,P)


def time_mix(p: Dict, cfg: ModelConfig, x: jnp.ndarray, x_prev: jnp.ndarray,
             state: jnp.ndarray, approx=None):
    """x: (B,S,d); x_prev: (B,d) last token of the previous segment;
    state: (B,H,P,P). Returns (out, last_x, new_state)."""
    r_cfg, nh = _dims(cfg)
    b, s, d = x.shape
    hp = r_cfg.head_dim
    xs = _token_shift(x, x_prev)

    def mixed(name):
        m = p["mix_" + name].astype(x.dtype)
        return x * m + xs * (1 - m)

    r = jnp.einsum("bsd,dk->bsk", mixed("r"), p["w_r"].astype(x.dtype))
    k = jnp.einsum("bsd,dk->bsk", mixed("k"), p["w_k"].astype(x.dtype))
    v = jnp.einsum("bsd,dk->bsk", mixed("v"), p["w_v"].astype(x.dtype))
    g = jnp.einsum("bsd,dk->bsk", mixed("g"), p["w_g"].astype(x.dtype))
    # Finch data-dependent decay
    dlora = jnp.einsum("bsd,dr->bsr", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", mixed("w"), p["decay_A"].astype(x.dtype))),
        p["decay_B"].astype(x.dtype))
    w = jnp.exp(-jnp.exp(jnp.clip(
        p["w0"][None, None, :] + dlora.astype(jnp.float32), -20.0, 3.0)))

    rh = r.reshape(b, s, nh, hp).astype(jnp.float32)
    kh = k.reshape(b, s, nh, hp).astype(jnp.float32)
    vh = v.reshape(b, s, nh, hp).astype(jnp.float32)
    wh = w.reshape(b, s, nh, hp)
    y, new_state = _wkv_scan(rh, kh, vh, wh, p["u"].astype(jnp.float32),
                             state)
    y = y.reshape(b, s, d).astype(x.dtype)
    y = common.layernorm(p["ln_x"], y, cfg.norm_eps)
    out = jnp.einsum("bsd,dk->bsk", y * jax.nn.silu(g),
                     p["w_o"].astype(x.dtype))
    return out, x[:, -1, :], new_state


def channel_mix(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                x_prev: jnp.ndarray, approx=None):
    xs = _token_shift(x, x_prev)
    m = p["mix_k"].astype(x.dtype)
    xk = x * m + xs * (1 - m)
    h = jnp.einsum("bsd,df->bsf", xk, p["w_k"].astype(x.dtype))
    h = jnp.square(jax.nn.relu(h))
    out = jnp.einsum("bsf,fd->bsd", h, p["w_v"].astype(x.dtype))
    return out, x[:, -1, :]


def init_layer(key, cfg: ModelConfig, dtype) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": common.norm_params("ln", cfg.d_model, dtype),
        "ln2": common.norm_params("ln", cfg.d_model, dtype),
        "tm": init_time_mix(k1, cfg, dtype),
        "cm": init_channel_mix(k2, cfg, dtype),
    }


def init_layer_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    r, nh = _dims(cfg)
    return {
        "tm_x": jnp.zeros((batch, cfg.d_model), dtype),
        "cm_x": jnp.zeros((batch, cfg.d_model), dtype),
        "wkv": jnp.zeros((batch, nh, r.head_dim, r.head_dim), jnp.float32),
    }


def layer_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray, cache: Dict,
                  approx=None):
    """One RWKV block over a full sequence, threading segment state."""
    h = common.layernorm(p["ln1"], x, cfg.norm_eps)
    att, tm_x, wkv = time_mix(p["tm"], cfg, h, cache["tm_x"].astype(x.dtype),
                              cache["wkv"], approx)
    x = x + att
    h2 = common.layernorm(p["ln2"], x, cfg.norm_eps)
    ffn, cm_x = channel_mix(p["cm"], cfg, h2, cache["cm_x"].astype(x.dtype),
                            approx)
    x = x + ffn
    new_cache = {"tm_x": tm_x.astype(cache["tm_x"].dtype),
                 "cm_x": cm_x.astype(cache["cm_x"].dtype), "wkv": wkv}
    return x, new_cache


def layer_decode(p: Dict, cfg: ModelConfig, x: jnp.ndarray, cache: Dict,
                 approx=None):
    """Single-token step: identical math with S=1 (state makes it O(1))."""
    return layer_forward(p, cfg, x, cache, approx)
