"""Mamba2 (SSD) mixer -- the zamba2 backbone layer.

Chunked State-Space-Duality form (Dao & Gu 2024): within a chunk the
recurrence is computed as a masked attention-like quadratic (MXU-friendly);
across chunks a lax.scan carries the (H, P, N) state. Decode is the O(1)
recurrent step. Scalar-per-head decay A, depthwise causal conv on (x, B, C),
gated output -- the Mamba2 block structure with n_groups shared B/C.

This is a TPU-native layout: chunk_size x chunk_size intra-chunk matmuls map
to the MXU, the inter-chunk scan is length S/chunk (not S).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import common


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    n_heads = d_in // s.head_dim
    return s, d_in, n_heads


def init_params(key, cfg: ModelConfig, dtype) -> Dict:
    s, d_in, nh = _dims(cfg)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    ks = jax.random.split(key, 6)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "w_in": common.dense_init(
            ks[0], (cfg.d_model,
                    2 * d_in + 2 * s.n_groups * s.d_state + nh), dtype=dtype),
        "conv_w": common.dense_init(ks[1], (s.conv_width, conv_dim),
                                    scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.asarray(
            jnp.log(jnp.linspace(1.0, 16.0, nh)), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": common.rmsnorm_params(d_in, dtype),
        "w_out": common.dense_init(ks[2], (d_in, cfg.d_model), dtype=dtype),
    }


def _split_proj(cfg, proj):
    s, d_in, nh = _dims(cfg)
    gn = s.n_groups * s.d_state
    z = proj[..., :d_in]
    xbc = proj[..., d_in:d_in + d_in + 2 * gn]
    dt = proj[..., d_in + d_in + 2 * gn:]
    return z, xbc, dt


def _causal_conv(xbc, w, b, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along S. xbc: (B,S,C); w: (W,C).

    state (B, W-1, C) carries the last inputs for decode. Returns
    (out, new_state)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros(xbc.shape[:1] + (width - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)                 # (B, S+W-1, C)
    out = sum(xp[:, i:i + xbc.shape[1], :] * w[i].astype(xbc.dtype)
              for i in range(width))
    out = out + b.astype(xbc.dtype)
    new_state = xp[:, -(width - 1):, :]
    return jax.nn.silu(out), new_state


def _ssd_chunked(xh, dt, A, B, C, chunk: int):
    """SSD scan. xh: (b,S,H,P); dt: (b,S,H); A: (H,) (negative);
    B, C: (b,S,G,N). Returns (y (b,S,H,P), final_state (b,H,P,N)).
    """
    b, S, H, P = xh.shape
    G, N = B.shape[2], B.shape[3]
    assert S % chunk == 0
    nc = S // chunk
    rep = H // G

    xs = xh.reshape(b, nc, chunk, H, P)
    dts = dt.reshape(b, nc, chunk, H)
    Bs = B.reshape(b, nc, chunk, G, N)
    Cs = C.reshape(b, nc, chunk, G, N)

    dA = dts * A[None, None, None, :]                        # (b,nc,l,H) <= 0
    cum = jnp.cumsum(dA, axis=2)                             # within-chunk
    # intra-chunk (attention-like) term: M[i,j] = exp(cum_i - cum_j) i>=j
    li = jnp.arange(chunk)
    causal = li[:, None] >= li[None, :]
    # decay(i,j) = exp(cum[i] - cum[j]) for i >= j
    dec = jnp.exp(jnp.clip(cum[:, :, :, None, :] - cum[:, :, None, :, :],
                           -60.0, 0.0))                      # (b,nc,i,j,H)
    dec = jnp.where(causal[None, None, :, :, None], dec, 0.0)
    CB = jnp.einsum("bnigN,bnjgN->bnijg", Cs, Bs)            # (b,nc,i,j,G)
    CB = jnp.repeat(CB, rep, axis=4) if rep > 1 else CB      # -> H
    scores = CB * dec * dts[:, :, None, :, :]                # dt_j factor
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", scores, xs)

    # chunk state: sum_j exp(cum_last - cum_j) dt_j B_j x_j
    last = cum[:, :, -1:, :]                                 # (b,nc,1,H)
    decay_to_end = jnp.exp(jnp.clip(last - cum, -60.0, 0.0)) # (b,nc,l,H)
    Bh = jnp.repeat(Bs, rep, axis=3) if rep > 1 else Bs      # (b,nc,l,H,N)
    state_c = jnp.einsum("bnlh,bnlhN,bnlhp->bnhpN",
                         decay_to_end * dts, Bh, xs)         # per-chunk

    # inter-chunk scan
    chunk_decay = jnp.exp(jnp.clip(last[:, :, 0, :], -60.0, 0.0))  # (b,nc,H)

    def scan_fn(h_prev, inp):
        st, cd = inp                                         # (b,H,P,N),(b,H)
        h_new = h_prev * cd[:, :, None, None] + st
        # emit the state ENTERING the chunk (pre-decay): y_inter applies the
        # within-chunk inclusive decay exp(cum_i) itself
        return h_new, h_prev

    h0 = jnp.zeros((b, H, P, N), xh.dtype)
    h_final, h_ins = jax.lax.scan(
        scan_fn, h0,
        (state_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    h_ins = h_ins.transpose(1, 0, 2, 3, 4)                   # (b,nc,H,P,N)

    # inter-chunk contribution: y_j += C_j exp(cum_j) h_in
    Ch = jnp.repeat(Cs, rep, axis=3) if rep > 1 else Cs      # (b,nc,l,H,N)
    in_decay = jnp.exp(jnp.clip(cum, -60.0, 0.0))            # (b,nc,l,H)
    y_inter = jnp.einsum("bnlhN,bnhpN,bnlh->bnlhp", Ch, h_ins, in_decay)

    y = (y_intra + y_inter).reshape(b, S, H, P)
    return y, h_final


def forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
            approx=None, return_state: bool = False):
    """Full-sequence Mamba2 mixer. x: (B, S, d_model).

    With return_state=True also returns the decode cache ({conv, ssm}) after
    consuming the sequence -- the prefill -> decode state handoff."""
    s, d_in, nh = _dims(cfg)
    bsz, S, _ = x.shape
    proj = jnp.einsum("bsd,dk->bsk", x, p["w_in"].astype(x.dtype))
    z, xbc, dt = _split_proj(cfg, proj)
    xbc_raw = xbc  # pre-conv inputs: the conv decode state is their tail
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc[..., :d_in]
    gn = s.n_groups * s.d_state
    B = xbc[..., d_in:d_in + gn].reshape(bsz, S, s.n_groups, s.d_state)
    C = xbc[..., d_in + gn:].reshape(bsz, S, s.n_groups, s.d_state)
    dt_f = jax.nn.softplus(dt.astype(jnp.float32) +
                           p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))             # (H,) negative
    xh = xs.reshape(bsz, S, nh, s.head_dim)
    # pad S to a whole number of SSD chunks (dt=0 on padding => identity)
    chunk = min(s.chunk_size, S)
    pad = (-S) % chunk
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt_f = jnp.pad(dt_f, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    y, h_final = _ssd_chunked(xh.astype(jnp.float32), dt_f, A,
                              B.astype(jnp.float32), C.astype(jnp.float32),
                              chunk)
    if pad:
        y = y[:, :S]
        xh = xh[:, :S]
    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(bsz, S, d_in).astype(x.dtype)
    y = common.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"].astype(x.dtype))
    if not return_state:
        return out
    w = s.conv_width
    if S >= w - 1:
        conv_state = xbc_raw[:, S - (w - 1):S, :]
    else:
        conv_state = jnp.concatenate(
            [jnp.zeros((bsz, w - 1 - S) + xbc_raw.shape[2:], xbc_raw.dtype),
             xbc_raw], axis=1)
    return out, {"conv": conv_state, "ssm": h_final}


def init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    s, d_in, nh = _dims(cfg)
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return {
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
        "ssm": jnp.zeros((batch, nh, s.head_dim, s.d_state), jnp.float32),
    }


def decode_step(p: Dict, cfg: ModelConfig, x: jnp.ndarray, cache: Dict,
                approx=None) -> Tuple[jnp.ndarray, Dict]:
    """O(1) recurrent step. x: (B, 1, d_model)."""
    s, d_in, nh = _dims(cfg)
    bsz = x.shape[0]
    proj = jnp.einsum("bsd,dk->bsk", x, p["w_in"].astype(x.dtype))
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state=cache["conv"])
    xs = xbc[..., :d_in]
    gn = s.n_groups * s.d_state
    B = xbc[..., d_in:d_in + gn].reshape(bsz, s.n_groups, s.d_state)
    C = xbc[..., d_in + gn:].reshape(bsz, s.n_groups, s.d_state)
    rep = nh // s.n_groups
    Bh = jnp.repeat(B, rep, axis=1) if rep > 1 else B        # (b,H,N)
    Ch = jnp.repeat(C, rep, axis=1) if rep > 1 else C
    dt_f = jax.nn.softplus(dt[:, 0].astype(jnp.float32) +
                           p["dt_bias"].astype(jnp.float32))  # (b,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt_f * A[None, :])                        # (b,H)
    xh = xs[:, 0].reshape(bsz, nh, s.head_dim).astype(jnp.float32)
    h = cache["ssm"] * decay[:, :, None, None] + \
        jnp.einsum("bh,bhN,bhp->bhpN", dt_f, Bh.astype(jnp.float32), xh)
    y = jnp.einsum("bhN,bhpN->bhp", Ch.astype(jnp.float32), h)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(bsz, 1, d_in).astype(x.dtype)
    y = common.rmsnorm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"].astype(x.dtype))
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}
