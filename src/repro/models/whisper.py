"""Whisper-large-v3 backbone: encoder-decoder transformer.

Per the brief the conv frontend is a STUB: `input_specs()` provides
precomputed log-mel frame embeddings (B, S_enc, d_model); the encoder runs
bidirectional attention over them, the decoder runs causal self-attention +
cross-attention. Decode shapes exercise the decoder with a KV cache against
a precomputed encoder memory.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention, common, lm, mlp


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


def _init_enc_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": common.norm_params("ln", cfg.d_model, dtype),
        "attn": attention.init_params(k1, cfg, dtype),
        "ln2": common.norm_params("ln", cfg.d_model, dtype),
        "ffn": mlp.init_params(k2, cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _init_dec_block(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": common.norm_params("ln", cfg.d_model, dtype),
        "self_attn": attention.init_params(k1, cfg, dtype),
        "ln_x": common.norm_params("ln", cfg.d_model, dtype),
        "cross_attn": attention.init_params(k2, cfg, dtype),
        "ln2": common.norm_params("ln", cfg.d_model, dtype),
        "ffn": mlp.init_params(k3, cfg.d_model, cfg.d_ff, "gelu", dtype),
    }


def _enc_block(p, cfg, x, positions):
    h = common.layernorm(p["ln1"], x, cfg.norm_eps)
    x = x + attention.forward(p["attn"], cfg, h, positions, causal=False,
                              approx=cfg.approx_attention)
    h = common.layernorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp.forward(p["ffn"], cfg, h, "gelu", approx=cfg.approx_ffn)


def _cross_attention(p, cfg, x, memory, positions_q):
    """Queries from decoder x; K/V from encoder memory (no causal mask)."""
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", memory, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", memory, p["wv"].astype(x.dtype))
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, memory.shape[1], cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, memory.shape[1], cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    ctx = common.chunked_attention(q, k, v, causal=False)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return jnp.einsum("bsh,hd->bsd", ctx, p["wo"].astype(x.dtype))


def _dec_block(p, cfg, x, memory, positions):
    h = common.layernorm(p["ln1"], x, cfg.norm_eps)
    x = x + attention.forward(p["self_attn"], cfg, h, positions, causal=True,
                              approx=cfg.approx_attention)
    h = common.layernorm(p["ln_x"], x, cfg.norm_eps)
    x = x + _cross_attention(p["cross_attn"], cfg, h, memory, positions)
    h = common.layernorm(p["ln2"], x, cfg.norm_eps)
    return x + mlp.forward(p["ffn"], cfg, h, "gelu", approx=cfg.approx_ffn)


def build(cfg: ModelConfig) -> "lm.Model":
    pdt = _dtype(cfg.param_dtype)
    cdt = _dtype(cfg.compute_dtype)
    L = cfg.n_layers

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {
            "embed": common.embed_init(k1, (cfg.padded_vocab_size, cfg.d_model), pdt),
            "enc_blocks": lm._stack_init(
                lambda k: _init_enc_block(k, cfg, pdt), k2, L),
            "dec_blocks": lm._stack_init(
                lambda k: _init_dec_block(k, cfg, pdt), k3, L),
            "enc_norm": common.norm_params("ln", cfg.d_model, pdt),
            "dec_norm": common.norm_params("ln", cfg.d_model, pdt),
            "head": common.dense_init(k4, (cfg.d_model, cfg.padded_vocab_size),
                                      dtype=pdt),
        }

    def encode(params, frames):
        x = frames.astype(cdt) + common.sinusoidal_positions(
            frames.shape[1], cfg.d_model).astype(cdt)[None]
        positions = jnp.arange(x.shape[1])

        def body(h, layer_p):
            return _enc_block(layer_p, cfg, h, positions), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = common.scan_layers(cfg.unroll_layers, body_fn, x,
                                  params["enc_blocks"])
        return common.layernorm(params["enc_norm"], x, cfg.norm_eps)

    def hidden(params, batch):
        memory = encode(params, batch["frames"])
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        positions = jnp.arange(x.shape[1])

        def body(h, layer_p):
            return _dec_block(layer_p, cfg, h, memory, positions), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = common.scan_layers(cfg.unroll_layers, body_fn, x,
                                  params["dec_blocks"])
        return common.layernorm(params["dec_norm"], x, cfg.norm_eps)

    def loss(params, batch):
        x = hidden(params, batch)
        total, count = lm.chunked_xent(x, params["head"], batch["labels"])
        out = total / jnp.maximum(count, 1.0)
        return out, {"xent": out}

    def init_cache(batch_size: int, max_len: int):
        return {
            "self": jax.vmap(
                lambda _: attention.init_cache(cfg, batch_size, max_len, cdt)
            )(jnp.arange(L)),
            # encoder memory is computed at prefill and kept
            "memory": jnp.zeros((batch_size, cfg.max_source_positions,
                                 cfg.d_model), cdt),
        }

    def prefill(params, batch):
        memory = encode(params, batch["frames"])
        x = jnp.take(params["embed"], batch["tokens"], axis=0).astype(cdt)
        cache = init_cache(x.shape[0], batch["max_len"])
        cache["memory"] = jnp.zeros_like(cache["memory"]) \
            .at[:, :memory.shape[1]].set(memory)
        positions = jnp.arange(x.shape[1])

        def body(h, inp):
            layer_p, layer_c = inp
            hh = common.layernorm(layer_p["ln1"], h, cfg.norm_eps)
            out, new_c = attention.prefill(layer_p["self_attn"], cfg, hh,
                                           layer_c)
            h = h + out
            hh = common.layernorm(layer_p["ln_x"], h, cfg.norm_eps)
            h = h + _cross_attention(layer_p["cross_attn"], cfg, hh, memory,
                                     positions)
            hh = common.layernorm(layer_p["ln2"], h, cfg.norm_eps)
            h = h + mlp.forward(layer_p["ffn"], cfg, hh, "gelu")
            return h, new_c

        x, new_self = common.scan_layers(cfg.unroll_layers, body, x,
                                         (params["dec_blocks"],
                                          cache["self"]))
        cache["self"] = new_self
        x = common.layernorm(params["dec_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, -1], params["head"].astype(cdt))
        return logits.astype(jnp.float32), cache

    def decode_step(params, cache, tokens, pos):
        x = jnp.take(params["embed"], tokens[:, None], axis=0).astype(cdt)
        memory = cache["memory"].astype(cdt)
        positions = jnp.full((1,), pos, jnp.int32)

        def body(h, inp):
            layer_p, layer_c = inp
            hh = common.layernorm(layer_p["ln1"], h, cfg.norm_eps)
            out, new_c = attention.decode_step(
                layer_p["self_attn"], cfg, hh, layer_c, pos,
                approx=cfg.approx_decode)
            h = h + out
            hh = common.layernorm(layer_p["ln_x"], h, cfg.norm_eps)
            h = h + _cross_attention(layer_p["cross_attn"], cfg, hh, memory,
                                     positions)
            hh = common.layernorm(layer_p["ln2"], h, cfg.norm_eps)
            h = h + mlp.forward(layer_p["ffn"], cfg, hh, "gelu")
            return h, new_c

        x, new_self = common.scan_layers(cfg.unroll_layers, body, x,
                                         (params["dec_blocks"],
                                          cache["self"]))
        new_cache = dict(cache)
        new_cache["self"] = new_self
        x = common.layernorm(params["dec_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", x[:, 0], params["head"].astype(cdt))
        return logits.astype(jnp.float32), new_cache

    return lm.Model(cfg=cfg, init=init, hidden=hidden, loss=loss,
                    init_cache=init_cache, prefill=prefill,
                    decode_step=decode_step)
