"""GQA attention with RoPE, optional qk-norm (qwen3), optional QKV bias
(qwen1.5), KV cache, and the paper's herded KV-block perforation as a
first-class option (ApproxSpec on the config).

Three lowering paths share one module:
  * train/prefill: chunked flash-style jnp attention (differentiable,
    memory O(chunk^2)); on TPU the Pallas kernel from
    kernels/perforated_attention.py takes over via `use_pallas`.
  * decode: single-token attention against the cache (linear in S).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.types import ApproxSpec, Technique
from repro.core.perforation import kept_indices
from . import common


def init_params(key, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 6)
    p = {
        "wq": common.dense_init(ks[0], (d, cfg.n_heads * hd), dtype=dtype),
        "wk": common.dense_init(ks[1], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": common.dense_init(ks[2], (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": common.dense_init(ks[3], (cfg.n_heads * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = common.rmsnorm_params(hd, dtype)
        p["k_norm"] = common.rmsnorm_params(hd, dtype)
    return p


def _project_qkv(p, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray):
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    wq = common.shard_hint(p["wq"], None, "model")
    wk = common.shard_hint(p["wk"], None, "model")
    wv = common.shard_hint(p["wv"], None, "model")
    q = jnp.einsum("bsd,dh->bsh", x, wq.astype(x.dtype))
    k = jnp.einsum("bsd,dh->bsh", x, wk.astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, wv.astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = common.rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = common.rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = common.apply_rope(q, positions, cfg.rope_theta)
    k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _maybe_perforate_kv(k, v, spec: ApproxSpec, block: int = 128):
    """Herded KV-block perforation on the jnp path: the kept set is static,
    so the KV tensors are structurally shortened -- same semantics as the
    Pallas kernel's shortened grid (kernels/perforated_attention.py).
    Returns (k, v, kv_positions | None): original timeline positions of the
    kept rows so the causal mask stays exact."""
    if spec is None or spec.technique != Technique.PERFORATION:
        return k, v, None
    skv = k.shape[2]
    nblocks = max(skv // block, 1)
    kept = kept_indices(nblocks, spec.perforation)
    if len(kept) == nblocks:
        return k, v, None
    import numpy as np
    idx = np.concatenate([np.arange(b * block, (b + 1) * block)
                          for b in kept])
    idx = idx[idx < skv]
    jidx = jnp.asarray(idx)
    return jnp.take(k, jidx, axis=2), jnp.take(v, jidx, axis=2), idx


def forward(p, cfg: ModelConfig, x: jnp.ndarray, positions: jnp.ndarray,
            causal: bool = True,
            approx: Optional[ApproxSpec] = None) -> jnp.ndarray:
    """Self-attention over a full sequence (train / prefill)."""
    b, s, d = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    k, v, kv_pos = _maybe_perforate_kv(k, v, approx)
    ctx = common.chunked_attention(q, k, v, causal=causal,
                                   kv_positions=kv_pos)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, -1)
    wo = common.shard_hint(p["wo"], "model", None)
    return jnp.einsum("bsh,hd->bsd", ctx, wo.astype(x.dtype))


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    hd = cfg.resolved_head_dim
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros((batch, cfg.n_kv_heads, max_len, hd), jnp.int8),
            "v": jnp.zeros((batch, cfg.n_kv_heads, max_len, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, cfg.n_kv_heads, max_len, 1),
                                 jnp.bfloat16),
            "v_scale": jnp.zeros((batch, cfg.n_kv_heads, max_len, 1),
                                 jnp.bfloat16),
        }
    return {
        "k": jnp.zeros((batch, cfg.n_kv_heads, max_len, hd), dtype),
        "v": jnp.zeros((batch, cfg.n_kv_heads, max_len, hd), dtype),
    }


def _quantize_kv(x: jnp.ndarray):
    """Symmetric per-(b, h, s) int8 quantization of K/V rows."""
    m = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(m, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def prefill(p, cfg: ModelConfig, x: jnp.ndarray, cache: Dict,
            approx: Optional[ApproxSpec] = None) -> Tuple[jnp.ndarray, Dict]:
    """Full-sequence forward that also fills the cache[0:S]."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _project_qkv(p, cfg, x, positions)
    kk, vv, kv_pos = _maybe_perforate_kv(k, v, approx)
    ctx = common.chunked_attention(q, kk, vv, causal=True,
                                   kv_positions=kv_pos)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, -1)
    out = jnp.einsum("bsh,hd->bsd", ctx, p["wo"].astype(x.dtype))
    if cfg.kv_cache_dtype == "int8":
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        cache = {
            "k": jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, 0, 0)),
            "v": jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, 0, 0)),
            "k_scale": jax.lax.dynamic_update_slice(
                cache["k_scale"], ks, (0, 0, 0, 0)),
            "v_scale": jax.lax.dynamic_update_slice(
                cache["v_scale"], vs, (0, 0, 0, 0)),
        }
        return out, cache
    cache = {
        "k": jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0)),
        "v": jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0)),
    }
    return out, cache


def _decode_step_int8(p, cfg: ModelConfig, q, k, v, x, cache: Dict, pos,
                      approx: Optional[ApproxSpec]) -> Tuple[jnp.ndarray, Dict]:
    """int8-KV decode (section Perf cell A, beyond-paper): the cache stores int8
    rows + per-(b,h,s) scales; logits/context absorb the scales exactly:
      logits[.., s] = (q . k_int8[s]) * k_scale[s]
      ctx = sum_s (p[s] * v_scale[s]) * v_int8[s]
    """
    b = x.shape[0]
    kq, ks = _quantize_kv(k)
    vq, vs = _quantize_kv(v)
    ck = jax.lax.dynamic_update_slice(cache["k"], kq, (0, 0, pos, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], vq, (0, 0, pos, 0))
    cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks, (0, 0, pos, 0))
    cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs, (0, 0, pos, 0))
    hq = q.shape[1]
    hkv = ck.shape[1]
    group = hq // hkv
    d = q.shape[-1]
    skv = ck.shape[2]
    da = common.data_axes_hint()
    scale = 1.0 / (d ** 0.5)
    qg = q.reshape(b, hkv, group, d)
    logits = jnp.einsum("bhgd,bhsd->bhgs", qg, ck.astype(q.dtype),
                        preferred_element_type=jnp.float32)
    logits = logits * cks[:, :, None, :, 0].astype(jnp.float32) * scale
    logits = common.shard_hint(logits, da, None, None, "model")
    mask = jnp.arange(skv)[None, None, None, :] <= pos
    logits = jnp.where(mask, logits, -1e30)
    m = jnp.max(logits, axis=-1, keepdims=True)
    pr = jnp.exp(logits - m)
    pr = jnp.where(mask, pr, 0.0)
    l = jnp.sum(pr, axis=-1, keepdims=True)
    pv = (pr * cvs[:, :, None, :, 0].astype(jnp.float32)).astype(q.dtype)
    ctx = jnp.einsum("bhgs,bhsd->bhgd", pv, cv.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    ctx = ctx / jnp.maximum(l, 1e-30)
    ctx = ctx.reshape(b, hq, 1, d).astype(x.dtype)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    out = jnp.einsum("bsh,hd->bsd", ctx, p["wo"].astype(x.dtype))
    return out, {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}


def decode_step(p, cfg: ModelConfig, x: jnp.ndarray, cache: Dict,
                pos: jnp.ndarray,
                approx: Optional[ApproxSpec] = None) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode: x (B, 1, d); writes cache at `pos`, attends to
    [0, pos]. Linear in cache length."""
    b = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    if cfg.kv_cache_dtype == "int8":
        return _decode_step_int8(p, cfg, q, k, v, x, cache, pos, approx)
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, 0, pos, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, 0, pos, 0))
    keep_mask = None
    if approx is not None and approx.technique == Technique.PERFORATION:
        # herded KV perforation at decode: mask dropped blocks of the cache
        skv = ck.shape[2]
        block = 128
        nblocks = max(skv // block, 1)
        kept = kept_indices(nblocks, approx.perforation)
        import numpy as np
        keep_np = np.zeros((skv,), bool)
        for kb in kept:
            keep_np[kb * block:(kb + 1) * block] = True
        keep_np[skv - skv % block:] = True  # tail beyond whole blocks stays
        keep_mask = jnp.asarray(keep_np)
    ctx = common.decode_attention(q, ck, cv, valid_len=pos + 1,
                                  keep_mask=keep_mask)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b, 1, -1)
    out = jnp.einsum("bsh,hd->bsd", ctx, p["wo"].astype(x.dtype))
    return out, {"k": ck, "v": cv}
