"""Mixture-of-Experts layer: GShard-style top-k routing with capacity and
grouped dispatch einsums (SPMD-friendly: the expert dimension shards over
the `model` mesh axis => XLA inserts the all-to-all pattern), plus optional
shared (always-on) experts -- the DeepSeek-V3 / OLMoE shapes.

Beyond-paper AC composition: *expert perforation* -- herded dropping of every
M-th routed expert (the paper's loop-perforation insight applied to the
expert loop). Because the drop set is herded (static and shared), the
dropped experts' weights are never touched: structural savings.

The dispatch is grouped (`router_group_size` tokens per group) so the
one-hot dispatch tensor stays (G, S_g, E, C) with S_g small -- the VMEM/HBM
capacity argument of paper Figure 3 applied to routing state.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.core.perforation import kept_indices
from repro.core.types import ApproxSpec, Technique
from . import common, mlp


def init_params(key, cfg: ModelConfig, dtype) -> Dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": common.dense_init(ks[0], (d, m.n_experts), dtype=jnp.float32),
        # experts stacked on a leading E axis (shards over `model`)
        "w_gate": common.dense_init(ks[1], (m.n_experts, d, m.d_ff_expert),
                                    scale=1.0 / (d ** 0.5), dtype=dtype),
        "w_up": common.dense_init(ks[2], (m.n_experts, d, m.d_ff_expert),
                                  scale=1.0 / (d ** 0.5), dtype=dtype),
        "w_down": common.dense_init(ks[3], (m.n_experts, m.d_ff_expert, d),
                                    scale=1.0 / (m.d_ff_expert ** 0.5),
                                    dtype=dtype),
    }
    if m.n_shared_experts:
        p["shared"] = mlp.init_params(
            ks[4], d, m.d_ff_expert * m.n_shared_experts, "gated_silu", dtype)
    return p


def _capacity(m: MoEConfig, group: int) -> int:
    c = int(group * m.experts_per_token * m.capacity_factor / m.n_experts)
    return max(c, m.experts_per_token)


def forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
            approx: Optional[ApproxSpec] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss). Dropped-token policy: capacity
    overflow falls through to the shared expert / residual (standard GShard).
    """
    m = cfg.moe
    b, s, d = x.shape
    dt = x.dtype
    n_e = m.n_experts

    # --- expert perforation (beyond-paper AC; herded over the expert list)
    kept_experts = None
    if approx is not None and approx.technique == Technique.PERFORATION:
        kept = kept_indices(n_e, approx.perforation)
        if len(kept) < n_e:
            kept_experts = jnp.asarray(kept, jnp.int32)

    group = min(m.router_group_size, b * s)
    n_tokens = b * s
    assert n_tokens % group == 0, (n_tokens, group)
    g = n_tokens // group
    xg = x.reshape(g, group, d)

    router_w = common.shard_hint(p["router"].astype(jnp.float32),
                                 None, None)  # tiny: replicate at use
    if kept_experts is not None:
        router_w = jnp.take(router_w, kept_experts, axis=1)
        w_gate = jnp.take(p["w_gate"], kept_experts, axis=0)
        w_up = jnp.take(p["w_up"], kept_experts, axis=0)
        w_down = jnp.take(p["w_down"], kept_experts, axis=0)
        n_e = len(kept)
    else:
        w_gate, w_up, w_down = p["w_gate"], p["w_up"], p["w_down"]
    # ZeRO-3 use-site re-gather (section Perf cell B): expert weights compute in
    # EP-only layout; FSDP keeps storage sharded over the data axes
    w_gate = common.shard_hint(w_gate, "model", None, None)
    w_up = common.shard_hint(w_up, "model", None, None)
    w_down = common.shard_hint(w_down, "model", None, None)

    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32), router_w)
    # router stays token-local (section Perf cell B4): no E-sharded probs =>
    # no all-gather around top_k
    logits = common.shard_hint(logits, common.data_axes_hint(), None, None)
    probs = jax.nn.softmax(logits, axis=-1)                  # (g, t, E)
    k = min(m.experts_per_token, n_e)
    top_w, top_i = jax.lax.top_k(probs, k)                   # (g, t, k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    onehot_top = jax.nn.one_hot(top_i, n_e, dtype=jnp.float32)  # (g,t,k,E)
    ce = jnp.mean(jnp.sum(onehot_top, axis=2), axis=(0, 1))  # (E,)
    aux = n_e * jnp.sum(me * ce) * m.aux_loss_coef

    # --- capacity assignment: position of each (token, slot) in its expert
    cap = _capacity(m, group)
    flat_assign = onehot_top                                  # (g,t,k,E)
    # rank within expert: cumsum over (t, k) flattened
    a2 = flat_assign.reshape(g, group * k, n_e)
    ranks = jnp.cumsum(a2, axis=1) - a2                       # (g, t*k, E)
    pos = jnp.sum(ranks * a2, axis=-1).reshape(g, group, k)   # (g, t, k)
    keep = pos < cap
    w_kept = top_w * keep.astype(jnp.float32)

    # dispatch tensor (g, t, E, C)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap).astype(jnp.int32),
                            cap + 1, dtype=jnp.float32)[..., :cap]
    disp = jnp.einsum("gtke,gtkc->gtec", onehot_top,
                      pos_oh * keep[..., None].astype(jnp.float32))
    comb = jnp.einsum("gtke,gtkc,gtk->gtec", onehot_top, pos_oh, w_kept)

    # expert compute: (g, E, C, d) -> ffn -> back. Layout pins (section Perf
    # cell B2): token groups over the data axes, experts over model; the
    # g<->E reshard is the all-to-all, everything else stays local.
    da = common.data_axes_hint()
    xg = common.shard_hint(xg, da, None, None)
    disp = common.shard_hint(disp, da, None, "model", None)
    xe = jnp.einsum("gtec,gtd->gecd", disp.astype(dt), xg)
    xe = common.shard_hint(xe, da, "model", None, None)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w_gate.astype(dt))) * \
        jnp.einsum("gecd,edf->gecf", xe, w_up.astype(dt))
    h = common.shard_hint(h, da, "model", None, None)
    ye = jnp.einsum("gecf,efd->gecd", h, w_down.astype(dt))
    ye = common.shard_hint(ye, da, "model", None, None)
    out = jnp.einsum("gtec,gecd->gtd", comb.astype(dt), ye)
    out = common.shard_hint(out, da, None, None)

    out = out.reshape(b, s, d)
    if m.n_shared_experts:
        out = out + mlp.forward(p["shared"], cfg, x, "gated_silu")
    return out, aux.astype(jnp.float32)
