"""Dense FFN (gated-SiLU / GELU) with the paper's iACT / TAF / perforation
hooks exposed through an ApproxSpec.

Herded FFN perforation drops hidden-dim blocks *structurally* (strided
slicing of W1/W3 columns and W2 rows) -- the jnp twin of
kernels/perforated_matmul.py, saving real FLOPs on every backend.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.perforation import kept_indices
from repro.core.types import ApproxSpec, Technique
from . import common


def init_params(key, d_model: int, d_ff: int, kind: str, dtype) -> Dict:
    ks = jax.random.split(key, 3)
    if kind == "gated_silu":
        return {
            "w_gate": common.dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w_up": common.dense_init(ks[1], (d_model, d_ff), dtype=dtype),
            "w_down": common.dense_init(ks[2], (d_ff, d_model), dtype=dtype),
        }
    return {
        "w_up": common.dense_init(ks[0], (d_model, d_ff), dtype=dtype),
        "w_down": common.dense_init(ks[1], (d_ff, d_model), dtype=dtype),
    }


def _keep_idx(d_ff: int, spec: Optional[ApproxSpec], block: int = 128):
    if spec is None or spec.technique != Technique.PERFORATION:
        return None
    nb = max(d_ff // block, 1)
    kept = kept_indices(nb, spec.perforation)
    if len(kept) == nb:
        return None
    idx = jnp.concatenate([jnp.arange(b * block, min((b + 1) * block, d_ff))
                           for b in kept])
    return idx


def forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray, kind: str,
            approx: Optional[ApproxSpec] = None) -> jnp.ndarray:
    """x: (B, S, d). Perforation (herded) shrinks the hidden dim blocks."""
    idx = _keep_idx(p["w_down"].shape[0], approx)
    dt = x.dtype
    if kind == "gated_silu":
        wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
        if idx is not None:
            wg = jnp.take(wg, idx, axis=1)
            wu = jnp.take(wu, idx, axis=1)
            wd = jnp.take(wd, idx, axis=0)
        # ZeRO-3 use-site re-gather: storage may be sharded over the data
        # axes; compute wants TP-only layout (weight all-gather bytes <<
        # activation all-reduce bytes at long sequence -- section Perf cell B)
        wg = common.shard_hint(wg, None, "model")
        wu = common.shard_hint(wu, None, "model")
        wd = common.shard_hint(wd, "model", None)
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, wg.astype(dt))) * \
            jnp.einsum("bsd,df->bsf", x, wu.astype(dt))
        return jnp.einsum("bsf,fd->bsd", h, wd.astype(dt))
    wu, wd = p["w_up"], p["w_down"]
    if idx is not None:
        wu = jnp.take(wu, idx, axis=1)
        wd = jnp.take(wd, idx, axis=0)
    wu = common.shard_hint(wu, None, "model")
    wd = common.shard_hint(wd, "model", None)
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, wu.astype(dt)))
    return jnp.einsum("bsf,fd->bsd", h, wd.astype(dt))
