"""Decoder blocks: (pre-norm attention + FFN/MoE residual) and the zamba2
hybrid grouping. All block params are built to STACK on a leading layer axis
so the layer loop is a lax.scan (compile-time O(1) in depth).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.types import ApproxSpec
from . import attention, common, mamba2, mla, mlp, moe


# ----------------------------------------------------------------------------
# standard decoder block (dense / vlm / moe)
# ----------------------------------------------------------------------------

def init_block(key, cfg: ModelConfig, dtype, use_moe: bool) -> Dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "ln1": common.norm_params(cfg.norm, cfg.d_model, dtype),
        "ln2": common.norm_params(cfg.norm, cfg.d_model, dtype),
    }
    if cfg.use_mla:
        p["attn"] = mla.init_params(k1, cfg, dtype)
    else:
        p["attn"] = attention.init_params(k1, cfg, dtype)
    if use_moe:
        p["moe"] = moe.init_params(k2, cfg, dtype)
    else:
        dff = cfg.moe.d_ff_dense if (cfg.moe and cfg.moe.d_ff_dense) else cfg.d_ff
        p["ffn"] = mlp.init_params(k2, cfg.d_model, dff, cfg.mlp, dtype)
    return p


def _pin_residual(x, cfg: ModelConfig):
    """Canonical residual-stream layout (section Perf cell B2): batch over the
    data axes, d_model REPLICATED over model. Without this pin XLA may defer
    the row-parallel reduction and contract the next matmul over a sharded
    d_model, all-reducing (B,S,d_ff)-sized partials instead of (B,S,d).

    Only applied where XLA's default goes pathological (FSDP-sharded weights
    / MoE dispatch); for plain dense TP the unpinned schedule measured
    slightly better (section Perf C1) and the pin is skipped."""
    if not cfg.fsdp:
        return x
    return common.shard_hint(x, common.data_axes_hint(), None, None)


def block_forward(p: Dict, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray, use_moe: bool,
                  approx_attn: Optional[ApproxSpec] = None,
                  approx_ffn: Optional[ApproxSpec] = None,
                  causal: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, aux_loss)."""
    x = _pin_residual(x, cfg)
    h = common.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    attn_mod = mla if cfg.use_mla else attention
    x = _pin_residual(
        x + attn_mod.forward(p["attn"], cfg, h, positions, causal=causal,
                             approx=approx_attn), cfg)
    h = common.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    aux = jnp.float32(0.0)
    if use_moe:
        out, aux = moe.forward(p["moe"], cfg, h, approx=approx_ffn)
        x = _pin_residual(x + out, cfg)
    else:
        x = _pin_residual(
            x + mlp.forward(p["ffn"], cfg, h, cfg.mlp, approx=approx_ffn),
            cfg)
    return x, aux


def block_prefill(p: Dict, cfg: ModelConfig, x, cache, use_moe: bool,
                  approx_attn=None, approx_ffn=None):
    h = common.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    attn_mod = mla if cfg.use_mla else attention
    out, cache = attn_mod.prefill(p["attn"], cfg, h, cache, approx=approx_attn)
    x = x + out
    h = common.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    if use_moe:
        out, _ = moe.forward(p["moe"], cfg, h, approx=approx_ffn)
        x = x + out
    else:
        x = x + mlp.forward(p["ffn"], cfg, h, cfg.mlp, approx=approx_ffn)
    return x, cache


def block_decode(p: Dict, cfg: ModelConfig, x, cache, pos, use_moe: bool,
                 approx_attn=None, approx_ffn=None):
    h = common.apply_norm(cfg.norm, p["ln1"], x, cfg.norm_eps)
    attn_mod = mla if cfg.use_mla else attention
    out, cache = attn_mod.decode_step(p["attn"], cfg, h, cache, pos,
                                      approx=approx_attn)
    x = x + out
    h = common.apply_norm(cfg.norm, p["ln2"], x, cfg.norm_eps)
    if use_moe:
        out, _ = moe.forward(p["moe"], cfg, h, approx=approx_ffn)
        x = x + out
    else:
        x = x + mlp.forward(p["ffn"], cfg, h, cfg.mlp, approx=approx_ffn)
    return x, cache


def init_block_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    if cfg.use_mla:
        return mla.init_cache(cfg, batch, max_len, dtype)
    return attention.init_cache(cfg, batch, max_len, dtype)


# ----------------------------------------------------------------------------
# zamba2 hybrid: groups of (attn_period-1) mamba layers + 1 SHARED attn block
# ----------------------------------------------------------------------------

def hybrid_layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_groups, mamba_per_group, n_tail_mamba): n_layers =
    n_groups*(mamba_per_group+1) + tail; shared attn applied once per group."""
    period = cfg.hybrid.attn_period
    n_groups = cfg.n_layers // period
    mamba_per_group = period - 1
    tail = cfg.n_layers - n_groups * period
    return n_groups, mamba_per_group, tail


def init_hybrid(key, cfg: ModelConfig, dtype) -> Dict:
    n_groups, mpg, tail = hybrid_layout(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def one_mamba(k):
        # Zamba2 mamba blocks are MIXER-ONLY (no per-layer MLP); the d_ff
        # MLP lives in the single SHARED attention block.
        return {
            "ln": common.norm_params(cfg.norm, cfg.d_model, dtype),
            "mixer": mamba2.init_params(k, cfg, dtype),
        }

    main_keys = jax.random.split(k1, n_groups * mpg)
    main = jax.vmap(one_mamba)(main_keys)
    main = jax.tree.map(
        lambda a: a.reshape((n_groups, mpg) + a.shape[1:]), main)
    tail_p = (jax.vmap(one_mamba)(jax.random.split(k2, tail))
              if tail else None)
    shared = init_block(k3, cfg, dtype, use_moe=False)  # ONE shared attn block
    return {"main": main, "tail": tail_p, "shared_attn": shared}


def mamba_sublayer(p, cfg: ModelConfig, x, approx_ffn=None):
    del approx_ffn  # mamba blocks have no FFN (zamba2 layout)
    h = common.apply_norm(cfg.norm, p["ln"], x, cfg.norm_eps)
    return x + mamba2.forward(p["mixer"], cfg, h)


def mamba_sublayer_prefill(p, cfg: ModelConfig, x, approx_ffn=None):
    """Full-sequence sublayer that also emits the decode cache (state
    handoff for prefill -> decode)."""
    del approx_ffn
    h = common.apply_norm(cfg.norm, p["ln"], x, cfg.norm_eps)
    out, state = mamba2.forward(p["mixer"], cfg, h, return_state=True)
    return x + out, state


def mamba_sublayer_decode(p, cfg: ModelConfig, x, cache, approx_ffn=None):
    del approx_ffn
    h = common.apply_norm(cfg.norm, p["ln"], x, cfg.norm_eps)
    out, new_cache = mamba2.decode_step(p["mixer"], cfg, h, cache)
    return x + out, new_cache
