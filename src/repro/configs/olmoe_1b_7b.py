"""olmoe-1b-7b [moe]: 64 experts top-8.

16L d_model=2048 16H (GQA kv=16) d_ff=1024(expert) vocab=50304
[arXiv:2409.02060; hf].
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    moe=MoEConfig(n_experts=64, experts_per_token=8, d_ff_expert=1024,
                  n_shared_experts=0, n_dense_layers=0,
                  capacity_factor=1.25, router_group_size=512),
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-smoke", family="moe", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=256,
        moe=MoEConfig(n_experts=8, experts_per_token=2, d_ff_expert=64,
                      n_shared_experts=0, n_dense_layers=0,
                      router_group_size=64),
        remat=False)
