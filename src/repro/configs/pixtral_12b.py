"""pixtral-12b [vlm]: pixtral-ViT frontend (STUB) + mistral-nemo backbone.

40L d_model=5120 32H (GQA kv=8) d_ff=14336 vocab=131072
[hf:mistralai/Pixtral-12B-2409; unverified]. Per the brief the ViT frontend
is a stub: input_specs() provides precomputed patch embeddings that are
prefixed to the text embeddings; seq_len = n_patch_tokens + text tokens.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b",
    family="vlm",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=131072,
    head_dim=128,
    rope_theta=1e6,
    frontend="vision_patches",
    n_patch_tokens=256,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-smoke", family="vlm", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab_size=256, head_dim=32,
        frontend="vision_patches", n_patch_tokens=8, remat=False)
