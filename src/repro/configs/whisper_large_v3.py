"""whisper-large-v3 [audio]: encoder-decoder; conv frontend STUB.

32L d_model=1280 20H (GQA kv=20) d_ff=5120 vocab=51866 [arXiv:2212.04356;
unverified]. 32 encoder + 32 decoder layers. input_specs() provides
precomputed log-mel frame embeddings (the conv1d frontend is stubbed per the
brief); decode shapes exercise the DECODER with a self-attn KV cache +
precomputed encoder memory.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    norm="ln",
    mlp="gelu",
    is_encdec=True,
    max_source_positions=1500,
    frontend="audio_frames",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, norm="ln",
        mlp="gelu", is_encdec=True, max_source_positions=16,
        frontend="audio_frames", remat=False)
