from .base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from .registry import get_config, get_smoke_config, list_archs

__all__ = ["SHAPES", "ModelConfig", "ShapeConfig", "shape_applicable",
           "get_config", "get_smoke_config", "list_archs"]
