"""Architecture + shape + approximation + parallelism config schema.

One `ModelConfig` per assigned architecture (exact numbers from the brief),
a `ShapeConfig` per assigned input shape, and the paper's technique exposed
as first-class `approx_*` fields.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.core.types import ApproxSpec


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style Multi-head Latent Attention dims."""

    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0            # routed experts
    experts_per_token: int = 0    # top-k
    d_ff_expert: int = 0          # per-expert hidden
    n_shared_experts: int = 0     # always-on experts (dsv3: 1)
    n_dense_layers: int = 0       # leading dense layers (dsv3: 3)
    d_ff_dense: int = 0           # hidden dim of those dense layers
    capacity_factor: float = 1.25
    router_group_size: int = 512  # tokens per dispatch group (memory knob)
    aux_loss_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    expand: int = 2
    head_dim: int = 64
    conv_width: int = 4
    chunk_size: int = 256         # SSD chunk length
    n_groups: int = 1             # B/C groups (GVA)


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora_rank: int = 64     # rank of the data-dependent decay (Finch)
    chunk_size: int = 128         # time-chunk for the chunked WKV form


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """zamba2-style: Mamba2 backbone + a SHARED attention block applied
    every `attn_period` layers (same weights at every application)."""

    attn_period: int = 6


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # attention flavor
    rope_theta: float = 10000.0
    qk_norm: bool = False         # qwen3
    qkv_bias: bool = False        # qwen1.5
    norm: str = "rms"             # rms | ln
    mlp: str = "gated_silu"       # gated_silu | gelu
    use_mla: bool = False
    mla: Optional[MLAConfig] = None
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    hybrid: Optional[HybridConfig] = None
    # enc-dec (whisper): n_layers == encoder layers == decoder layers
    is_encdec: bool = False
    max_source_positions: int = 1500
    # modality frontend stub: inputs arrive as precomputed embeddings
    frontend: str = "none"        # none | audio_frames | vision_patches
    n_patch_tokens: int = 0       # vlm: patch embeddings per sample
    # training details
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mtp: bool = False             # dsv3 multi-token prediction
    mtp_loss_coef: float = 0.1
    param_dtype: str = "float32"  # float32 | bfloat16 (dsv3 uses bf16)
    compute_dtype: str = "bfloat16"
    remat: bool = True            # activation checkpointing over blocks
    # python-unroll the layer loop instead of lax.scan (roofline marginal-
    # cost artifacts need unrolled HLO: XLA cost analysis counts a while
    # body once regardless of trip count -- verified empirically)
    unroll_layers: bool = False
    # sub-quadratic attention available? (long_500k eligibility)
    subquadratic: bool = False
    # parallelism policy
    fsdp: bool = False            # shard params over data axis too (ZeRO-3)
    # serving: KV cache storage dtype ("bfloat16" | "int8"); int8 stores a
    # per-(batch, head, position) scale and halves decode's dominant HBM
    # traffic (beyond-paper optimization, section Perf cell A)
    kv_cache_dtype: str = "bfloat16"
    # the paper's technique, first-class (defaults: off == exact baseline)
    approx_attention: ApproxSpec = dataclasses.field(default_factory=ApproxSpec)
    approx_ffn: ApproxSpec = dataclasses.field(default_factory=ApproxSpec)
    approx_decode: ApproxSpec = dataclasses.field(default_factory=ApproxSpec)

    # embedding tables padded to a multiple of this (TP divisibility --
    # standard production practice; whisper's 51866 is not 16-divisible)
    vocab_pad_multiple: int = 256

    @property
    def padded_vocab_size(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        total = v * d                                  # embed
        if not self.tie_embeddings:
            total += v * d                             # head
        hd = self.resolved_head_dim

        def attn_params():
            if self.use_mla:
                m = self.mla
                q = d * m.q_lora_rank + m.q_lora_rank * self.n_heads * (
                    m.qk_nope_head_dim + m.qk_rope_head_dim)
                kv = d * (m.kv_lora_rank + m.qk_rope_head_dim) + \
                    m.kv_lora_rank * self.n_heads * (
                        m.qk_nope_head_dim + m.v_head_dim)
                o = self.n_heads * m.v_head_dim * d
                return q + kv + o
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o

        def dense_ffn(dff):
            mult = 3 if self.mlp == "gated_silu" else 2
            return mult * d * dff

        if self.family in ("dense", "vlm"):
            total += self.n_layers * (attn_params() + dense_ffn(self.d_ff))
        elif self.family == "moe":
            m = self.moe
            n_moe = self.n_layers - m.n_dense_layers
            total += self.n_layers * attn_params()
            total += m.n_dense_layers * dense_ffn(m.d_ff_dense or self.d_ff)
            total += n_moe * (m.n_experts + m.n_shared_experts) * \
                dense_ffn(m.d_ff_expert)
            total += n_moe * d * m.n_experts  # router
        elif self.family == "ssm":
            r = self.rwkv
            total += self.n_layers * (4 * d * d + d * self.d_ff * 2 +
                                      2 * d * r.decay_lora_rank)
        elif self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            # mixer only: zamba2 mamba blocks carry no per-layer MLP
            per_mamba = d * (2 * d_in + 2 * s.n_groups * s.d_state +
                             d_in // s.head_dim) + d_in * d
            n_attn = n_hybrid_attn_applications(self)
            n_mamba = self.n_layers - n_attn
            total += n_mamba * per_mamba
            total += attn_params() + dense_ffn(self.d_ff)  # ONE shared block
        elif self.family == "audio":
            # encoder + decoder stacks (n_layers each) + cross attention
            total += self.n_layers * (attn_params() + dense_ffn(self.d_ff))
            total += self.n_layers * (2 * attn_params() + dense_ffn(self.d_ff))
        if self.mtp:
            total += attn_params() + dense_ffn(self.d_ff) + 2 * d * d
        return total

    def active_param_count(self) -> int:
        """Active (per-token) parameters -- MoE uses top-k experts only."""
        if self.family != "moe":
            return self.param_count()
        m = self.moe
        total = self.param_count()
        n_moe = self.n_layers - m.n_dense_layers
        mult = 3 if self.mlp == "gated_silu" else 2
        all_experts = n_moe * m.n_experts * mult * self.d_model * m.d_ff_expert
        active_experts = n_moe * m.experts_per_token * mult * \
            self.d_model * m.d_ff_expert
        return total - all_experts + active_experts


def n_hybrid_attn_applications(cfg: ModelConfig) -> int:
    """zamba2: shared attention applied every attn_period-th layer slot."""
    return cfg.n_layers // cfg.hybrid.attn_period


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Brief rules: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch has no "
                       "sub-quadratic mode (DESIGN.md section 6)")
    return True, ""
