"""starcoder2-3b [dense]: GQA kv=2, RoPE, LayerNorm + GELU MLP.

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152 [arXiv:2402.19173; hf].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    n_kv_heads=2,
    d_ff=12288,
    vocab_size=49152,
    norm="ln",
    mlp="gelu",
    qkv_bias=True,
    rope_theta=1e5,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256, norm="ln",
        mlp="gelu", qkv_bias=True, remat=False)
