"""rwkv6-1.6b [ssm]: Finch -- attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892; unverified].
O(1) state per layer => sub-quadratic, eligible for long_500k.
"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,            # d_model / head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    norm="ln",
    rwkv=RWKVConfig(head_dim=64, decay_lora_rank=64, chunk_size=128),
    subquadratic=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-smoke", family="ssm", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=256, norm="ln",
        rwkv=RWKVConfig(head_dim=16, decay_lora_rank=8, chunk_size=8),
        subquadratic=True, remat=False)
