"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242; unverified]. The shared attention block (single weight
set) is applied every 6th layer slot; remaining slots are Mamba2+FFN.
Sub-quadratic: eligible for long_500k (decode attention is O(S) per step and
the Mamba2 state is O(1)).
"""
from repro.configs.base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, expand=2, head_dim=64, conv_width=4,
                  chunk_size=256, n_groups=1),
    hybrid=HybridConfig(attn_period=6),
    subquadratic=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
        ssm=SSMConfig(d_state=16, expand=2, head_dim=16, conv_width=4,
                      chunk_size=8, n_groups=1),
        hybrid=HybridConfig(attn_period=2), subquadratic=True, remat=False)
