"""deepseek-v3-671b [moe]: MLA + 1 shared + 256 routed top-8 + MTP.

61L d_model=7168 128H d_ff=2048(expert) vocab=129280 [arXiv:2412.19437; hf].
First 3 layers dense (d_ff 18432). MLA latent cache: kv_lora 512 + rope 64.
bf16 params + FSDP over the data axis (671B params do not fit TP-only).
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab_size=129280,
    use_mla=True,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, experts_per_token=8, d_ff_expert=2048,
                  n_shared_experts=1, n_dense_layers=3, d_ff_dense=18432,
                  capacity_factor=1.25, router_group_size=512),
    mtp=True,
    param_dtype="bfloat16",
    fsdp=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="dsv3-smoke", family="moe", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=64, vocab_size=256, use_mla=True,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=8, experts_per_token=2, d_ff_expert=64,
                      n_shared_experts=1, n_dense_layers=1, d_ff_dense=128,
                      router_group_size=64),
        mtp=True, remat=False)
