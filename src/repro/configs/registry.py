"""Architecture registry: --arch <id> resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

_MODULES: Dict[str, str] = {
    "zamba2-7b": "repro.configs.zamba2_7b",
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    return importlib.import_module(_MODULES[arch]).smoke_config()
