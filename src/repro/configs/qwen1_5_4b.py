"""qwen1.5-4b [dense]: QKV bias.

40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936
[hf:Qwen/Qwen1.5-0.5B; hf].
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1e6,
    norm_eps=1e-6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256, qkv_bias=True,
        remat=False)
