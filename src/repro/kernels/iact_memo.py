"""iACT input-memoized row function Pallas kernel (paper sections 3.1.4, 3.3).

The approximated region is an FFN tile y = gelu(x @ w1) @ w2 applied to rows
of x -- the archetypal "expensive device function" of paper Figure 5. Rows
are processed in blocks of `block_rows` by a sequential TPU grid; the memo
table is VMEM scratch (the paper's shared-memory table, sized by the block,
not by N -- the Figure 3 capacity argument: table bytes =
table_size*(d_in+d_out)*4, independent of N).

Faithful mechanics:
  * read phase: all rows probe the table (vectorized distance computation);
  * block-level majority-rules vote (ballot/popcount == masked sum);
  * approximate path: one-hot x table -> nearest cached outputs, the FFN
    matmuls are genuinely skipped via ``@pl.when``;
  * accurate path + write phase: a SINGLE writer -- the row with the largest
    distance from any table value -- inserts at the round-robin cursor.

The distance threshold is a **traced** scalar-prefetch operand: only the
structural parameters (block_rows, table_size, layer widths) shape the
compiled program, so a threshold sweep compiles once per structural group
and stacked thresholds ``jax.vmap`` straight through (docs/kernels.md).

Unlike the other hot kernels this one has NO ``pipeline=`` variant: its
grid is a single sequential axis and the memo table (keys/vals/meta
scratch) carries across *every* block -- there is no state-free axis to
mark "parallel", so DMA/compute overlap cannot be exposed through
``dimension_semantics`` here (docs/kernels.md "Block-shape autotuning &
DMA pipelining").
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_BIG = 3.4e38  # python float: jnp constants would be captured by the kernel


def _iact_kernel(thresh_ref, x_ref, w1_ref, w2_ref, o_ref, mask_ref,
                 keys_ref, vals_ref, meta_ref, *, table_size: int):
    b = pl.program_id(0)
    threshold = thresh_ref[0]

    @pl.when(b == 0)
    def _reset():
        meta_ref[0] = 0  # round-robin cursor
        meta_ref[1] = 0  # number of valid entries
        keys_ref[...] = jnp.zeros_like(keys_ref)
        vals_ref[...] = jnp.zeros_like(vals_ref)

    x = x_ref[...].astype(jnp.float32)                       # (R, d_in)
    keys = keys_ref[...]                                     # (T, d_in)
    n_valid = meta_ref[1]
    # read phase: squared euclidean distances (monotone in the paper's norm)
    diff = x[:, None, :] - keys[None, :, :]                  # (R, T, d_in)
    d2 = jnp.sum(diff * diff, axis=-1)                       # (R, T)
    slot_valid = jax.lax.broadcasted_iota(jnp.int32, d2.shape, 1) < n_valid
    d2 = jnp.where(slot_valid, d2, _BIG)
    min_d2 = jnp.min(d2, axis=1)                             # (R,)
    best = jnp.argmin(d2, axis=1)                            # (R,)
    hit = jnp.logical_and(min_d2 < threshold * threshold, n_valid > 0)
    n_rows = x.shape[0]
    approximate = jnp.sum(hit.astype(jnp.int32)) * 2 > n_rows  # majority

    @pl.when(approximate)
    def _approx_path():
        # nearest cached outputs via one-hot matmul (TPU-friendly gather)
        onehot = (best[:, None] ==
                  jax.lax.broadcasted_iota(jnp.int32, (n_rows, table_size), 1))
        out = jnp.dot(onehot.astype(jnp.float32), vals_ref[...],
                      preferred_element_type=jnp.float32)
        o_ref[...] = out.astype(o_ref.dtype)
        mask_ref[0] = 1

    @pl.when(jnp.logical_not(approximate))
    def _accurate_path():
        h = jnp.dot(x, w1_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        h = jax.nn.gelu(h)
        y = jnp.dot(h, w2_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)
        mask_ref[0] = 0
        # write phase -- single writer: farthest row from any cached value
        score = jnp.where(min_d2 >= _BIG, _BIG, min_d2)
        writer = jnp.argmax(score)
        wsel = (jax.lax.broadcasted_iota(jnp.int32, (n_rows, 1), 0) == writer)
        wx = jnp.sum(jnp.where(wsel, x, 0.0), axis=0)        # (d_in,)
        wy = jnp.sum(jnp.where(wsel, y, 0.0), axis=0)        # (d_out,)
        cursor = meta_ref[0]
        keys_ref[pl.dslice(cursor, 1), :] = wx[None, :]
        vals_ref[pl.dslice(cursor, 1), :] = wy[None, :]
        meta_ref[0] = jax.lax.rem(cursor + 1, table_size)
        meta_ref[1] = jnp.minimum(n_valid + 1, table_size)


@functools.partial(jax.jit, static_argnames=(
    "block_rows", "table_size", "out_dtype", "interpret"))
def iact_rowfn(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray, *,
               block_rows: int = 128, table_size: int = 4,
               threshold=0.5, out_dtype=jnp.float32,
               interpret: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (N, d_out), block_approx_mask (num_blocks,) bool).

    `threshold` may be a Python float or a traced scalar: it rides in scalar
    memory and never shapes the compiled program.
    """
    n, d_in = x.shape
    d_h = w1.shape[1]
    d_out = w2.shape[1]
    if w1.shape[0] != d_in or w2.shape[0] != d_h:
        raise ValueError(
            f"iact_rowfn layer width mismatch: x is (N={n}, d_in={d_in}) so "
            f"w1 must be (d_in, d_h) and w2 (d_h, d_out); got "
            f"w1.shape={tuple(w1.shape)}, w2.shape={tuple(w2.shape)}")
    if n % block_rows:
        raise ValueError(
            f"iact_rowfn block_rows={block_rows} does not divide the row "
            f"count N={n}: the sequential grid needs whole row blocks. "
            "kernels.tuning.search_space() enumerates only divisor-valid "
            "shapes for these operands.")
    num_b = n // block_rows

    thresh = jnp.asarray(threshold, jnp.float32).reshape((1,))
    kernel = functools.partial(_iact_kernel, table_size=table_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(num_b,),
        in_specs=[
            pl.BlockSpec((block_rows, d_in), lambda b, thresh_ref: (b, 0)),
            pl.BlockSpec((d_in, d_h), lambda b, thresh_ref: (0, 0)),
            pl.BlockSpec((d_h, d_out), lambda b, thresh_ref: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d_out), lambda b, thresh_ref: (b, 0)),
            pl.BlockSpec((1,), lambda b, thresh_ref: (b,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((table_size, d_in), jnp.float32),
            pltpu.VMEM((table_size, d_out), jnp.float32),
            pltpu.SMEM((2,), jnp.int32),
        ],
    )
    y, mask = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, d_out), out_dtype),
            jax.ShapeDtypeStruct((num_b,), jnp.int32),
        ],
        interpret=interpret,
    )(thresh, x, w1, w2)
    return y, mask.astype(bool)
