"""Flash attention with herded KV-block perforation (paper section 3.1.5 -> TPU).

Online-softmax flash attention over a (B, H, num_q, n_enum) grid whose KV
dimension enumerates perforated context blocks: the same KV blocks are
dropped for every query tile, batch and head -- herded perforation. `ini`
drops the oldest context, `fini` the newest; `small`/`large` give strided
context sparsity. With `perfo=None` this is a standard causal
flash-attention kernel (our full-attention baseline), and with `ini`
fractions it degenerates into a sliding-window: the sub-quadratic mode used
by long-context configs.

Two perforation modes share one kernel body:

  * **structural** (`fraction=None`): the kept-block list is computed on the
    host from the static `perfo` params and the grid enumerates ONLY the
    kept blocks -- dropped blocks are never visited (the herded payoff).
  * **masked** (`fraction=` a possibly-traced scalar; ini/fini/random
    kinds): the grid enumerates ALL KV blocks and a per-block liveness
    vector -- computed in-trace from the traced fraction -- gates each
    block's work under ``@pl.when``. The compiled program is shaped only by
    the block geometry, so a fraction sweep compiles once and stacked
    fractions ``jax.vmap`` straight through (docs/kernels.md). This is the
    kernel-level analogue of `perforated_loop(fraction=...)`'s masked
    variant: blocks still iterate, their compute is skipped.

Both the kept-block list and the liveness vector arrive via TPU scalar
prefetch so index maps and the causal mask read ``kept_ref[kk]``. GQA is
handled in the index map (kv head = q head // group); no KV repeat is
materialized. Scratch m/l/acc implement the numerically-safe online
softmax; a causal early-out ``@pl.when`` skips KV blocks entirely above the
diagonal (uniform across the tile -> genuinely free, the same argument as
herding).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.perforation import (FRACTION_KINDS, kept_indices,
                                    traced_execute_mask)
from repro.core.types import PerforationParams

_NEG = -1e30  # python float: jnp constants would be captured by the kernel


def _attn_kernel(kept_ref, live_ref, q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref,
                 *, block_q: int, block_kv: int, offset: int, scale: float,
                 causal: bool, n_enum: int):
    iq = pl.program_id(2)
    kk = pl.program_id(3)
    kid = kept_ref[kk]

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal early-out: KV block entirely above the diagonal for this q tile
    last_q_global = iq * block_q + offset + block_q - 1
    block_live = jnp.logical_or(
        jnp.asarray(not causal), kid * block_kv <= last_q_global)
    block_live = jnp.logical_and(block_live, live_ref[kk] > 0)

    @pl.when(block_live)
    def _process():
        q = q_ref[0, 0].astype(jnp.float32)                  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qi = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0) + \
                iq * block_q + offset
            ki = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1) + \
                kid * block_kv
            mask = ki <= qi
            logits = jnp.where(mask, logits, _NEG)
        else:
            mask = jnp.ones(logits.shape, dtype=bool)
        m_prev = m_ref[:, 0]                                 # (bq,)
        row_max = jnp.max(logits, axis=1)
        m_new = jnp.maximum(m_prev, row_max)
        p = jnp.where(mask, jnp.exp(logits - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[:, 0] = m_new
        l_ref[:, 0] = l_new

    @pl.when(kk == n_enum - 1)
    def _finalize():
        l = l_ref[:, 0]
        safe = jnp.maximum(l, 1e-30)
        out = acc_ref[...] / safe[:, None]
        out = jnp.where((l > 0.5)[:, None], out, 0.0)  # fully-masked rows -> 0
        o_ref[0, 0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_kv", "perfo", "causal", "scale", "interpret",
    "pipeline"))
def perforated_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         block_q: int = 128, block_kv: int = 128,
                         perfo: Optional[PerforationParams] = None,
                         fraction=None,
                         causal: bool = True,
                         scale: Optional[float] = None,
                         interpret: bool = False,
                         pipeline: bool = False) -> jnp.ndarray:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.

    Returns (B, Hq, Sq, D) in q.dtype. Queries sit at the END of the KV
    timeline (offset = Skv - Sq), covering self-attention, chunked prefill
    and single-token decode.

    `fraction` is the traced-parameter hook: a (possibly traced) scalar
    overriding ``perfo.fraction`` for the fraction-driven kinds
    (ini/fini/random). When set, the kernel runs in MASKED mode -- the grid
    enumerates every KV block and a liveness vector computed in-trace gates
    the dropped ones -- so the same compiled program serves any fraction.

    `pipeline=True` marks the batch/head/query-tile axes "parallel" (the
    online-softmax scratch m/l/acc only carries along the kk axis),
    letting Mosaic multi-buffer the next KV tile's DMA against the current
    tile's compute. Bit-identical outputs either way.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, dk = k.shape
    if dk != d or v.shape != k.shape or hq % hkv:
        raise ValueError(
            f"perforated_attention operand mismatch: q is "
            f"(B, Hq, Sq, D)={tuple(q.shape)} so k and v must share "
            f"(B, Hkv, Skv, D) with D={d} and Hq % Hkv == 0; got "
            f"k.shape={tuple(k.shape)}, v.shape={tuple(v.shape)}")
    if sq % block_q or skv % block_kv:
        raise ValueError(
            f"perforated_attention block shape (block_q={block_q}, "
            f"block_kv={block_kv}) does not divide the sequence geometry "
            f"(Sq={sq}, Skv={skv}): block_q must divide Sq and block_kv "
            "must divide Skv. kernels.tuning.search_space() enumerates "
            "only divisor-valid shapes for these operands.")
    group = hq // hkv
    nkv = skv // block_kv
    if fraction is not None:
        if perfo is None or perfo.kind not in FRACTION_KINDS:
            raise ValueError(
                "fraction is a traced hook for ini/fini/random perforation; "
                f"got perfo={perfo}")
        # Masked mode: enumerate every KV block; liveness is data.
        kept_arr = jnp.arange(nkv, dtype=jnp.int32)
        live_arr = traced_execute_mask(nkv, perfo, fraction).astype(jnp.int32)
        n_enum = nkv
    else:
        kept = np.arange(nkv) if perfo is None else kept_indices(nkv, perfo)
        if len(kept) == 0:
            raise ValueError("perforation dropped every KV block")
        kept_arr = jnp.asarray(kept, jnp.int32)
        live_arr = jnp.ones((len(kept),), jnp.int32)
        n_enum = len(kept)
    offset = skv - sq
    scale = scale if scale is not None else float(1.0 / np.sqrt(d))

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_kv=block_kv, offset=offset,
        scale=scale, causal=causal, n_enum=n_enum)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hq, sq // block_q, n_enum),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, h, iq, kk, kept_ref, live_ref:
                         (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, iq, kk, kept_ref, live_ref:
                         (bb, h // group, kept_ref[kk], 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bb, h, iq, kk, kept_ref, live_ref:
                         (bb, h // group, kept_ref[kk], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, h, iq, kk, kept_ref, live_ref:
                               (bb, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
    )
    extra = {}
    if pipeline:
        # b, h, iq tile independent outputs; only kk carries the
        # online-softmax scratch. Interpret mode ignores compiler_params.
        extra["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        interpret=interpret,
        **extra,
    )(kept_arr, live_arr, q, k, v)
