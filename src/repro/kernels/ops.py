"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (`interpret=True`
executes the kernel body in Python for validation); on TPU they compile to
Mosaic. `ON_TPU` flips automatically; `ref.py` provides the oracles used by
tests and by the pure-jnp model paths.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import PerforationParams
from . import ref
from .iact_memo import iact_rowfn as _iact_rowfn
from .perforated_attention import perforated_attention as _perf_attention
from .perforated_matmul import perforated_matmul as _perf_matmul
from .taf_matmul import taf_matmul as _taf_matmul

ON_TPU = jax.default_backend() == "tpu"


def _interp(override: Optional[bool]) -> bool:
    return (not ON_TPU) if override is None else override


def taf_matmul(x, w, *, block_m=128, block_n=128, history_size=3,
               prediction_size=8, rsd_threshold=0.5, out_dtype=jnp.float32,
               interpret: Optional[bool] = None):
    """`rsd_threshold` is a traced operand: sweeping it reuses one compile
    per (block shape, history_size, prediction_size) structural group."""
    return _taf_matmul(x, w, block_m=block_m, block_n=block_n,
                       history_size=history_size,
                       prediction_size=prediction_size,
                       rsd_threshold=rsd_threshold, out_dtype=out_dtype,
                       interpret=_interp(interpret))


def iact_rowfn(x, w1, w2, *, block_rows=128, table_size=4, threshold=0.5,
               out_dtype=jnp.float32, interpret: Optional[bool] = None):
    """`threshold` is a traced operand: sweeping it reuses one compile per
    (block_rows, table_size, widths) structural group."""
    return _iact_rowfn(x, w1, w2, block_rows=block_rows,
                       table_size=table_size, threshold=threshold,
                       out_dtype=out_dtype, interpret=_interp(interpret))


def perforated_matmul(x, w, *, block_m=128, block_n=128, block_k=128,
                      perfo: Optional[PerforationParams] = None,
                      fraction=None, rescale=False, out_dtype=jnp.float32,
                      interpret: Optional[bool] = None):
    """`fraction` is the traced hook for ini/fini/random perforation: when
    set, the kernel's masked mode gates K blocks from an in-trace liveness
    vector and one compiled program serves any fraction."""
    if fraction is not None and perfo is not None:
        # Masked mode ignores perfo.fraction (the traced operand carries
        # it), but perfo is a static jit arg: normalize the dead field so
        # the natural sweep pattern -- a fresh PerforationParams per grid
        # point -- still hits one compile.
        perfo = dataclasses.replace(perfo, fraction=0.0)
    return _perf_matmul(x, w, block_m=block_m, block_n=block_n,
                        block_k=block_k, perfo=perfo, fraction=fraction,
                        rescale=rescale, out_dtype=out_dtype,
                        interpret=_interp(interpret))


def perforated_attention(q, k, v, *, block_q=128, block_kv=128,
                         perfo: Optional[PerforationParams] = None,
                         fraction=None, causal=True,
                         scale: Optional[float] = None,
                         interpret: Optional[bool] = None):
    """`fraction` is the traced hook for ini/fini/random perforation: when
    set, the kernel's masked mode gates KV blocks from an in-trace liveness
    vector and one compiled program serves any fraction."""
    if fraction is not None and perfo is not None:
        # Masked mode ignores perfo.fraction (the traced operand carries
        # it), but perfo is a static jit arg: normalize the dead field so
        # the natural sweep pattern -- a fresh PerforationParams per grid
        # point -- still hits one compile.
        perfo = dataclasses.replace(perfo, fraction=0.0)
    return _perf_attention(q, k, v, block_q=block_q, block_kv=block_kv,
                           perfo=perfo, fraction=fraction, causal=causal,
                           scale=scale, interpret=_interp(interpret))


def flash_attention(q, k, v, *, block_q=128, block_kv=128, causal=True,
                    scale: Optional[float] = None,
                    interpret: Optional[bool] = None):
    """Standard causal flash attention == perforated_attention with no drops."""
    return _perf_attention(q, k, v, block_q=block_q, block_kv=block_kv,
                           perfo=None, causal=causal, scale=scale,
                           interpret=_interp(interpret))
