"""jit'd public wrappers for the Pallas kernels.

On this CPU container the kernels run in interpret mode (`interpret=True`
executes the kernel body in Python for validation); on TPU they compile to
Mosaic. `ON_TPU` flips automatically; `ref.py` provides the oracles used by
tests and by the pure-jnp model paths.

Block arguments default to **None**, which resolves through the tuning
cache (`kernels/tuning.py`): an exact (kernel, operand shapes, dtype,
machine, substrate) hit supplies the autotuned block shape, anything else
falls back to the historical hardcoded defaults (`tuning.FALLBACK_BLOCKS`,
128 everywhere). Callers that rely on block geometry for SEMANTICS (approx
masks are block-granular) keep passing explicit blocks -- a tuned geometry
is a different workload fingerprint, not a transparent speedup.

`pipeline` defaults to None -> True: the double-buffered kernel variants
(parallel `dimension_semantics` on the state-free grid axes, so Mosaic
overlaps the next tile's operand DMA with the current tile's compute) are
bit-identical to `pipeline=False` and are the default data path.
`iact_rowfn` has no pipelined variant: its grid is a single sequential
axis whose memo-table scratch carries across every block.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.types import PerforationParams
from . import ref
from .iact_memo import iact_rowfn as _iact_rowfn
from .perforated_attention import perforated_attention as _perf_attention
from .perforated_matmul import perforated_matmul as _perf_matmul
from .taf_matmul import taf_matmul as _taf_matmul

ON_TPU = jax.default_backend() == "tpu"


def _interp(override: Optional[bool]) -> bool:
    return (not ON_TPU) if override is None else override


def _pipe(override: Optional[bool]) -> bool:
    return True if override is None else override


def _resolve_blocks(kernel: str, arrays, dtype, **blocks):
    """Fill None block args from the tuning cache (exact-shape hit) or the
    hardcoded fallbacks. Explicit ints pass through untouched."""
    if all(v is not None for v in blocks.values()):
        return blocks
    from . import tuning
    shapes = tuning.operand_shapes(arrays)
    tuned = tuning.tuned_config(kernel, shapes, dtype=str(dtype)) or {}
    fallback = tuning.FALLBACK_BLOCKS[kernel]
    return {k: (v if v is not None else int(tuned.get(k, fallback[k])))
            for k, v in blocks.items()}


def taf_matmul(x, w, *, block_m: Optional[int] = None,
               block_n: Optional[int] = None, history_size=3,
               prediction_size=8, rsd_threshold=0.5, out_dtype=jnp.float32,
               interpret: Optional[bool] = None,
               pipeline: Optional[bool] = None):
    """`rsd_threshold` is a traced operand: sweeping it reuses one compile
    per (block shape, history_size, prediction_size) structural group."""
    b = _resolve_blocks("taf_matmul", (x, w), x.dtype,
                        block_m=block_m, block_n=block_n)
    return _taf_matmul(x, w, block_m=b["block_m"], block_n=b["block_n"],
                       history_size=history_size,
                       prediction_size=prediction_size,
                       rsd_threshold=rsd_threshold, out_dtype=out_dtype,
                       interpret=_interp(interpret),
                       pipeline=_pipe(pipeline))


def iact_rowfn(x, w1, w2, *, block_rows: Optional[int] = None, table_size=4,
               threshold=0.5, out_dtype=jnp.float32,
               interpret: Optional[bool] = None):
    """`threshold` is a traced operand: sweeping it reuses one compile per
    (block_rows, table_size, widths) structural group."""
    b = _resolve_blocks("iact_rowfn", (x, w1, w2), x.dtype,
                        block_rows=block_rows)
    return _iact_rowfn(x, w1, w2, block_rows=b["block_rows"],
                       table_size=table_size, threshold=threshold,
                       out_dtype=out_dtype, interpret=_interp(interpret))


def perforated_matmul(x, w, *, block_m: Optional[int] = None,
                      block_n: Optional[int] = None,
                      block_k: Optional[int] = None,
                      perfo: Optional[PerforationParams] = None,
                      fraction=None, rescale=False, out_dtype=jnp.float32,
                      interpret: Optional[bool] = None,
                      pipeline: Optional[bool] = None):
    """`fraction` is the traced hook for ini/fini/random perforation: when
    set, the kernel's masked mode gates K blocks from an in-trace liveness
    vector and one compiled program serves any fraction."""
    if fraction is not None and perfo is not None:
        # Masked mode ignores perfo.fraction (the traced operand carries
        # it), but perfo is a static jit arg: normalize the dead field so
        # the natural sweep pattern -- a fresh PerforationParams per grid
        # point -- still hits one compile.
        perfo = dataclasses.replace(perfo, fraction=0.0)
    b = _resolve_blocks("perforated_matmul", (x, w), x.dtype,
                        block_m=block_m, block_n=block_n, block_k=block_k)
    return _perf_matmul(x, w, block_m=b["block_m"], block_n=b["block_n"],
                        block_k=b["block_k"], perfo=perfo, fraction=fraction,
                        rescale=rescale, out_dtype=out_dtype,
                        interpret=_interp(interpret),
                        pipeline=_pipe(pipeline))


def perforated_attention(q, k, v, *, block_q: Optional[int] = None,
                         block_kv: Optional[int] = None,
                         perfo: Optional[PerforationParams] = None,
                         fraction=None, causal=True,
                         scale: Optional[float] = None,
                         interpret: Optional[bool] = None,
                         pipeline: Optional[bool] = None):
    """`fraction` is the traced hook for ini/fini/random perforation: when
    set, the kernel's masked mode gates KV blocks from an in-trace liveness
    vector and one compiled program serves any fraction."""
    if fraction is not None and perfo is not None:
        # Masked mode ignores perfo.fraction (the traced operand carries
        # it), but perfo is a static jit arg: normalize the dead field so
        # the natural sweep pattern -- a fresh PerforationParams per grid
        # point -- still hits one compile.
        perfo = dataclasses.replace(perfo, fraction=0.0)
    b = _resolve_blocks("perforated_attention", (q, k), q.dtype,
                        block_q=block_q, block_kv=block_kv)
    return _perf_attention(q, k, v, block_q=b["block_q"],
                           block_kv=b["block_kv"],
                           perfo=perfo, fraction=fraction, causal=causal,
                           scale=scale, interpret=_interp(interpret),
                           pipeline=_pipe(pipeline))


def flash_attention(q, k, v, *, block_q: Optional[int] = None,
                    block_kv: Optional[int] = None, causal=True,
                    scale: Optional[float] = None,
                    interpret: Optional[bool] = None,
                    pipeline: Optional[bool] = None):
    """Standard causal flash attention == perforated_attention with no drops."""
    b = _resolve_blocks("perforated_attention", (q, k), q.dtype,
                        block_q=block_q, block_kv=block_kv)
    return _perf_attention(q, k, v, block_q=b["block_q"],
                           block_kv=b["block_kv"], perfo=None, causal=causal,
                           scale=scale, interpret=_interp(interpret),
                           pipeline=_pipe(pipeline))
