"""Block-shape autotuning for the Pallas kernels.

HPAC-Offload's headline numbers are *measured wall-clock* speedups; the
surveys it builds on stress that AC techniques only pay off when their
decision overhead is hidden by the memory hierarchy -- exactly what tile
sizing and DMA/compute overlap control. This module turns the repo's
hardcoded block shapes into a measured decision:

  1. **search space** -- per-kernel, divisor-valid only: power-of-two
     candidates for `block_m/block_n` (taf_matmul), `block_m/block_n/
     block_k` (perforated_matmul), `block_rows` (iact_rowfn) and
     `block_q/block_kv` (perforated_attention) that divide the operand
     geometry, bounded by a VMEM working-set budget;
  2. **cost-model pre-prune** -- every candidate is traced through
     `analysis/cost.trace_cost` (pallas_call body FLOPs x grid product)
     and ranked on the `analysis/machine` roofline profile with the grid
     step count as the invocation term: per-step dispatch overhead is what
     small blocks pay, on real hardware and (amplified) in interpret mode.
     Only the best `max_measure` candidates graduate to measurement;
  3. **measured wall-clock** -- explicit warm-up calls, then median-of-k
     timings around `jax.block_until_ready`. Measurement runs the precise
     path (knobs that never approximate), so candidates are compared on
     block geometry alone, not on data-dependent skip luck. With
     `measure=False` the tuner falls back to pure cost-model ranking
     (useful when interpret-mode Python timing is too slow to be worth
     paying -- see docs/kernels.md);
  4. **persistent cache** -- winners land in a JSON `TuningCache` keyed by
     (kernel, operand shapes, dtype, machine, substrate). A cache hit
     skips all measurement. `$REPRO_TUNING_CACHE` points at a cache file;
     otherwise the committed `benchmarks/baselines/tuning_cache.json` (if
     present) seeds the defaults that `kernels/ops.py` resolves when a
     caller leaves its block arguments None.

Tuned blocks are *semantic* for the AC masks (a TAF mask is
(M/block_m, N/block_n); iACT votes per block_rows; perforation liveness is
per block_kv), so a tuned geometry is a different workload fingerprint --
apps that pin geometry for parity keep passing explicit blocks, and
`approx_ffn.make_app(blocks="tuned")` records the resolved blocks in its
workload dict. Lint rule A002 audits committed caches: an entry whose
block shape no longer divides its recorded operand geometry, or whose
machine key is stale vs `analysis.machine.SUBSTRATE_MACHINES`, is a
finding.
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

KERNELS = ("taf_matmul", "iact_rowfn", "perforated_matmul",
           "perforated_attention")

# Power-of-two block candidates; TPU-friendly (lane/sublane multiples) and
# small enough to enumerate exhaustively before the cost-model prune.
_POW2 = (8, 16, 32, 64, 128, 256, 512)

# VMEM working-set budget per grid step (operand blocks + scratch). Real
# v5e VMEM is ~128 MiB; stay well under so double-buffered operand blocks
# (2x the in-specs) still fit.
VMEM_BUDGET_BYTES = 48 * 2 ** 20

# Hardcoded fallbacks: the pre-tuning defaults of kernels/ops.py. Used when
# no cache entry matches the operand shapes.
FALLBACK_BLOCKS: Dict[str, Dict[str, int]] = {
    "taf_matmul": {"block_m": 128, "block_n": 128},
    "iact_rowfn": {"block_rows": 128},
    "perforated_matmul": {"block_m": 128, "block_n": 128, "block_k": 128},
    "perforated_attention": {"block_q": 128, "block_kv": 128},
}

# config key -> (operand index, axis index) the block must divide
_BLOCK_AXES: Dict[str, Dict[str, Tuple[int, int]]] = {
    "taf_matmul": {"block_m": (0, 0), "block_n": (1, 1)},
    "iact_rowfn": {"block_rows": (0, 0)},
    "perforated_matmul": {"block_m": (0, 0), "block_n": (1, 1),
                          "block_k": (0, 1)},
    "perforated_attention": {"block_q": (0, 2), "block_kv": (1, 2)},
}

# how many leading operand shapes identify the workload in a cache key:
# attention's v mirrors k, so (q, k) is the canonical pair -- this must
# agree with what `ops._resolve_blocks` passes on lookup
_KEY_OPERANDS = {"taf_matmul": 2, "iact_rowfn": 3,
                 "perforated_matmul": 2, "perforated_attention": 2}


def key_shapes(kernel: str,
               shapes: Sequence[Sequence[int]]) -> Tuple[Tuple[int, ...],
                                                         ...]:
    """The canonical cache-key shape tuple: the leading operands that
    identify the workload (normalized to int tuples)."""
    nops = _KEY_OPERANDS.get(kernel, len(shapes))
    return tuple(tuple(int(d) for d in s) for s in shapes[:nops])


# --------------------------------------------------------------------------
# search space + validation
# --------------------------------------------------------------------------

def _pow2_divisors(n: int) -> List[int]:
    out = [b for b in _POW2 if b <= n and n % b == 0]
    return out or [int(n)]  # no pow2 divisor: the full axis is the one tile


def validate_config(kernel: str, shapes: Sequence[Sequence[int]],
                    config: Dict[str, int]) -> Optional[str]:
    """None if `config` is divisor-valid for `shapes`, else the reason.

    Shared by the search-space generator (which must emit only valid
    shapes), the kernel wrappers' error paths, and the A002 tuning-cache
    audit (a committed entry whose blocks stopped dividing the recorded
    geometry is stale).
    """
    axes = _BLOCK_AXES.get(kernel)
    if axes is None:
        return f"unknown kernel {kernel!r} (expected one of {KERNELS})"
    for key, (op, ax) in axes.items():
        if key not in config:
            return f"config is missing {key!r}"
        block = config[key]
        if not isinstance(block, int) or block <= 0:
            return f"{key}={block!r} is not a positive int"
        if op >= len(shapes) or ax >= len(shapes[op]):
            return (f"shapes {list(map(tuple, shapes))} have no operand "
                    f"{op} axis {ax} for {key}")
        dim = int(shapes[op][ax])
        if dim % block:
            return (f"{key}={block} does not divide operand axis "
                    f"{dim} (operand {op}, axis {ax})")
    extra = set(config) - set(axes)
    if extra:
        return f"config has keys {sorted(extra)} unknown to {kernel}"
    return None


def search_space(kernel: str, shapes: Sequence[Sequence[int]]
                 ) -> List[Dict[str, int]]:
    """All divisor-valid block configs for `kernel` on `shapes`, within the
    VMEM working-set budget. Deterministic order (sorted by block values).
    """
    axes = _BLOCK_AXES.get(kernel)
    if axes is None:
        raise ValueError(f"unknown kernel {kernel!r} "
                         f"(expected one of {KERNELS})")
    keys = sorted(axes)
    choices = []
    for key in keys:
        op, ax = axes[key]
        choices.append(_pow2_divisors(int(shapes[op][ax])))
    configs: List[Dict[str, int]] = []

    def rec(i, cur):
        if i == len(keys):
            cfg = dict(cur)
            if vmem_bytes(kernel, shapes, cfg) <= VMEM_BUDGET_BYTES:
                configs.append(cfg)
            return
        for b in choices[i]:
            cur[keys[i]] = b
            rec(i + 1, cur)

    rec(0, {})
    for cfg in configs:  # the generator's own contract, cheap to enforce
        err = validate_config(kernel, shapes, cfg)
        if err:
            raise AssertionError(f"search_space emitted invalid {cfg}: {err}")
    return configs


def grid_steps(kernel: str, shapes: Sequence[Sequence[int]],
               config: Dict[str, int]) -> int:
    """Grid size at `config`: the per-step dispatch/loop count the roofline
    invocation term charges (interpret mode pays it as a Python loop)."""
    if kernel == "taf_matmul":
        (m, _), (_, n) = shapes[0], shapes[1]
        return (m // config["block_m"]) * (n // config["block_n"])
    if kernel == "iact_rowfn":
        return shapes[0][0] // config["block_rows"]
    if kernel == "perforated_matmul":
        (m, k), (_, n) = shapes[0], shapes[1]
        return ((m // config["block_m"]) * (n // config["block_n"])
                * (k // config["block_k"]))
    if kernel == "perforated_attention":
        b, hq, sq, _ = shapes[0]
        skv = shapes[1][2]
        return (b * hq * (sq // config["block_q"])
                * (skv // config["block_kv"]))
    raise ValueError(f"unknown kernel {kernel!r}")


def vmem_bytes(kernel: str, shapes: Sequence[Sequence[int]],
               config: Dict[str, int]) -> int:
    """f32 working set of one grid step: operand/output blocks + scratch."""
    f = 4
    if kernel == "taf_matmul":
        k = shapes[0][1]
        bm, bn = config["block_m"], config["block_n"]
        return f * (bm * k + k * bn + 2 * bm * bn + 8)
    if kernel == "iact_rowfn":
        d_in, d_h = shapes[1]
        d_out = shapes[2][1]
        br = config["block_rows"]
        table = 4 * (d_in + d_out)  # default table_size
        return f * (br * d_in + d_in * d_h + d_h * d_out + br * d_out + table)
    if kernel == "perforated_matmul":
        bm, bn, bk = config["block_m"], config["block_n"], config["block_k"]
        return f * (bm * bk + bk * bn + 2 * bm * bn)
    if kernel == "perforated_attention":
        d = shapes[0][3]
        bq, bkv = config["block_q"], config["block_kv"]
        return f * (bq * d + 2 * bkv * d + 2 * bq * d + 2 * bq)
    raise ValueError(f"unknown kernel {kernel!r}")


# --------------------------------------------------------------------------
# cost-model pre-prune
# --------------------------------------------------------------------------

def build_call(kernel: str, config: Dict[str, int],
               pipeline: bool = True) -> Callable:
    """The precise-path callable tuned/measured at `config`: knobs are set
    so no block ever approximates (TAF/iACT thresholds 0, no perforation),
    making candidates comparable on block geometry alone."""
    from . import ops
    if kernel == "taf_matmul":
        return lambda x, w: ops.taf_matmul(
            x, w, rsd_threshold=0.0, pipeline=pipeline, **config)[0]
    if kernel == "iact_rowfn":
        return lambda x, w1, w2: ops.iact_rowfn(
            x, w1, w2, threshold=0.0, **config)[0]
    if kernel == "perforated_matmul":
        return lambda x, w: ops.perforated_matmul(
            x, w, perfo=None, pipeline=pipeline, **config)
    if kernel == "perforated_attention":
        return lambda q, k, v: ops.flash_attention(
            q, k, v, pipeline=pipeline, **config)
    raise ValueError(f"unknown kernel {kernel!r}")


def predict_time_s(kernel: str, arrays: Sequence, config: Dict[str, int],
                   machine=None, pipeline: bool = True) -> float:
    """Roofline-predicted seconds at `config`: traced FLOPs/bytes through
    `analysis.cost.trace_cost`, with the grid step count as the invocation
    term so per-step dispatch overhead penalizes small blocks."""
    from repro.analysis.cost import trace_cost
    from repro.analysis.machine import get_machine
    mp = get_machine(machine if machine is not None
                     else current_machine_name())
    shapes = operand_shapes(arrays)
    cv = trace_cost(build_call(kernel, config, pipeline=pipeline), *arrays)
    steps = grid_steps(kernel, shapes, config)
    return mp.time_s(cv.flops, cv.bytes, invocations=float(steps))


# --------------------------------------------------------------------------
# measurement
# --------------------------------------------------------------------------

def measure_s(fn: Callable, *args, warmup: int = 2, repeats: int = 5
              ) -> float:
    """Median-of-k wall-clock seconds: explicit warm-up calls absorb
    compile + first-dispatch, then each repeat blocks on the result.
    Thin wrapper over the shared `obs.timing.measure` (same semantics;
    this name is the tuner's historical entry point)."""
    from repro.obs.timing import measure
    return measure(fn, *args, warmup=max(1, warmup),
                   repeats=max(1, repeats), stat="median",
                   span="tuning.measure").seconds


# --------------------------------------------------------------------------
# the persistent cache
# --------------------------------------------------------------------------

def current_substrate() -> str:
    """"mosaic" when the kernels compile for TPU, "interpret" on hosts."""
    from . import ops
    return "mosaic" if ops.ON_TPU else "interpret"


def current_machine_name() -> str:
    """The registered roofline profile of the running substrate (tuning
    caches key on registered names so committed caches lint cleanly --
    the session-local "measured" profile sharpens predictions but is not a
    stable cache key across machines)."""
    from . import ops
    return "tpu-v5e" if ops.ON_TPU else "host-sim"


def operand_shapes(arrays: Sequence) -> Tuple[Tuple[int, ...], ...]:
    return tuple(tuple(int(d) for d in a.shape) for a in arrays)


def cache_key(kernel: str, shapes: Sequence[Sequence[int]], dtype: str,
              machine: str, substrate: str) -> str:
    s = "x".join(".".join(str(int(d)) for d in shp) for shp in shapes)
    return f"{kernel}|{s}|{dtype}|{machine}|{substrate}"


def validate_entry(key: str, entry: Dict) -> Optional[str]:
    """None if a cache entry is internally consistent, else the reason.
    Checks: known kernel, divisor-valid config for the recorded shapes,
    and that the entry's key fields re-derive its cache key (a hand-edited
    or stale entry fails here)."""
    kernel = entry.get("kernel")
    if kernel not in KERNELS:
        return f"unknown kernel {kernel!r}"
    shapes = entry.get("shapes")
    config = entry.get("config")
    if not shapes or not isinstance(config, dict):
        return "entry is missing shapes/config"
    err = validate_config(kernel, shapes, config)
    if err:
        return err
    rekey = cache_key(kernel, shapes, entry.get("dtype", ""),
                      entry.get("machine", ""), entry.get("substrate", ""))
    if rekey != key:
        return (f"entry fields re-derive key {rekey!r} but it is stored "
                f"under {key!r} (stale or hand-edited)")
    return None


class TuningCache:
    """A {cache_key: entry} JSON store. Entries record everything needed to
    re-validate them (kernel, shapes, dtype, machine, substrate, config)
    plus the winning measurement."""

    def __init__(self, path: Optional[str] = None,
                 entries: Optional[Dict[str, Dict]] = None):
        self.path = path
        self.entries: Dict[str, Dict] = dict(entries or {})

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        with open(path) as f:
            doc = json.load(f)
        return cls(path=path, entries=doc.get("entries", {}))

    def save(self, path: Optional[str] = None) -> str:
        path = path or self.path
        if not path:
            raise ValueError("TuningCache has no path to save to")
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            json.dump({"version": 1,
                       "entries": {k: self.entries[k]
                                   for k in sorted(self.entries)}},
                      f, indent=1, sort_keys=True)
        self.path = path
        return path

    def get(self, key: str) -> Optional[Dict]:
        return self.entries.get(key)

    def put(self, key: str, entry: Dict) -> None:
        self.entries[key] = entry

    def __len__(self) -> int:
        return len(self.entries)


def default_cache_path() -> Optional[str]:
    """$REPRO_TUNING_CACHE, else the committed baseline cache (if any)."""
    env = os.environ.get("REPRO_TUNING_CACHE")
    if env:
        return env
    # this file lives at <root>/src/repro/kernels/tuning.py
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    p = os.path.join(root, "benchmarks", "baselines", "tuning_cache.json")
    return p if os.path.exists(p) else None


_DEFAULT_CACHE: Optional[TuningCache] = None


def default_cache(reload: bool = False) -> TuningCache:
    """The process-ambient cache `kernels/ops.py` consults for None block
    defaults. Loaded lazily from `default_cache_path()`; empty when none."""
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None or reload:
        p = default_cache_path()
        _DEFAULT_CACHE = (TuningCache.load(p) if p and os.path.exists(p)
                          else TuningCache())
    return _DEFAULT_CACHE


def set_default_cache(cache: Optional[TuningCache]) -> None:
    """Install (or, with None, drop back to lazy-loading) the ambient
    cache. Tests use this to pin tuned defaults without touching disk."""
    global _DEFAULT_CACHE
    _DEFAULT_CACHE = cache


def tuned_config(kernel: str, shapes: Sequence[Sequence[int]],
                 dtype: str = "float32", machine: Optional[str] = None,
                 substrate: Optional[str] = None,
                 cache: Optional[TuningCache] = None
                 ) -> Optional[Dict[str, int]]:
    """Pure cache lookup (never measures): the tuned block config for this
    exact (kernel, shapes, dtype, machine, substrate), or None on miss."""
    cache = cache if cache is not None else default_cache()
    if not cache.entries:
        return None
    key = cache_key(kernel, key_shapes(kernel, shapes), str(dtype),
                    machine or current_machine_name(),
                    substrate or current_substrate())
    entry = cache.get(key)
    return dict(entry["config"]) if entry else None


# --------------------------------------------------------------------------
# the autotuner
# --------------------------------------------------------------------------

def autotune(kernel: str, *arrays, cache: Optional[TuningCache] = None,
             machine=None, substrate: Optional[str] = None,
             max_measure: int = 6, warmup: int = 2, repeats: int = 5,
             pipeline: bool = True, measure: bool = True,
             measure_fn: Optional[Callable] = None,
             log: Optional[Callable[[str], None]] = None) -> Dict[str, int]:
    """Tune `kernel`'s block shapes for these operands; returns the config.

    Flow: cache hit -> return immediately (no tracing, no measurement).
    Miss -> enumerate the divisor-valid search space, rank every candidate
    on the roofline cost model, measure the top `max_measure` wall-clock
    (or, with `measure=False`, crown the cost-model winner outright), and
    persist the result. `measure_fn(fn, args) -> seconds` overrides the
    timer (tests inject deterministic ones).
    """
    from repro.analysis.machine import get_machine
    mp = get_machine(machine if machine is not None
                     else current_machine_name())
    sub = substrate or current_substrate()
    shapes = key_shapes(kernel, operand_shapes(arrays))
    dtype = str(arrays[0].dtype)
    from repro import obs
    cache = cache if cache is not None else default_cache()
    key = cache_key(kernel, shapes, dtype, mp.name, sub)
    hit = cache.get(key)
    if hit is not None:
        obs.count("tuning.cache_hits")
        return dict(hit["config"])
    obs.count("tuning.cache_misses")

    space = search_space(kernel, shapes)
    if not space:
        raise ValueError(f"empty search space for {kernel} on "
                         f"{list(map(tuple, shapes))}")
    ranked = sorted(
        ((predict_time_s(kernel, arrays, cfg, machine=mp,
                         pipeline=pipeline), i, cfg)
         for i, cfg in enumerate(space)),
        key=lambda t: (t[0], t[1]))
    candidates = [cfg for _, _, cfg in ranked[:max(1, max_measure)]]
    predicted_us = {json.dumps(cfg, sort_keys=True): t * 1e6
                    for t, _, cfg in ranked}

    if measure:
        timer = measure_fn or (
            lambda fn, args: measure_s(fn, *args, warmup=warmup,
                                       repeats=repeats))
        from repro.obs import trace
        timed = []
        for cfg in candidates:
            with trace.span("tuning.measure_config", kernel=kernel,
                            config=dict(cfg)):
                s = float(timer(build_call(kernel, cfg, pipeline=pipeline),
                                arrays))
            timed.append((s, cfg))
            if log:
                log(f"{kernel} {cfg}: {s * 1e6:.1f}us")
        best_s, best = min(timed, key=lambda t: t[0])
        measured = len(timed)
    else:  # cost-model ranking fallback: no wall-clock at all
        best_s, best = ranked[0][0], candidates[0]
        measured = 0

    entry = {
        "kernel": kernel,
        "shapes": [list(s) for s in shapes],
        "dtype": dtype,
        "machine": mp.name,
        "substrate": sub,
        "config": dict(best),
        "us": round(best_s * 1e6, 3),
        "predicted_us": round(
            predicted_us[json.dumps(best, sort_keys=True)], 3),
        "pipeline": bool(pipeline),
        "candidates": len(space),
        "measured": measured,
    }
    cache.put(key, entry)
    if cache.path:
        cache.save()
    return dict(best)
