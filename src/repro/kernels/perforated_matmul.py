"""Herded-perforated matmul Pallas kernel (paper section 3.1.5 on TPU).

Drops the SAME K-blocks of the contraction for every output tile. Because the
kept set is shared ("herded"), the grid is simply *shorter*: dropped blocks
are never scheduled, so -- unlike per-element (divergent) perforation, which
on a vector machine saves nothing -- the FLOP savings are structural:
executed_flops = kept/total * full_flops.

Two perforation modes share one kernel body (the same split as
``perforated_attention``):

  * **structural** (`fraction=None`): the kept-block list is computed on the
    host from the static `perfo` params and the grid enumerates ONLY the
    kept blocks -- dropped blocks are never scheduled (the herded payoff).
  * **masked** (`fraction=` a possibly-traced scalar; ini/fini/random
    kinds): the grid enumerates ALL K blocks and a per-block liveness
    vector -- computed in-trace from the traced fraction -- gates each
    block's accumulation under ``@pl.when``. The compiled program is shaped
    only by the block geometry, so a fraction sweep compiles once.

The kept-block list, liveness vector, and rescale factor arrive via TPU
scalar prefetch (``pltpu.PrefetchScalarGridSpec``): the index maps read
``kept_ref[kk]`` so the DMA engine fetches exactly the kept tiles; in
structural mode control flow stays perfectly uniform (liveness is all-ones,
so the ``@pl.when`` guard is compile-time foldable on the hot path).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.perforation import (FRACTION_KINDS, kept_indices,
                                    traced_execute_mask)
from repro.core.types import PerforationParams


def _perf_matmul_kernel(kept_ref, live_ref, factor_ref, x_ref, w_ref, o_ref,
                        acc_ref, *, n_enum: int):
    del kept_ref  # consumed by the index maps
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(live_ref[k] > 0)
    def _accum():
        acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                                w_ref[...].astype(jnp.float32),
                                preferred_element_type=jnp.float32)

    @pl.when(k == n_enum - 1)
    def _fini():
        o_ref[...] = (acc_ref[...] * factor_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "perfo", "rescale", "out_dtype",
    "interpret", "pipeline"))
def perforated_matmul(x: jnp.ndarray, w: jnp.ndarray, *, block_m: int = 128,
                      block_n: int = 128, block_k: int = 128,
                      perfo: Optional[PerforationParams] = None,
                      fraction=None,
                      rescale: bool = False, out_dtype=jnp.float32,
                      interpret: bool = False,
                      pipeline: bool = False) -> jnp.ndarray:
    """Y ~= X @ W computing only the kept K-blocks (herded perforation).

    `fraction` is the traced-parameter hook: a (possibly traced) scalar
    overriding ``perfo.fraction`` for the fraction-driven kinds
    (ini/fini/random). When set, the kernel runs in MASKED mode -- the grid
    enumerates every K block and a liveness vector computed in-trace gates
    the dropped ones -- so the same compiled program serves any fraction.

    `pipeline=True` marks the two output-tile axes (i, j) "parallel" (the
    accumulator scratch only carries along the kk axis), letting Mosaic
    multi-buffer the next tile's operand DMA against the current tile's
    compute. Bit-identical outputs either way.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(
            f"perforated_matmul contraction mismatch: x has K={k} columns "
            f"but w has K={k2} rows (x.shape={tuple(x.shape)}, "
            f"w.shape={tuple(w.shape)})")
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"perforated_matmul block shape (block_m={block_m}, "
            f"block_n={block_n}, block_k={block_k}) does not divide the "
            f"operand geometry (M={m}, N={n}, K={k}): each block must "
            "divide its axis. kernels.tuning.search_space() enumerates "
            "only divisor-valid shapes for these operands.")
    nk = k // block_k
    if fraction is not None:
        if perfo is None or perfo.kind not in FRACTION_KINDS:
            raise ValueError(
                "fraction is a traced hook for ini/fini/random perforation; "
                f"got perfo={perfo}")
        # Masked mode: enumerate every K block; liveness is data.
        kept_arr = jnp.arange(nk, dtype=jnp.int32)
        live_arr = traced_execute_mask(nk, perfo, fraction).astype(jnp.int32)
        n_enum = nk
        n_live = jnp.maximum(jnp.sum(live_arr), 1).astype(jnp.float32)
        factor = (nk / n_live) if rescale else jnp.float32(1.0)
    else:
        kept = np.arange(nk) if perfo is None else kept_indices(nk, perfo)
        if len(kept) == 0:
            raise ValueError("perforation dropped every K block")
        kept_arr = jnp.asarray(kept, jnp.int32)
        live_arr = jnp.ones((len(kept),), jnp.int32)
        n_enum = len(kept)
        factor = (nk / n_enum) if rescale else 1.0
    factor_arr = jnp.asarray(factor, jnp.float32).reshape((1,))

    kernel = functools.partial(_perf_matmul_kernel, n_enum=n_enum)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(m // block_m, n // block_n, n_enum),
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda i, j, kk, kept_ref, live_ref, factor_ref:
                         (i, kept_ref[kk])),
            pl.BlockSpec((block_k, block_n),
                         lambda i, j, kk, kept_ref, live_ref, factor_ref:
                         (kept_ref[kk], j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda i, j, kk, kept_ref, live_ref, factor_ref:
                               (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    extra = {}
    if pipeline:
        # i and j tile independent outputs; only kk carries the accumulator
        # scratch. Interpret mode ignores compiler_params entirely.
        extra["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
        **extra,
    )(kept_arr, live_arr, factor_arr, x, w)
