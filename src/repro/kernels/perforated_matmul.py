"""Herded-perforated matmul Pallas kernel (paper section 3.1.5 on TPU).

Drops the SAME K-blocks of the contraction for every output tile. Because the
kept set is shared ("herded"), the grid is simply *shorter*: dropped blocks
are never scheduled, so -- unlike per-element (divergent) perforation, which
on a vector machine saves nothing -- the FLOP savings are structural:
executed_flops = kept/total * full_flops.

The kept-block list arrives via TPU scalar prefetch
(``pltpu.PrefetchScalarGridSpec``): the index maps read ``kept_ref[kk]`` so
the DMA engine fetches exactly the kept tiles; control flow is perfectly
uniform (no ``@pl.when`` on the hot path).
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.perforation import kept_indices
from repro.core.types import PerforationParams


def _perf_matmul_kernel(kept_ref, x_ref, w_ref, o_ref, acc_ref, *,
                        n_kept: int, rescale_factor: float):
    del kept_ref  # consumed by the index maps
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == n_kept - 1)
    def _fini():
        o_ref[...] = (acc_ref[...] * rescale_factor).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "block_k", "perfo", "rescale", "out_dtype",
    "interpret"))
def perforated_matmul(x: jnp.ndarray, w: jnp.ndarray, *, block_m: int = 128,
                      block_n: int = 128, block_k: int = 128,
                      perfo: Optional[PerforationParams] = None,
                      rescale: bool = False, out_dtype=jnp.float32,
                      interpret: bool = False) -> jnp.ndarray:
    """Y ~= X @ W computing only the kept K-blocks (herded perforation)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    nk = k // block_k
    kept = np.arange(nk) if perfo is None else kept_indices(nk, perfo)
    if len(kept) == 0:
        raise ValueError("perforation dropped every K block")
    kept_arr = jnp.asarray(kept, jnp.int32)
    n_kept = len(kept)
    factor = (nk / n_kept) if rescale else 1.0

    kernel = functools.partial(_perf_matmul_kernel, n_kept=n_kept,
                               rescale_factor=factor)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // block_m, n // block_n, n_kept),
        in_specs=[
            pl.BlockSpec((block_m, block_k),
                         lambda i, j, kk, kept_ref: (i, kept_ref[kk])),
            pl.BlockSpec((block_k, block_n),
                         lambda i, j, kk, kept_ref: (kept_ref[kk], j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda i, j, kk, kept_ref: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(kept_arr, x, w)
