"""Pure-jnp oracles for every Pallas kernel in this package.

Each `*_ref` implements the exact block-level semantics of the corresponding
kernel (same block partitioning, same TAF/iACT state evolution, same
perforation sets) so tests can `assert_allclose` kernel-vs-ref across shape
and dtype sweeps.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.perforation import kept_indices
from repro.core.types import PerforationParams


# ----------------------------------------------------------------------------
# plain matmul
# ----------------------------------------------------------------------------

def matmul_ref(x: jnp.ndarray, w: jnp.ndarray,
               out_dtype=jnp.float32) -> jnp.ndarray:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32)).astype(out_dtype)


# ----------------------------------------------------------------------------
# TAF matmul (block-level output memoization across row-blocks)
# ----------------------------------------------------------------------------

def taf_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, *, block_m: int,
                   block_n: int, history_size: int, prediction_size: int,
                   rsd_threshold: float,
                   out_dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels/taf_matmul.py.

    Grid semantics: for each column-block j, row-blocks i = 0..M/bm-1 are a
    temporal sequence of invocations of "the region" (paper Fig. 4d: the
    core's grid-stride loop). Block-level TAF state per j:
      window of last `history_size` block means; when RSD < threshold the
      next `prediction_size` row-blocks reuse the memoized block output.
    Returns (y, approx_mask) where approx_mask is (M/bm, N/bn) bool.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and m % block_m == 0 and n % block_n == 0
    num_i, num_j = m // block_m, n // block_n
    xf = np.asarray(x, np.float32)
    wf = np.asarray(w, np.float32)
    y = np.zeros((m, n), np.float32)
    approx = np.zeros((num_i, num_j), bool)
    for j in range(num_j):
        window: list = []
        remaining = 0
        memo = np.zeros((block_m, block_n), np.float32)
        for i in range(num_i):
            if remaining > 0:
                y[i * block_m:(i + 1) * block_m,
                  j * block_n:(j + 1) * block_n] = memo
                remaining -= 1
                approx[i, j] = True
                continue
            blk = xf[i * block_m:(i + 1) * block_m] @ \
                wf[:, j * block_n:(j + 1) * block_n]
            y[i * block_m:(i + 1) * block_m,
              j * block_n:(j + 1) * block_n] = blk
            memo = blk
            window.append(float(blk.mean()))
            window = window[-history_size:]
            if len(window) == history_size:
                mu = float(np.mean(window))
                sigma = float(np.std(window))
                if sigma / max(abs(mu), 1e-12) < rsd_threshold:
                    remaining = prediction_size
    return jnp.asarray(y).astype(out_dtype), jnp.asarray(approx)


# ----------------------------------------------------------------------------
# iACT memoized row function (two-phase, single-writer, round-robin)
# ----------------------------------------------------------------------------

def iact_rowfn_ref(x: jnp.ndarray, w1: jnp.ndarray, w2: jnp.ndarray, *,
                   block_rows: int, table_size: int, threshold: float,
                   out_dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for kernels/iact_memo.py.

    Region fn: y = gelu(x @ w1) @ w2 per row (an FFN tile -- the archetypal
    "expensive device function"). Rows are processed in blocks of
    `block_rows`; one table serves each block (tables_per_block=1); the
    decision is block-level majority (the kernel's only real-savings mode).
    Read phase -> vote -> (approx: nearest value | accurate: compute, then
    single max-distance writer inserts round-robin).
    Returns (y, block_approx_mask (num_blocks,)).
    """
    n, d_in = x.shape
    d_out = w2.shape[1]
    assert n % block_rows == 0
    num_b = n // block_rows
    xf = np.asarray(x, np.float32)
    w1f = np.asarray(w1, np.float32)
    w2f = np.asarray(w2, np.float32)

    def f(rows):
        h = rows @ w1f
        h = 0.5 * h * (1.0 + np.tanh(np.sqrt(2 / np.pi) * (h + 0.044715 * h ** 3)))
        return h @ w2f

    keys = np.zeros((table_size, d_in), np.float32)
    values = np.zeros((table_size, d_out), np.float32)
    valid = np.zeros((table_size,), bool)
    cursor = 0
    y = np.zeros((n, d_out), np.float32)
    approx = np.zeros((num_b,), bool)
    for b in range(num_b):
        rows = xf[b * block_rows:(b + 1) * block_rows]
        if valid.any():
            d = np.linalg.norm(rows[:, None, :] - keys[None], axis=-1)
            d[:, ~valid] = np.inf
            best = d.argmin(axis=1)
            mind = d.min(axis=1)
        else:
            best = np.zeros((block_rows,), int)
            mind = np.full((block_rows,), np.inf)
        hit = mind < threshold
        if hit.sum() * 2 > block_rows:                       # majority-rules
            y[b * block_rows:(b + 1) * block_rows] = values[best]
            approx[b] = True
            continue
        out = f(rows)
        y[b * block_rows:(b + 1) * block_rows] = out
        # single writer: the row farthest from any cached value
        writer = int(np.where(np.isinf(mind), np.float32(3.4e38), mind).argmax())
        keys[cursor] = rows[writer]
        values[cursor] = out[writer]
        valid[cursor] = True
        cursor = (cursor + 1) % table_size
    return jnp.asarray(y).astype(out_dtype), jnp.asarray(approx)


# ----------------------------------------------------------------------------
# herded-perforated matmul (K-block dropping)
# ----------------------------------------------------------------------------

def perforated_matmul_ref(x: jnp.ndarray, w: jnp.ndarray, *, block_k: int,
                          perfo: Optional[PerforationParams],
                          rescale: bool = False,
                          out_dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for kernels/perforated_matmul.py: drop the same K-blocks from
    the contraction for every output tile (herded -> uniform control flow)."""
    m, k = x.shape
    assert k % block_k == 0
    nk = k // block_k
    kept = list(range(nk)) if perfo is None else list(kept_indices(nk, perfo))
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    acc = jnp.zeros((m, w.shape[1]), jnp.float32)
    for kb in kept:
        sl = slice(kb * block_k, (kb + 1) * block_k)
        acc = acc + xf[:, sl] @ wf[sl, :]
    if rescale and kept:
        acc = acc * (nk / len(kept))
    return acc.astype(out_dtype)


# ----------------------------------------------------------------------------
# flash attention with herded KV-block perforation
# ----------------------------------------------------------------------------

def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, block_kv: Optional[int] = None,
                  perfo: Optional[PerforationParams] = None,
                  scale: Optional[float] = None,
                  out_dtype=None) -> jnp.ndarray:
    """Oracle for kernels/perforated_attention.py.

    q: (B, H, Sq, D), k/v: (B, H, Skv, D). When `perfo` is set, whole KV
    blocks of size `block_kv` are dropped from the softmax domain -- the
    same blocks for every query (herded; ini == drop-oldest-context,
    fini == drop-newest-context).
    """
    b, h, sq, d = q.shape
    skv = k.shape[2]
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    mask = jnp.ones((sq, skv), bool)
    if causal:
        offset = skv - sq  # queries sit at the END of the KV timeline
        qi = jnp.arange(sq)[:, None]
        ki = jnp.arange(skv)[None, :]
        mask = mask & (ki <= qi + offset)
    if perfo is not None:
        assert block_kv is not None and skv % block_kv == 0
        nkv = skv // block_kv
        keepb = np.zeros((nkv,), bool)
        keepb[kept_indices(nkv, perfo)] = True
        keep = np.repeat(keepb, block_kv)
        mask = mask & jnp.asarray(keep)[None, :]
    logits = jnp.where(mask[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(jnp.isnan(probs), 0.0, probs)  # fully-masked rows
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(out_dtype or q.dtype)
