"""TAF-memoized matmul Pallas kernel (paper sections 3.1.1, 3.1.3 on TPU).

Y = X @ W over a (num_j, num_i) grid of (block_m, block_n) output tiles.
TPU Pallas grids execute **sequentially** on a core, so for a fixed column
block j the row blocks i = 0..num_i-1 form exactly the paper's grid-stride
temporal sequence (Figure 4d), and VMEM/SMEM scratch is the paper's
"shared memory" AC state (section 3.1.1): its size depends on the block shape,
never on the total number of logical iterations.

State (per column block; reset when i wraps to 0, i.e. kernel-lifetime scope):
  window    VMEM (1, history_size) -- last accurate block-mean outputs
  counters  SMEM (2,)              -- [filled, remaining]
  memo      VMEM (block_m, block_n) -- last accurate block output

The decision is **block-level** (paper `level(team)`): a scalar predicate
drives ``@pl.when``, so an approximated tile genuinely skips its MXU dot --
the divergence-free fast path that element-level masking cannot give on a
vector machine (DESIGN.md section 2).

The RSD threshold is a **traced** scalar-prefetch operand, not a static jit
argument: the compiled program is shaped only by the structural parameters
(block shape, history/prediction sizes), so a threshold sweep reuses one
executable per structural group and a batched runner can ``jax.vmap``
stacked thresholds straight through the kernel (docs/kernels.md).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _taf_matmul_kernel(thresh_ref, x_ref, w_ref, o_ref, mask_ref,
                       window_ref, counters_ref, memo_ref, *,
                       history_size: int, prediction_size: int):
    j = pl.program_id(0)  # column block (slow axis)
    i = pl.program_id(1)  # row block (fast axis) -- the temporal sequence
    del j
    rsd_threshold = thresh_ref[0]

    @pl.when(i == 0)
    def _reset():  # kernel-lifetime state scope, fresh per column block
        counters_ref[0] = 0  # filled
        counters_ref[1] = 0  # remaining
        window_ref[...] = jnp.zeros_like(window_ref)

    remaining = counters_ref[1]
    approximate = remaining > 0

    @pl.when(approximate)
    def _approx_path():
        # Return the last accurately-computed output; no MXU work at all.
        o_ref[...] = memo_ref[...].astype(o_ref.dtype)
        mask_ref[0, 0] = 1
        counters_ref[1] = remaining - 1

    @pl.when(jnp.logical_not(approximate))
    def _accurate_path():
        y = jnp.dot(x_ref[...].astype(jnp.float32),
                    w_ref[...].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)
        mask_ref[0, 0] = 0
        memo_ref[...] = y
        # Slide the RSD window (hSize is tiny: 1..5).
        s = jnp.mean(y)
        win = window_ref[0, :]
        win = jnp.roll(win, -1).at[history_size - 1].set(s)
        window_ref[0, :] = win
        filled = jnp.minimum(counters_ref[0] + 1, history_size)
        counters_ref[0] = filled
        mu = jnp.mean(win)
        sigma = jnp.sqrt(jnp.maximum(jnp.mean(win * win) - mu * mu, 0.0))
        stable = (sigma / jnp.maximum(jnp.abs(mu), 1e-12) < rsd_threshold)
        stable = jnp.logical_and(stable, filled >= history_size)
        counters_ref[1] = jnp.where(stable, prediction_size, 0)


@functools.partial(jax.jit, static_argnames=(
    "block_m", "block_n", "history_size", "prediction_size",
    "out_dtype", "interpret", "pipeline"))
def taf_matmul(x: jnp.ndarray, w: jnp.ndarray, *, block_m: int = 128,
               block_n: int = 128, history_size: int = 3,
               prediction_size: int = 8, rsd_threshold=0.5,
               out_dtype=jnp.float32, interpret: bool = False,
               pipeline: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (M, N), approx_mask (num_i, num_j) int32).

    `rsd_threshold` may be a Python float or a traced scalar: it rides in
    scalar memory and never shapes the compiled program.

    `pipeline=True` marks the column-block axis j "parallel" (it carries no
    scratch state: window/counters/memo reset at i == 0 per column block),
    letting Mosaic multi-buffer the next tile's operand DMA against the
    current tile's compute. The temporal axis i stays "arbitrary" -- its
    scratch carry IS the TAF mechanism. Bit-identical outputs either way.
    """
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(
            f"taf_matmul contraction mismatch: x has K={k} columns but w "
            f"has K={k2} rows (x.shape={tuple(x.shape)}, "
            f"w.shape={tuple(w.shape)})")
    if m % block_m or n % block_n:
        raise ValueError(
            f"taf_matmul block shape ({block_m}, {block_n}) does not divide "
            f"the output geometry ({m}, {n}): block_m must divide M={m} and "
            f"block_n must divide N={n}. kernels.tuning.search_space() "
            "enumerates only divisor-valid shapes for these operands.")
    num_i, num_j = m // block_m, n // block_n

    thresh = jnp.asarray(rsd_threshold, jnp.float32).reshape((1,))
    grid = (num_j, num_i)  # j slow, i fast: temporal sequence over row blocks
    kernel = functools.partial(
        _taf_matmul_kernel, history_size=history_size,
        prediction_size=prediction_size)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda j, i, thresh_ref: (i, 0)),
            pl.BlockSpec((k, block_n), lambda j, i, thresh_ref: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), lambda j, i, thresh_ref: (i, j)),
            pl.BlockSpec((1, 1), lambda j, i, thresh_ref: (i, j)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, history_size), jnp.float32),
            pltpu.SMEM((2,), jnp.int32),
            pltpu.VMEM((block_m, block_n), jnp.float32),
        ],
    )
    extra = {}
    if pipeline:
        # j carries no state across grid steps (scratch resets at i == 0 per
        # column block); i is the paper's temporal sequence and must stay
        # sequential. Interpret mode ignores compiler_params entirely.
        extra["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    y, mask = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((m, n), out_dtype),
            jax.ShapeDtypeStruct((num_i, num_j), jnp.int32),
        ],
        interpret=interpret,
        **extra,
    )(thresh, x, w)
    return y, mask.astype(bool)
