"""Pallas TPU kernels for the HPAC-Offload hot paths.

Four kernels, each the TPU re-derivation of one paper mechanism:
  taf_matmul            -- section 3.1.3 TAF with VMEM-scratch state machine
  iact_memo             -- section 3.1.4 iACT with VMEM memo tables, two-phase update
  perforated_matmul     -- section 3.1.5 herded perforation of the K loop
  perforated_attention  -- section 3.1.5 herded KV-block perforation / flash attn

ops.py  -- jit'd wrappers (auto interpret on CPU)
ref.py  -- pure-jnp oracles with identical block semantics
"""
from . import ops, ref

__all__ = ["ops", "ref"]
