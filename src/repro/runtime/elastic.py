"""Elastic scaling: choose a mesh for the devices that are actually alive,
and reshard state onto it.

Recovery flow after losing hosts (or gaining them back):
  1. `best_mesh_shape(n)` picks the largest supported (data, model) grid
     that fits n devices (model axis preserved when possible -- TP degree is
     a property of the weight layout; the data axis absorbs elasticity).
  2. rebuild shardings for the new mesh (runtime.sharding).
  3. CheckpointManager.restore(..., shardings=new) reshards on load.
The global batch is kept constant by rescaling gradient-accumulation steps
(`accum_steps_for`), so training dynamics are unchanged across reshapes.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

from repro.compat import make_mesh


def best_mesh_shape(n_devices: int, model_parallel: int = 16,
                    min_model: int = 1) -> Tuple[int, int]:
    """Largest (data, model) grid with data*model <= n_devices, preferring to
    keep the requested TP degree; degrade TP only when unavoidable."""
    mp = min(model_parallel, n_devices)
    while mp > min_model and n_devices % mp:
        mp //= 2
    data = n_devices // mp
    return data, mp


def make_mesh_for(n_devices: Optional[int] = None, model_parallel: int = 16,
                  axis_names: Sequence[str] = ("data", "model")):
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    data, mp = best_mesh_shape(n, model_parallel)
    return make_mesh((data, mp), axis_names, devices=devs[: data * mp])


def data_mesh_for(n_devices: Optional[int] = None,
                  axis_names: Sequence[str] = ("data", "model")):
    """Pure data-parallel mesh for the SERVING data plane: request lanes
    shard over `data`, TP degree pinned to 1 (decode-time TAF actuates
    per-shard thresholds, and a model axis would split heads the sharded
    serve step does not reduce over). Shape selection still flows through
    `best_mesh_shape`, so elasticity semantics match training: losing a
    device reshapes to (n-1, 1) and the engine re-plans its shards."""
    return make_mesh_for(n_devices, model_parallel=1, axis_names=axis_names)


def accum_steps_for(global_batch: int, per_device_batch: int,
                    n_data_shards: int) -> int:
    """Keep the global batch constant across elastic reshapes by adjusting
    gradient accumulation."""
    per_step = per_device_batch * n_data_shards
    accum = max(1, global_batch // per_step)
    if accum * per_step != global_batch:
        raise ValueError(
            f"global_batch {global_batch} not reachable with "
            f"{n_data_shards} shards x {per_device_batch}/device")
    return accum
