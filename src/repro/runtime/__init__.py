from . import elastic, hlo, pipeline, sharding, straggler

__all__ = ["elastic", "hlo", "pipeline", "sharding", "straggler"]
