"""Compiled-HLO analysis: collective byte accounting for the roofline.

`cost_analysis()` has FLOPs and memory bytes but no collective traffic; we
parse the post-SPMD compiled HLO text and sum operand bytes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%name = TYPE[dims]{layout} op-name(...)` (possibly tuple-typed)
_OP_RE = re.compile(
    r"=\s*(?P<type>\(?[a-z0-9](?:[^=\n])*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"(?P<dt>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def summary(self) -> str:
        parts = [f"{k}: n={self.counts.get(k, 0)} "
                 f"bytes={self.bytes_by_kind.get(k, 0):,}"
                 for k in _COLLECTIVES if self.counts.get(k, 0)]
        return "; ".join(parts) if parts else "none"


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Sum RESULT-shape bytes of every collective op (per-device view:
    SPMD-partitioned HLO shapes are already per-device). `-done` ops are
    skipped so async start/done pairs are not double-counted."""
    counts: Dict[str, int] = {}
    byts: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        b = _shape_bytes(m.group("type"))
        counts[op] = counts.get(op, 0) + 1
        byts[op] = byts.get(op, 0) + b
    return CollectiveStats(counts, byts)


_CONVERT_RE = re.compile(
    r"=\s+(?P<dt>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\][^ ]*\s+convert\(")
_FREE_OPS_RE = re.compile(
    r"=\s+(?P<dt>[a-z][a-z0-9]*)\[(?P<dims>[0-9,]*)\][^ ]*\s+"
    r"(?P<op>bitcast|copy)\(")


def entry_text(hlo_text: str) -> str:
    """The ENTRY computation's lines only (top-level ops; fusion bodies are
    separate computations whose interior ops never touch HBM)."""
    lines = hlo_text.splitlines()
    out = []
    depth = None
    for ln in lines:
        if depth is None:
            if ln.startswith("ENTRY"):
                depth = 1
            continue
        depth += ln.count("{") - ln.count("}")
        out.append(ln)
        if depth <= 0:
            break
    return "\n".join(out)


def convert_bytes(hlo_text: str) -> int:
    """Total (operand + output) bytes of TOP-LEVEL dtype-convert ops.

    The CPU backend materializes bf16<->f32 converts around every dot; a TPU
    MXU consumes bf16 natively and fuses converts into surrounding ops. The
    roofline's TPU-faithful memory term subtracts this traffic (reported as
    `memory_adj_s` next to the raw `memory_s`).
    """
    hlo_text = entry_text(hlo_text)
    total = 0
    for m in _CONVERT_RE.finditer(hlo_text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        dims = m.group("dims")
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out_b = n * _DTYPE_BYTES[dt]
        # operand dtype unknown from this line; bf16<->f32 dominates:
        in_b = out_b // 2 if dt in ("f32", "s32") else out_b * 2
        total += out_b + in_b
    for m in _FREE_OPS_RE.finditer(hlo_text):
        dt = m.group("dt")
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        dims = m.group("dims")
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += 2 * n * _DTYPE_BYTES[dt]  # bitcasts/copies are free on TPU
    return total


def while_trip_counts(hlo_text: str) -> List[int]:
    """Best-effort trip counts of while loops (for scan-aware cost accounting
    diagnostics)."""
    out = []
    for m in re.finditer(r"trip_count[=:]\s*(\d+)", hlo_text):
        out.append(int(m.group(1)))
    return out
