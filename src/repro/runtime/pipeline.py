"""GPipe-style pipeline parallelism over a mesh axis via shard_map +
lax.ppermute.

`pipeline_apply(fn, params_stacked, x, mesh, axis)` treats the `axis` mesh
dimension as pipeline stages: stage s holds layer-group s of the stacked
params (sharded on their leading dim) and passes activations to stage s+1
with collective_permute. Microbatching: the input batch is split into M
microbatches; the schedule runs S + M - 1 ticks (fill + steady state +
drain), the classic GPipe bubble fraction (S-1)/(S+M-1).

This substrate is validated in tests/test_distributed.py on 8 host devices
and is the PP building block for meshes that dedicate the `pod` axis to
stages. The default production configs use DP over `pod` (better for the
assigned shapes -- see DESIGN.md section 5); PP is config-selectable.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

PyTree = Any


def pipeline_apply(layer_fn: Callable, params_stacked: PyTree, x: jnp.ndarray,
                   mesh: Mesh, axis: str = "stage",
                   n_microbatches: int = 4) -> jnp.ndarray:
    """Run x through S pipeline stages, each applying `layer_fn(params_s, .)`.

    layer_fn: (stage_params, activations (mb, ...)) -> activations.
    params_stacked: leaves with leading dim == S (one slice per stage).
    x: (batch, ...) with batch % n_microbatches == 0.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0, (b, n_microbatches)
    mb = b // n_microbatches

    param_specs = jax.tree.map(lambda _: P(axis), params_stacked)

    def stage_program(params_local, x_local):
        # params_local leaves: (1, ...) -- this stage's slice
        params_s = jax.tree.map(lambda a: a[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        xm = x_local.reshape((n_microbatches, mb) + x_local.shape[1:])
        n_ticks = n_stages + n_microbatches - 1
        fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(carry, t):
            buf, out = carry                     # buf: (mb,...) in-transit
            # stage 0 injects microbatch t (if available)
            inject = jnp.where(t < n_microbatches, t, 0)
            x_in = jnp.where(stage_id == 0,
                             xm[inject].astype(buf.dtype), buf)
            active = jnp.logical_and(stage_id <= t,
                                     t - stage_id < n_microbatches)
            y = layer_fn(params_s, x_in)
            y = jnp.where(active, y, x_in)
            # last stage collects its finished microbatch
            done_idx = t - (n_stages - 1)
            collect = jnp.logical_and(stage_id == n_stages - 1,
                                      done_idx >= 0)
            out = jax.lax.cond(
                collect,
                lambda o: jax.lax.dynamic_update_slice(
                    o, y[None].astype(o.dtype),
                    (jnp.maximum(done_idx, 0),) + (0,) * (o.ndim - 1)),
                lambda o: o, out)
            buf_next = jax.lax.ppermute(y, axis, fwd_perm)
            return (buf_next, out), None

        buf0 = jnp.zeros((mb,) + x_local.shape[1:], x_local.dtype)
        out0 = jnp.zeros((n_microbatches, mb) + x_local.shape[1:],
                         x_local.dtype)
        (buf, out), _ = jax.lax.scan(tick, (buf0, out0),
                                     jnp.arange(n_ticks))
        # only the last stage holds real output; zero elsewhere + psum
        # broadcasts it (replicated out-spec)
        out = jnp.where(stage_id == n_stages - 1, out,
                        jnp.zeros_like(out))
        out = jax.lax.psum(out, axis)
        return out.reshape((b,) + x_local.shape[1:])

    fn = shard_map(stage_program, mesh=mesh,
                   in_specs=(param_specs, P()),
                   out_specs=P(), check_replication=False)
    return fn(params_stacked, x)
