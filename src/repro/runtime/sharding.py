"""Sharding rules: map every parameter / activation / cache leaf to a
PartitionSpec on the production mesh.

Policy (Megatron-style TP over `model`, DP over `data` (+`pod`), optional
FSDP/ZeRO-3 over the data axes):

  column-parallel weights (out-features sharded):  (..., d, f)  -> f: model
  row-parallel weights (in-features sharded):      (..., f, d)  -> f: model
  embeddings (V, d):                                V: model
  MoE expert stacks (L, E, d, f):                   E: model (EP)
  norms / biases / scalars:                         replicated
  FSDP: additionally shard the largest replicated dim over the data axes.

Leading layer-stack dims (from scan-stacked init) are never sharded.
Divisibility is checked against the mesh and the rule silently degrades to
replication for a dim that does not divide (e.g. tiny smoke configs).
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# parameter-name classes (last path component)
_COL = {"wq", "wk", "wv", "w_gate", "w_up", "w_in", "w_r", "w_k", "w_v",
        "w_g", "w_uq", "w_uk", "w_uv", "w_dq", "w_dkv", "head", "proj",
        "decay_A", "decay_B"}
_ROW = {"wo", "w_down", "w_out", "w_o"}
_EMBED = {"embed"}
# rwkv channel-mix: w_k is col (d->f), w_v is row (f->d) -- disambiguated by
# path context below; attention wv stays col.


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(np.prod([mesh.shape[a] for a in axis]))
    return mesh.shape[axis]


def _fits(shape, dim: int, mesh: Mesh, axis) -> bool:
    return shape[dim] % _axis_size(mesh, axis) == 0


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ('pod','data') on multi-pod, ('data',) else."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_spec(mesh: Mesh) -> P:
    da = data_axes(mesh)
    return P(da if len(da) > 1 else da[0])


def _param_spec(path: str, shape, mesh: Mesh, fsdp: bool) -> P:
    parts = path.split("||")
    name = parts[-1].strip("[]'\" .")
    rank = len(shape)
    spec = [None] * rank
    in_moe = any("moe" in p for p in parts)
    in_cm = any("cm" in p.strip("[]'\" .") == "cm" or p.strip("[]'\" .") == "cm"
                for p in parts)

    def set_if(dim, axis):
        if spec[dim] is None and _fits(shape, dim, mesh, axis):
            spec[dim] = axis
            return True
        return False

    if name in _EMBED and rank == 2:
        set_if(0, "model")
    elif in_moe and name in ("w_gate", "w_up", "w_down") and rank >= 3:
        # expert stacks: (..., E, d, f) -- shard E (EP)
        set_if(rank - 3, "model")
    elif in_cm and name == "w_v" and rank >= 2:
        set_if(rank - 2, "model")      # rwkv channel-mix down-proj: row
    elif name in _ROW and rank >= 2:
        set_if(rank - 2, "model")
    elif name in _COL and rank >= 2:
        set_if(rank - 1, "model")
    # FSDP/ZeRO-3: shard one remaining dim over the data axes
    if fsdp and rank >= 2:
        da = data_axes(mesh)
        axis = da if len(da) > 1 else da[0]
        # prefer the largest unsharded trailing dim
        dims = sorted(range(max(rank - 2, 0), rank),
                      key=lambda d: -shape[d])
        for d in dims:
            if spec[d] is None and set_if(d, axis):
                break
    return P(*spec)


def param_shardings(mesh: Mesh, params: PyTree, fsdp: bool = False) -> PyTree:
    """NamedSharding tree mirroring `params` (works on ShapeDtypeStructs)."""
    def one(path, leaf):
        key = "||".join(str(p) for p in path)
        spec = _param_spec(key, leaf.shape, mesh, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(mesh: Mesh, opt_state: PyTree,
                        fsdp: bool = False) -> PyTree:
    """AdamW moments mirror the param layout; the step counter replicates."""
    def one(path, leaf):
        key = "||".join(str(p) for p in path)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = _param_spec(key, leaf.shape, mesh, fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, opt_state)


def cache_shardings(mesh: Mesh, cache: PyTree, batch_size: int) -> PyTree:
    """Decode/prefill cache layout. Rules per leaf (leading dim is the
    layer stack for stacked caches):
      * batch dim sharded over the data axes when divisible;
      * a heads-like dim sharded over `model` when divisible;
      * long_500k (batch=1): the SEQUENCE dim shards over `data` instead
        (context parallelism) and heads over `model`.
    """
    da = data_axes(mesh)
    daxis = da if len(da) > 1 else da[0]
    d_sz = _axis_size(mesh, daxis)
    m_sz = mesh.shape["model"]

    def one(path, leaf):
        shape = leaf.shape
        rank = len(shape)
        spec = [None] * rank
        # find the batch dim: first dim equal to batch_size (after any
        # leading layer-stack dims)
        try:
            bdim = next(i for i, s in enumerate(shape) if s == batch_size)
        except StopIteration:
            bdim = None
        if bdim is not None and shape[bdim] % d_sz == 0:
            spec[bdim] = daxis
            seq_shardable = False
        else:
            seq_shardable = True  # batch unshardable: context parallelism
        # shard a heads/seq dim over model: prefer a dim divisible by m_sz
        start = (bdim + 1) if bdim is not None else 1
        for i in range(start, rank):
            if spec[i] is None and shape[i] > 1 and shape[i] % m_sz == 0:
                spec[i] = "model"
                break
        if seq_shardable:
            # context parallelism: the largest remaining dim over data
            dims = sorted(range(rank), key=lambda d: -shape[d])
            for d in dims:
                if spec[d] is None and shape[d] % d_sz == 0 and shape[d] > 1:
                    spec[d] = daxis
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ----------------------------------------------------------------------------
# serving data plane: decode-cache layout for the sharded serve step
# ----------------------------------------------------------------------------

def decode_shard_axis(path, shape, batch_size: int) -> Optional[Tuple[str, int]]:
    """Classify one decode-cache leaf for data-parallel serving.

    Returns ("state", 0) for TAF detector-state leaves (per-shard, leading
    shard dim added by `models.lm.shard_taf_state`), ("batch", axis) for
    leaves carrying the request-lane dim (KV cache, TAF memos), or None for
    replicated leaves. Same caveat as `cache_shardings`: the batch dim is
    found as the FIRST dim equal to `batch_size`, so engines should avoid
    slot counts that collide with the layer/head/sequence extents of their
    cache (e.g. slots == n_layers on a smoke config).
    """
    from repro.models.lm import TAF_SHARD_STATE
    parts = [str(p) for p in path]
    name = parts[-1].strip("[]'\" .") if parts else ""
    if any("taf" in p for p in parts) and name in TAF_SHARD_STATE:
        return ("state", 0)
    for i, s in enumerate(shape):
        if s == batch_size:
            return ("batch", i)
    return None


def decode_partition_specs(mesh: Mesh, cache: PyTree,
                           batch_size: int) -> PyTree:
    """PartitionSpec tree for the sharded serve step's cache argument --
    the shard_map sibling of `cache_shardings` (which builds placement
    NamedShardings for jit). TAF detector state shards its leading
    (logical-shard) dim over the data axes; batch-bearing leaves shard the
    lane dim; everything else replicates.
    """
    da = data_axes(mesh)
    daxis = da if len(da) > 1 else da[0]

    def one(path, leaf):
        kind = decode_shard_axis(path, leaf.shape, batch_size)
        if kind is None:
            return P()
        spec = [None] * len(leaf.shape)
        spec[kind[1]] = daxis
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache)
