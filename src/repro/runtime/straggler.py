"""Straggler detection + preemption handling.

At 1000+ nodes, slow hosts (thermal throttling, failing HBM, noisy
neighbors) stretch every synchronous step. The monitor keeps a rolling
per-step duration window; a step slower than `threshold x median` raises a
flag with an attribution hook (in multi-host deployments, per-host step
barriers timestamps feed `record_host`); the supervisor can then evict/
replace the host and the elastic restore path (checkpoint.manager +
runtime.elastic) brings the job back on the surviving mesh.
"""
from __future__ import annotations

import collections
import dataclasses
import signal
import time
from typing import Callable, Deque, Dict, List, Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    duration_s: float
    median_s: float
    host: Optional[int] = None

    @property
    def slowdown(self) -> float:
        return self.duration_s / max(self.median_s, 1e-9)


class StepMonitor:
    def __init__(self, window: int = 32, threshold: float = 2.5,
                 warmup_steps: int = 4):
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.threshold = threshold
        self.warmup_steps = warmup_steps
        self.events: List[StragglerEvent] = []
        self._step = 0

    def record(self, duration_s: float,
               host: Optional[int] = None) -> Optional[StragglerEvent]:
        self._step += 1
        if self._step <= self.warmup_steps:
            self.window.append(duration_s)
            return None
        med = sorted(self.window)[len(self.window) // 2]
        event = None
        if duration_s > self.threshold * med:
            event = StragglerEvent(self._step, duration_s, med, host)
            self.events.append(event)
        else:
            # only healthy steps update the baseline -- a straggling phase
            # must not drag the median up and mask itself
            self.window.append(duration_s)
        return event

    def record_host_durations(self, durations: Dict[int, float]
                              ) -> List[StragglerEvent]:
        """Multi-host form: per-host step durations (from barrier
        timestamps); flags each host beyond threshold x cross-host median."""
        med = sorted(durations.values())[len(durations) // 2]
        out = []
        for host, d in durations.items():
            if d > self.threshold * med:
                ev = StragglerEvent(self._step, d, med, host)
                self.events.append(ev)
                out.append(ev)
        self._step += 1
        return out


class PreemptionGuard:
    """SIGTERM-aware context: cloud preemptions deliver a grace signal; the
    train loop polls `should_stop` each step and checkpoints before exit."""

    def __init__(self, install: bool = True):
        self._flag = False
        if install:
            try:
                signal.signal(signal.SIGTERM, self._handler)
                signal.signal(signal.SIGINT, self._handler)
            except ValueError:
                pass  # not on main thread (tests)

    def _handler(self, signum, frame):
        self._flag = True

    @property
    def should_stop(self) -> bool:
        return self._flag

    def trigger(self):  # for tests / manual drain
        self._flag = True
