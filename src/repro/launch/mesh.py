"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state -- the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading pod=2 axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small mesh for subprocess distributed tests (8 host devices)."""
    return make_mesh((data, model), ("data", "model"))
