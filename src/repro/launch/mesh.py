"""Production mesh definitions.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state -- the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading pod=2 axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(data: int = 2, model: int = 4):
    """Small mesh for subprocess distributed tests (8 host devices)."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
