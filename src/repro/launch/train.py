"""Production-shaped training driver.

Wires together every substrate: config registry -> model -> sharded
train_step -> synthetic data pipeline (prefetching) -> AdamW + cosine ->
checkpoint manager (async, keep-N, resume) -> straggler monitor ->
preemption guard. On this CPU container it trains reduced configs end-to-end
(examples/train_100m.py drives a ~100M model); on a real cluster the same
driver runs the full configs on the production mesh.

Fault tolerance: `--resume` restarts from the latest checkpoint (the data
pipeline is a pure function of step, so batches replay exactly);
SIGTERM-style preemption triggers a final checkpoint + clean exit(42), and
launch/run_with_restarts.sh supervises restart.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, PrefetchIterator, SyntheticLM
from repro.launch import steps as steps_mod
from repro.models import build
from repro.optim import adamw
from repro.optim import schedule as sched
from repro.runtime import sharding as shardlib
from repro.runtime.elastic import make_mesh_for
from repro.runtime.straggler import PreemptionGuard, StepMonitor

PREEMPTED_EXIT = 42


def add_frontend_stub(batch, cfg, rng):
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = rng.standard_normal(
            (batch["tokens"].shape[0], cfg.n_patch_tokens, cfg.d_model)
        ).astype(np.float32) * 0.02
    elif cfg.frontend == "audio_frames":
        batch["frames"] = rng.standard_normal(
            (batch["tokens"].shape[0], cfg.max_source_positions, cfg.d_model)
        ).astype(np.float32) * 0.02
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build(cfg)
    mesh = make_mesh_for(model_parallel=args.model_parallel)
    n_data = mesh.shape["data"]

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_cfg = adamw.AdamWConfig(lr=args.lr)
    opt_state = adamw.init(params)
    p_sh = shardlib.param_shardings(mesh, params, fsdp=cfg.fsdp)
    o_sh = shardlib.opt_state_shardings(mesh, opt_state, fsdp=cfg.fsdp)
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)

    mgr = CheckpointManager(args.ckpt_dir, keep_n=3, async_save=True) \
        if args.ckpt_dir else None
    start_step = 0
    if args.resume and mgr and mgr.latest_step() is not None:
        (params, opt_state), start_step = mgr.restore(
            (params, opt_state), shardings=(p_sh, o_sh))
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(
        steps_mod.make_train_step(
            model, opt_cfg, schedule_fn=sched.warmup_cosine,
            schedule_kwargs=dict(warmup_steps=args.warmup,
                                 total_steps=args.steps)),
        in_shardings=(p_sh, o_sh, None),
        out_shardings=(p_sh, o_sh, None),
        donate_argnums=(0, 1))

    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq_len,
                                  global_batch=args.batch, seed=args.seed))
    it = PrefetchIterator(data, start_step=start_step)
    monitor = StepMonitor()
    guard = PreemptionGuard()
    rng = np.random.RandomState(args.seed + 17)

    losses = []
    step = start_step
    try:
        for step in range(start_step, args.steps):
            t0 = time.time()
            batch = add_frontend_stub(next(it), cfg, rng)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            ev = monitor.record(dt)
            if ev is not None:
                print(f"[straggler] step {step}: {ev.duration_s:.2f}s = "
                      f"{ev.slowdown:.1f}x median")
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} {dt:.2f}s",
                      flush=True)
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, (params, opt_state))
            if guard.should_stop:
                print("preemption signal: checkpoint + exit")
                if mgr:
                    mgr.save(step + 1, (params, opt_state))
                    mgr.wait()
                sys.exit(PREEMPTED_EXIT)
    finally:
        it.close()
        if mgr:
            mgr.wait()
    if mgr:
        mgr.save(args.steps, (params, opt_state))
        mgr.wait()
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
