"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build the model from its exact config,
  * ShapeDtypeStruct inputs (specs.py) -- zero allocation,
  * jit with production in/out shardings, .lower().compile(),
  * record memory_analysis() (fits?), cost_analysis() (FLOPs/bytes) and the
    collective schedule parsed from the compiled HLO.

Results go to results/dryrun/<cell>.json; EXPERIMENTS.md section Dry-run and the
roofline read from there.

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--single-pod]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (the two lines above MUST precede any jax import: jax locks the device
# count on first init)

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch import specs as specs_mod
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.optim import adamw
from repro.runtime import hlo as hlo_mod
from repro.runtime import sharding as shardlib

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mem_dict(mem) -> Dict[str, int]:
    return {
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "code_bytes": mem.generated_code_size_in_bytes,
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               cfg_override: Optional[Any] = None,
               donate: bool = True) -> Dict[str, Any]:
    """Lower + compile one cell; returns the result record."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": 512 if multi_pod else 256,
        "kind": shape.kind,
    }
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec

    model = build(cfg)
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(model.init, key)
    if shape.kind != "train":
        # serving weights are pre-cast to the compute dtype (one-time cost)
        cdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
            cfg.compute_dtype]
        params_sds = jax.tree.map(
            lambda l: (jax.ShapeDtypeStruct(l.shape, cdt)
                       if jnp.issubdtype(l.dtype, jnp.floating) else l),
            params_sds)
    # FSDP is a TRAINING-memory optimization; serving keeps weights
    # TP-resident (weight re-gather per decode step would dwarf the tiny
    # activation traffic -- measured: dsv3 decode collective 0.107->3.4s
    # with ZeRO-3 on, section Perf iteration B5)
    fsdp_now = cfg.fsdp and shape.kind == "train"
    p_sh = shardlib.param_shardings(mesh, params_sds, fsdp=fsdp_now)

    t0 = time.time()
    # NamedShardings carry the mesh; `with mesh:` is only needed for
    # PartitionSpec-based with_sharding_constraint inside the models.
    with mesh:
        if shape.kind == "train":
            opt_sds = jax.eval_shape(adamw.init, params_sds)
            o_sh = shardlib.opt_state_shardings(mesh, opt_sds, fsdp=cfg.fsdp)
            batch = specs_mod.train_batch_specs(cfg, shape)
            b_sh = specs_mod.batch_shardings(mesh, batch)
            step = steps_mod.make_train_step(model, adamw.AdamWConfig())
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_sds, opt_sds, batch)
        elif shape.kind == "prefill":
            batch = specs_mod.prefill_batch_specs(cfg, shape)
            b_sh = specs_mod.batch_shardings(mesh, batch)
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_sh = shardlib.cache_shardings(mesh, cache_sds,
                                            shape.global_batch)
            step = steps_mod.make_prefill_step(model, shape.seq_len)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh),
                             out_shardings=(None, c_sh))
            lowered = jitted.lower(params_sds, batch)
        elif shape.kind == "decode":
            cache_sds, tok_sds = specs_mod.decode_specs(model, cfg, shape)
            c_sh = shardlib.cache_shardings(mesh, cache_sds,
                                            shape.global_batch)
            t_sh = specs_mod.batch_shardings(mesh, {"tokens": tok_sds})[
                "tokens"]
            step = steps_mod.make_serve_step(model)
            jitted = jax.jit(
                step, in_shardings=(p_sh, c_sh, t_sh, None),
                out_shardings=(t_sh, None, c_sh),
                donate_argnums=(1,) if donate else ())
            pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
            lowered = jitted.lower(params_sds, cache_sds, tok_sds, pos_sds)
        else:
            raise ValueError(shape.kind)

        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compat.cost_analysis(compiled)
    coll = hlo_mod.collective_stats(compiled.as_text())
    n = cfg.param_count()
    n_active = cfg.active_param_count()
    rec.update({
        "status": "ok",
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "memory": _mem_dict(mem),
        "per_device_bytes": (mem.argument_size_in_bytes +
                             mem.output_size_in_bytes +
                             mem.temp_size_in_bytes),
        "hlo_flops_per_device": float(cost.get("flops", 0.0)),
        "hlo_bytes_per_device": float(cost.get("bytes accessed", 0.0)),
        "collectives": {"counts": coll.counts,
                        "bytes_by_kind": coll.bytes_by_kind,
                        "total_bytes_per_device": coll.total_bytes},
        "params": n,
        "active_params": n_active,
    })
    return rec


def run_all(multi_pod_only: bool = False, single_pod_only: bool = False,
            archs=None, shapes=None) -> None:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    meshes = []
    if not multi_pod_only:
        meshes.append(False)
    if not single_pod_only:
        meshes.append(True)
    n_ok = n_skip = n_fail = 0
    for arch in (archs or list_archs()):
        for shape_name in (shapes or list(SHAPES)):
            for multi in meshes:
                tag = f"{arch}__{shape_name}__{'multi' if multi else 'single'}"
                out_path = os.path.join(RESULTS_DIR, tag + ".json")
                try:
                    rec = lower_cell(arch, shape_name, multi)
                except Exception as e:  # a failure here is a system bug
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "FAILED", "error": str(e)[-2000:],
                           "traceback": traceback.format_exc()[-4000:]}
                with open(out_path, "w") as f:
                    json.dump(rec, f, indent=1)
                s = rec["status"]
                n_ok += s == "ok"
                n_skip += s == "skipped"
                n_fail += s == "FAILED"
                extra = ""
                if s == "ok":
                    gb = rec["per_device_bytes"] / 2**30
                    extra = (f"mem/dev={gb:.2f}GiB "
                             f"flops/dev={rec['hlo_flops_per_device']:.3g} "
                             f"coll/dev={rec['collectives']['total_bytes_per_device']:.3g}B "
                             f"compile={rec['compile_s']}s")
                elif s == "FAILED":
                    extra = rec["error"].splitlines()[-1][:160] if rec["error"] else ""
                print(f"[{s:7s}] {tag} {extra}", flush=True)
    print(f"done: ok={n_ok} skipped={n_skip} FAILED={n_fail}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="only the 2x16x16 mesh")
    ap.add_argument("--single-pod", action="store_true",
                    help="only the 16x16 mesh")
    args = ap.parse_args()
    if args.all or not (args.arch and args.shape):
        run_all(multi_pod_only=args.multi_pod,
                single_pod_only=args.single_pod,
                archs=[args.arch] if args.arch else None,
                shapes=[args.shape] if args.shape else None)
        return
    for multi in ([True] if args.multi_pod else
                  [False] if args.single_pod else [False, True]):
        rec = lower_cell(args.arch, args.shape, multi)
        print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
