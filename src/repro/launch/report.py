"""Render EXPERIMENTS.md tables from results/ JSON records.

  PYTHONPATH=src python -m repro.launch.report [--section dryrun|roofline]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results")


def _load(subdir):
    recs = []
    for p in sorted(glob.glob(os.path.join(RESULTS, subdir, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def _gib(b):
    return b / 2 ** 30


def dryrun_table() -> str:
    recs = _load("dryrun")
    out = ["| arch | shape | mesh | status | mem/dev GiB | HLO GFLOP/dev* | "
           "coll MB/dev* | top collectives | compile s |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "ok":
            colls = ", ".join(f"{k}:{v}" for k, v in sorted(
                r["collectives"]["counts"].items()))
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
                f"{_gib(r['per_device_bytes']):.2f} | "
                f"{r['hlo_flops_per_device']/1e9:.1f} | "
                f"{r['collectives']['total_bytes_per_device']/1e6:.1f} | "
                f"{colls} | {r['compile_s']} |")
        else:
            reason = r.get("reason", r.get("error", ""))[:60]
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"{r['status']} | — | — | — | {reason} | — |")
    out.append("")
    out.append("*scan-form artifact: while-loop bodies counted once "
               "(see section Roofline for composed full-depth numbers).")
    return "\n".join(out)


def roofline_table(tag: str = "baseline") -> str:
    recs = [r for r in _load("roofline") if r.get("tag") == tag]
    out = ["| arch | shape | compute s | memory s (adj) | collective s | "
           "dominant | roofline frac | MODEL/HLO flops | one-line lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    levers = {
        ("memory", "train"): "cut activation materializations (fuse QKV, "
                             "bf16 norm internals, bigger attn chunks)",
        ("memory", "decode"): "quantize the KV cache (int8) and fold "
                              "valid-len masking into fewer passes",
        ("memory", "prefill"): "larger attention chunks; bf16 intermediates",
        ("collective", "train"): "shard activations 2D / reduce-scatter "
                                 "instead of all-reduce; overlap with compute",
        ("collective", "decode"): "keep decode TP-local (replicate small "
                                  "caches) to remove per-step all-gathers",
        ("compute", "train"): "drop remat recompute on cheap layers; "
                              "herded perforation where error budget allows",
        ("compute", "decode"): "TAF layer skipping (the paper's technique)",
        ("compute", "prefill"): "herded KV-block perforation",
    }
    shapes_kind = {"train_4k": "train", "prefill_32k": "prefill",
                   "decode_32k": "decode", "long_500k": "decode"}
    for r in recs:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r.get('reason', 'skipped')[:40]} | — | — | — |")
            continue
        lever = levers.get((r["dominant"], shapes_kind[r["shape"]]), "")
        mem = (f"{r['memory_s']:.3g} ({r['memory_adj_s']:.3g})"
               if "memory_adj_s" in r else f"{r['memory_s']:.3g}")
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{mem} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['roofline_fraction']:.3f} | "
            f"{r['useful_flops_ratio']:.2f} | {lever} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all")
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    if args.section in ("all", "dryrun"):
        print("### Dry-run matrix\n")
        print(dryrun_table())
        print()
    if args.section in ("all", "roofline"):
        print(f"### Roofline ({args.tag})\n")
        print(roofline_table(args.tag))


if __name__ == "__main__":
    main()
