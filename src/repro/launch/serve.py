"""Serving driver: batched prefill + greedy decode loop, with the paper's
decode-time TAF approximation as a flag.

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --smoke \
      --prompt-len 32 --gen 32 --taf "memo(out:3:8:0.05)"

With --taf, each transformer layer carries a TAF state machine across decode
steps (repro.models.lm); the report prints tokens/s and the fraction of
layer-invocations skipped -- the serving analogue of the paper's speedup
metric (on TPU the skip is a genuine lax.cond fast path).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.types import parse_pragma
from repro.launch import steps as steps_mod
from repro.models import build


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--taf", default=None,
                    help='e.g. "memo(out:3:8:0.05)" -- decode-time TAF')
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.taf:
        cfg = dataclasses.replace(cfg, approx_decode=parse_pragma(args.taf))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(0, cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)
    max_len = args.prompt_len + args.gen
    batch = {"tokens": jnp.asarray(prompts), "max_len": max_len}
    if cfg.frontend == "vision_patches":
        batch["patch_embeds"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_patch_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.frontend == "audio_frames":
        batch["frames"] = jnp.asarray(rng.standard_normal(
            (args.batch, cfg.max_source_positions, cfg.d_model)) * 0.02,
            jnp.float32)

    prefill = jax.jit(steps_mod.make_prefill_step(model, max_len))
    serve = jax.jit(steps_mod.make_serve_step(model))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    out = [tokens]
    approx_hits = 0
    approx_total = 0
    t0 = time.time()
    for t in range(args.gen - 1):
        pos = jnp.int32(args.prompt_len + t)
        tokens, logits, cache = serve(params, cache, tokens, pos)
        if args.taf and "taf" in cache:
            rem = np.asarray(cache["taf"]["remaining"])
            approx_hits += int((rem > 0).sum())
            approx_total += rem.size
        out.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.time() - t0
    gen = np.stack([np.asarray(t) for t in out], axis=1)
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"prefill: {t_prefill:.3f}s  decode: {t_decode:.3f}s "
          f"({tps:.1f} tok/s)")
    if args.taf and approx_total:
        print(f"TAF: {approx_hits}/{approx_total} layer-steps in stable "
              f"regime ({100 * approx_hits / approx_total:.1f}% skipped)")
    print("sample:", gen[0, :16])
    return gen


if __name__ == "__main__":
    main()
