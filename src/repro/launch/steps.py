"""Step functions: train_step (fwd + bwd + AdamW), prefill, serve(decode).

These are the functions the dry-run lowers and the drivers execute.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import Model
from repro.optim import adamw
from repro.optim import schedule as sched


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    schedule_fn: Optional[Callable] = None,
                    schedule_kwargs: Optional[Dict] = None) -> Callable:
    schedule_fn = schedule_fn or sched.constant
    schedule_kwargs = schedule_kwargs or {}

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        lr_scale = schedule_fn(opt_state.step, **schedule_kwargs)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state,
                                               params, lr_scale)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_train_step_accum(model: Model, opt_cfg: adamw.AdamWConfig,
                          accum_steps: int,
                          schedule_fn: Optional[Callable] = None,
                          schedule_kwargs: Optional[Dict] = None) -> Callable:
    """Gradient-accumulated train step: the global batch is split into
    `accum_steps` microbatches scanned sequentially; activation memory drops
    ~accum_steps x (the remedy for train cells whose per-device working set
    exceeds HBM -- EXPERIMENTS.md section Dry-run), and on TPU the per-bucket
    gradient reduction overlaps the next microbatch's compute. Also the
    elastic-scaling knob: `runtime.elastic.accum_steps_for` keeps the global
    batch constant across mesh reshapes."""
    schedule_fn = schedule_fn or sched.constant
    schedule_kwargs = schedule_kwargs or {}

    def train_step(params, opt_state, batch):
        def to_micro(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])

        micro = {k: to_micro(v) for k, v in batch.items()}

        def body(carry, mb):
            g_acc, loss_acc = carry
            (loss, _), grads = jax.value_and_grad(
                lambda p: model.loss(p, mb), has_aux=True)(params)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                g_acc, grads)
            return (g_acc, loss_acc + loss / accum_steps), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), micro)
        lr_scale = schedule_fn(opt_state.step, **schedule_kwargs)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state,
                                               params, lr_scale)
        om["loss"] = loss
        return new_params, new_opt, om

    return train_step


def make_prefill_step(model: Model, max_len: int) -> Callable:
    def prefill_step(params, batch):
        full = dict(batch)
        full["max_len"] = max_len
        return model.prefill(params, full)

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """One-token decode: (params, cache, tokens (B,), pos) ->
    (next_tokens, logits, new_cache)."""

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(params, cache, tokens, pos)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, new_cache

    return serve_step
