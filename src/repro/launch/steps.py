"""Step functions: train_step (fwd + bwd + AdamW), prefill, serve(decode).

These are the functions the dry-run lowers and the drivers execute.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.lm import Model
from repro.optim import adamw
from repro.optim import schedule as sched


def make_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                    schedule_fn: Optional[Callable] = None,
                    schedule_kwargs: Optional[Dict] = None) -> Callable:
    schedule_fn = schedule_fn or sched.constant
    schedule_kwargs = schedule_kwargs or {}

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        lr_scale = schedule_fn(opt_state.step, **schedule_kwargs)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state,
                                               params, lr_scale)
        metrics = dict(metrics)
        metrics.update(om)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_train_step_accum(model: Model, opt_cfg: adamw.AdamWConfig,
                          accum_steps: int,
                          schedule_fn: Optional[Callable] = None,
                          schedule_kwargs: Optional[Dict] = None) -> Callable:
    """Gradient-accumulated train step: the global batch is split into
    `accum_steps` microbatches scanned sequentially; activation memory drops
    ~accum_steps x (the remedy for train cells whose per-device working set
    exceeds HBM -- EXPERIMENTS.md section Dry-run), and on TPU the per-bucket
    gradient reduction overlaps the next microbatch's compute. Also the
    elastic-scaling knob: `runtime.elastic.accum_steps_for` keeps the global
    batch constant across mesh reshapes."""
    schedule_fn = schedule_fn or sched.constant
    schedule_kwargs = schedule_kwargs or {}

    def train_step(params, opt_state, batch):
        def to_micro(x):
            b = x.shape[0]
            assert b % accum_steps == 0, (b, accum_steps)
            return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])

        micro = {k: to_micro(v) for k, v in batch.items()}

        def body(carry, mb):
            g_acc, loss_acc = carry
            (loss, _), grads = jax.value_and_grad(
                lambda p: model.loss(p, mb), has_aux=True)(params)
            g_acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / accum_steps,
                g_acc, grads)
            return (g_acc, loss_acc + loss / accum_steps), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params)
        (grads, loss), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), micro)
        lr_scale = schedule_fn(opt_state.step, **schedule_kwargs)
        new_params, new_opt, om = adamw.update(opt_cfg, grads, opt_state,
                                               params, lr_scale)
        om["loss"] = loss
        return new_params, new_opt, om

    return train_step


def make_prefill_step(model: Model, max_len: int) -> Callable:
    def prefill_step(params, batch):
        full = dict(batch)
        full["max_len"] = max_len
        return model.prefill(params, full)

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """One-token decode: (params, cache, tokens (B,), pos) ->
    (next_tokens, logits, new_cache)."""

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = model.decode_step(params, cache, tokens, pos)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, new_cache

    return serve_step


def make_sharded_serve_step(model: Model, mesh, n_shards: int,
                            batch_size: int) -> Callable:
    """The serve step shard_map'd over the mesh's data axes: request lanes
    are data-parallel, and the decode cache's TAF detector state (see
    `models.lm.shard_taf_state`) carries a leading LOGICAL-shard dim that is
    vmapped inside each device, so:

      * n_shards is decoupled from the device count (any multiple of the
        mesh's data extent): the same engine config runs on 1 device and
        on the CI 8-device mesh with bit-identical outputs -- per-shard
        compute has no cross-shard collectives, and vmap of the per-shard
        step produces the same values regardless of how shards are packed
        onto devices;
      * each shard's TAF threshold is an independent traced knob: the QoS
        plane tightens/loosens individual shards by writing one row of the
        (n_shards, n_layers) threshold leaf -- never a recompile;
      * the TAF stability statistic (a batch mean) is computed over each
        shard's OWN lanes, so one shard's regime change cannot flip
        another shard's skip decisions.

    Call with a cache whose TAF state has been through `shard_taf_state`.
    Signature matches `make_serve_step`: (params, cache, tokens (B,), pos)
    -> (next_tokens, logits, new_cache).
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.runtime import sharding as shardlib

    serve = make_serve_step(model)
    da = shardlib.data_axes(mesh)
    if not da:
        raise ValueError("mesh has no data axis (expected 'data'/'pod')")
    daxis = da if len(da) > 1 else da[0]
    n_data = 1
    for a in da:
        n_data *= int(mesh.shape[a])
    if n_shards % n_data:
        raise ValueError(f"n_shards ({n_shards}) must be a multiple of the "
                         f"mesh's data extent ({n_data})")
    if batch_size % n_shards:
        raise ValueError(f"batch_size ({batch_size}) must divide evenly "
                         f"into {n_shards} shards")
    local_shards = n_shards // n_data
    lanes = batch_size // n_shards
    tok_spec = shardlib.batch_spec(mesh)

    def sharded_step(params, cache, tokens, pos):
        paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(cache)
        kinds = [shardlib.decode_shard_axis(p, l.shape, batch_size)
                 for p, l in paths_leaves]
        cache_specs = shardlib.decode_partition_specs(mesh, cache,
                                                      batch_size)
        # vmap axis per leaf: the shard dim's position (None = broadcast)
        vmap_axes = jax.tree_util.tree_unflatten(
            treedef, [None if k is None else k[1] for k in kinds])

        def local_step(params, cache, tokens, pos):
            # split each local leaf's lane dim (local_shards * lanes) into
            # an explicit shard dim for vmap; detector-state leaves already
            # lead with it
            def split(leaf, kind):
                if kind is None or kind[0] == "state":
                    return leaf
                ax, sh = kind[1], leaf.shape
                return leaf.reshape(sh[:ax] + (local_shards, lanes)
                                    + sh[ax + 1:])

            def merge(leaf, kind):
                if kind is None or kind[0] == "state":
                    return leaf
                ax, sh = kind[1], leaf.shape
                return leaf.reshape(sh[:ax] + (local_shards * lanes,)
                                    + sh[ax + 2:])

            leaves = treedef.flatten_up_to(cache)
            c = jax.tree_util.tree_unflatten(
                treedef, [split(l, k) for l, k in zip(leaves, kinds)])
            step = jax.vmap(serve, in_axes=(None, vmap_axes, 0, None),
                            out_axes=(0, 0, vmap_axes))
            ntok, logits, ncache = step(
                params, c, tokens.reshape(local_shards, lanes), pos)
            nleaves = treedef.flatten_up_to(ncache)
            ncache = jax.tree_util.tree_unflatten(
                treedef, [merge(l, k) for l, k in zip(nleaves, kinds)])
            return (ntok.reshape(local_shards * lanes),
                    logits.reshape(local_shards * lanes, logits.shape[-1]),
                    ncache)

        f = shard_map(local_step, mesh=mesh,
                      in_specs=(P(), cache_specs, tok_spec, P()),
                      out_specs=(tok_spec, tok_spec, cache_specs),
                      check_replication=False)
        return f(params, cache, tokens, pos)

    return sharded_step
