"""Launchers: mesh definition, multi-pod dry-run, roofline, train, serve."""
from . import mesh, specs, steps

__all__ = ["mesh", "specs", "steps"]
