"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation -- the dry-run lowers against
these. Shapes follow the brief: LM shapes are seq_len x global_batch;
decode_* / long_* lower `serve_step` (one new token against a seq_len KV
cache); [audio]/[vlm] get stubbed frontend embeddings.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.lm import Model
from repro.runtime import sharding as shardlib

SDS = jax.ShapeDtypeStruct


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "vision_patches":
        s_text = s - cfg.n_patch_tokens
        return {
            "tokens": SDS((b, s_text), jnp.int32),
            "labels": SDS((b, s_text), jnp.int32),
            "patch_embeds": SDS((b, cfg.n_patch_tokens, cfg.d_model),
                                jnp.bfloat16),
        }
    if cfg.frontend == "audio_frames":
        return {
            "tokens": SDS((b, s), jnp.int32),
            "labels": SDS((b, s), jnp.int32),
            "frames": SDS((b, cfg.max_source_positions, cfg.d_model),
                          jnp.bfloat16),
        }
    return {"tokens": SDS((b, s), jnp.int32),
            "labels": SDS((b, s), jnp.int32)}


def prefill_batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend == "vision_patches":
        out["tokens"] = SDS((b, s - cfg.n_patch_tokens), jnp.int32)
        out["patch_embeds"] = SDS((b, cfg.n_patch_tokens, cfg.d_model),
                                  jnp.bfloat16)
    elif cfg.frontend == "audio_frames":
        out["tokens"] = SDS((b, s), jnp.int32)
        out["frames"] = SDS((b, cfg.max_source_positions, cfg.d_model),
                            jnp.bfloat16)
    else:
        out["tokens"] = SDS((b, s), jnp.int32)
    return out


def decode_specs(model: Model, cfg: ModelConfig,
                 shape: ShapeConfig) -> Tuple[Any, Any]:
    """(cache SDS pytree, tokens SDS) for one serve_step."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: model.init_cache(b, s))
    return cache, SDS((b,), jnp.int32)


def batch_shardings(mesh: Mesh, batch: Dict[str, Any]) -> Dict[str, Any]:
    bspec = shardlib.batch_spec(mesh)

    def one(k, v):
        da = bspec[0]
        if v.shape[0] % shardlib._axis_size(mesh, da) == 0:
            return NamedSharding(mesh, P(da, *([None] * (len(v.shape) - 1))))
        return NamedSharding(mesh, P())  # e.g. batch=1: replicate

    return {k: one(k, v) for k, v in batch.items()}
