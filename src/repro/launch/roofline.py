"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape) on the single-pod mesh (hardware constants
from the shared machine table, `repro.analysis.machine` -- TPU v5e-class
profile by default):

  compute_s    = HLO_FLOPs_per_device / peak_flops      (bf16 peak)
  memory_s     = HLO_bytes_per_device / hbm_bw          (HBM bw)
  collective_s = collective_bytes_per_device / ici_bw   (ICI link bw)

Scan caveat (verified empirically): XLA cost analysis counts a while body
ONCE regardless of trip count. Terms are therefore composed from UNROLLED
small-depth lowerings:

  cost(total) = base + sum_type( n_layers_of_type x marginal_type )

with marginals extracted by differencing two (or three) small-depth
artifacts per architecture family. The full scan artifact is still compiled
by dryrun.py for memory analysis + compile-success.
"""
from __future__ import annotations

import dataclasses
import json
import os

if __name__ == "__main__":  # must precede first jax init (see dryrun.py)
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs import SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig
from repro.launch import specs as specs_mod
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.optim import adamw
from repro.runtime import hlo as hlo_mod
from repro.runtime import sharding as shardlib

from repro.analysis.machine import get_machine

# hardware constants: one source of truth, shared with the analytical cost
# model (repro.analysis.cost) via the named machine-profile table
_MACHINE = get_machine("tpu-v5e")
PEAK_FLOPS = _MACHINE.peak_flops     # bf16 / chip
HBM_BW = _MACHINE.hbm_bw             # bytes/s / chip
ICI_BW = _MACHINE.ici_bw             # bytes/s / link

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "roofline")


@dataclasses.dataclass
class Cost:
    flops: float
    bytes: float
    coll_bytes: float
    adj_bytes: float = 0.0   # bytes minus CPU-artifact convert/copy traffic

    def __sub__(self, o):
        return Cost(self.flops - o.flops, self.bytes - o.bytes,
                    self.coll_bytes - o.coll_bytes,
                    self.adj_bytes - o.adj_bytes)

    def __add__(self, o):
        return Cost(self.flops + o.flops, self.bytes + o.bytes,
                    self.coll_bytes + o.coll_bytes,
                    self.adj_bytes + o.adj_bytes)

    def __mul__(self, k):
        return Cost(self.flops * k, self.bytes * k, self.coll_bytes * k,
                    self.adj_bytes * k)

    __rmul__ = __mul__


def _cost_of(cfg: ModelConfig, shape_name: str, mesh) -> Cost:
    """Lower+compile one (small, UNROLLED) variant; extract per-device cost."""
    shape = SHAPES[shape_name]
    model = build(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if shape.kind != "train":
        # serving deployments cast weights to the compute dtype ONCE;
        # inference artifacts must not pay per-step f32->bf16 converts
        cdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
            cfg.compute_dtype]
        params_sds = jax.tree.map(
            lambda l: (jax.ShapeDtypeStruct(l.shape, cdt)
                       if jnp.issubdtype(l.dtype, jnp.floating) else l),
            params_sds)
    # FSDP is a TRAINING-memory optimization; serving keeps weights
    # TP-resident (weight re-gather per decode step would dwarf the tiny
    # activation traffic -- measured: dsv3 decode collective 0.107->3.4s
    # with ZeRO-3 on, section Perf iteration B5)
    fsdp_now = cfg.fsdp and shape.kind == "train"
    p_sh = shardlib.param_shardings(mesh, params_sds, fsdp=fsdp_now)
    with mesh:
        if shape.kind == "train":
            opt_sds = jax.eval_shape(adamw.init, params_sds)
            o_sh = shardlib.opt_state_shardings(mesh, opt_sds, fsdp=cfg.fsdp)
            batch = specs_mod.train_batch_specs(cfg, shape)
            b_sh = specs_mod.batch_shardings(mesh, batch)
            step = steps_mod.make_train_step(model, adamw.AdamWConfig())
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                              out_shardings=(p_sh, o_sh, None)).lower(
                                  params_sds, opt_sds, batch)
        elif shape.kind == "prefill":
            batch = specs_mod.prefill_batch_specs(cfg, shape)
            b_sh = specs_mod.batch_shardings(mesh, batch)
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_sh = shardlib.cache_shardings(mesh, cache_sds,
                                            shape.global_batch)
            step = steps_mod.make_prefill_step(model, shape.seq_len)
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh),
                              out_shardings=(None, c_sh)).lower(
                                  params_sds, batch)
        else:
            cache_sds, tok_sds = specs_mod.decode_specs(model, cfg, shape)
            c_sh = shardlib.cache_shardings(mesh, cache_sds,
                                            shape.global_batch)
            t_sh = specs_mod.batch_shardings(
                mesh, {"tokens": tok_sds})["tokens"]
            step = steps_mod.make_serve_step(model)
            # donate the cache exactly like the production serve_step: the
            # undonated artifact would count a full cache copy per layer
            lowered = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, None),
                              out_shardings=(t_sh, None, c_sh),
                              donate_argnums=(1,)).lower(
                                  params_sds, cache_sds, tok_sds,
                                  jax.ShapeDtypeStruct((), jnp.int32))
        compiled = lowered.compile()
    cost = compat.cost_analysis(compiled)
    text = compiled.as_text()
    coll = hlo_mod.collective_stats(text)
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    adj = max(raw_bytes - hlo_mod.convert_bytes(text), 0.0)
    return Cost(float(cost.get("flops", 0.0)), raw_bytes,
                float(coll.total_bytes), adj)


def _variant(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, unroll_layers=True, **kw)


def composed_cost(arch: str, shape_name: str, mesh,
                  cfg: Optional[ModelConfig] = None
                  ) -> Tuple[Cost, Dict[str, float]]:
    """Compose full-depth per-device cost from unrolled marginal artifacts."""
    cfg = cfg if cfg is not None else get_config(arch)
    fam = cfg.family
    detail: Dict[str, float] = {}
    if fam == "moe" and cfg.moe.n_dense_layers > 0:
        # three-point solve: cost(d,m) = base + d*D + m*M
        import dataclasses as dc
        a = _cost_of(_variant(cfg, n_layers=2,
                              moe=dc.replace(cfg.moe, n_dense_layers=1)),
                     shape_name, mesh)                       # (1,1)
        b = _cost_of(_variant(cfg, n_layers=3,
                              moe=dc.replace(cfg.moe, n_dense_layers=2)),
                     shape_name, mesh)                       # (2,1)
        c = _cost_of(_variant(cfg, n_layers=3,
                              moe=dc.replace(cfg.moe, n_dense_layers=1)),
                     shape_name, mesh)                       # (1,2)
        d_marg = b - a
        m_marg = c - a
        base = a - d_marg - m_marg
        nd = cfg.moe.n_dense_layers
        nm = cfg.n_layers - nd
        total = base + nd * d_marg + nm * m_marg
        detail = {"dense_marginal_flops": d_marg.flops,
                  "moe_marginal_flops": m_marg.flops, "n_dense": nd,
                  "n_moe": nm}
    elif fam == "hybrid":
        period = cfg.hybrid.attn_period
        a = _cost_of(_variant(cfg, n_layers=period), shape_name, mesh)
        b = _cost_of(_variant(cfg, n_layers=2 * period), shape_name, mesh)
        c = _cost_of(_variant(cfg, n_layers=period + 1), shape_name, mesh)
        g_marg = b - a          # one (5 mamba + shared attn) group
        t_marg = c - a          # one tail mamba layer
        base = a - g_marg
        n_groups = cfg.n_layers // period
        tail = cfg.n_layers - n_groups * period
        total = base + n_groups * g_marg + tail * t_marg
        detail = {"group_marginal_flops": g_marg.flops,
                  "mamba_marginal_flops": t_marg.flops,
                  "n_groups": n_groups, "tail": tail}
    else:
        a = _cost_of(_variant(cfg, n_layers=1), shape_name, mesh)
        b = _cost_of(_variant(cfg, n_layers=2), shape_name, mesh)
        marg = b - a
        base = a - marg
        total = base + cfg.n_layers * marg
        detail = {"layer_marginal_flops": marg.flops,
                  "n_layers": cfg.n_layers}
    return total, detail


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """MODEL_FLOPS: 6*N*D train (fwd+bwd), 2*N*D forward-only (N = active)."""
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens


def analyze(arch: str, shape_name: str,
            cfg: Optional[ModelConfig] = None,
            tag: str = "baseline") -> Dict:
    """Full roofline record for one cell (single-pod mesh)."""
    base_cfg = cfg if cfg is not None else get_config(arch)
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(base_cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": reason, "tag": tag}
    mesh = make_production_mesh(multi_pod=False)
    chips = 256
    total, detail = composed_cost(arch, shape_name, mesh, cfg=base_cfg)
    compute_s = total.flops / PEAK_FLOPS
    memory_s = total.bytes / HBM_BW
    memory_adj_s = max(total.adj_bytes, 0.0) / HBM_BW
    coll_s = max(total.coll_bytes, 0.0) / ICI_BW
    # dominant/fraction use the TPU-faithful adjusted memory term; the raw
    # term is reported alongside (see runtime/hlo.convert_bytes)
    dominant = max((("compute", compute_s), ("memory", memory_adj_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    mf = model_flops(base_cfg, shape_name)
    hlo_total_flops = total.flops * chips
    rec = {
        "arch": arch, "shape": shape_name, "tag": tag, "status": "ok",
        "chips": chips,
        "flops_per_device": total.flops,
        "bytes_per_device": total.bytes,
        "coll_bytes_per_device": total.coll_bytes,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "memory_adj_s": memory_adj_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "bound_s": max(compute_s, memory_adj_s, coll_s),
        "model_flops": mf,
        "useful_flops_ratio": mf / max(hlo_total_flops, 1.0),
        "roofline_fraction": compute_s / max(compute_s, memory_adj_s,
                                             coll_s),
        "detail": detail,
    }
    return rec


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", default="baseline")
    args = ap.parse_args()
    os.makedirs(RESULTS_DIR, exist_ok=True)
    rec = analyze(args.arch, args.shape, tag=args.tag)
    out = os.path.join(RESULTS_DIR,
                       f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(out, "w") as f:
        json.dump(rec, f, indent=1)
    print(json.dumps(rec, indent=1))


if __name__ == "__main__":
    main()
