"""HLO inspection CLI -- the profiling tool behind the section-Perf iterations.

Lowers one (arch x shape) cell on the single-pod mesh and prints:
  * per-op-kind output-byte histogram (ENTRY computation = HBM-relevant),
  * the largest collectives with their op_name provenance,
  * cost_analysis totals.

  PYTHONPATH=src python -m repro.launch.inspect_hlo --arch qwen3-1.7b \
      --shape decode_32k [--layers 2] [--top 12]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import collections
import dataclasses
import re

import jax
import jax.numpy as jnp

_DT = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "pred": 1, "s8": 1,
       "f16": 2, "s64": 8, "u8": 1}


def op_histogram(entry_text: str):
    agg = collections.Counter()
    for line in entry_text.splitlines():
        m = re.search(r"=\s+([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
                      r"([a-z0-9\-\.]+)\(", line)
        if not m:
            continue
        dt, dims, op = m.groups()
        if dt not in _DT:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        agg[op] += n * _DT[dt]
    return agg


def largest_collectives(text: str, top: int):
    from repro.runtime import hlo as hlo_mod
    rows = []
    for line in text.splitlines():
        m = re.search(r"=\s+(\(?[a-z0-9][^=\n]*?)\s+(all-reduce|all-gather|"
                      r"reduce-scatter|all-to-all|collective-permute)\(",
                      line)
        if m and "-done" not in line:
            b = hlo_mod._shape_bytes(m.group(1))
            meta = re.search(r'op_name="([^"]*)"', line)
            rows.append((b, m.group(2),
                         meta.group(1)[-72:] if meta else ""))
    rows.sort(reverse=True)
    return rows[:top]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--layers", type=int, default=2,
                    help="unrolled depth for the artifact")
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    from repro import compat
    from repro.configs import SHAPES, get_config
    from repro.launch import specs as specs_mod
    from repro.launch import steps as steps_mod
    from repro.launch.mesh import make_production_mesh
    from repro.models import build
    from repro.optim import adamw
    from repro.runtime import hlo as hlo_mod
    from repro.runtime import sharding as shardlib

    mesh = make_production_mesh(multi_pod=False)
    cfg0 = get_config(args.arch)
    kw = {"unroll_layers": True, "n_layers": args.layers}
    if cfg0.moe and cfg0.moe.n_dense_layers > 0:
        kw["moe"] = dataclasses.replace(cfg0.moe, n_dense_layers=1)
        kw["n_layers"] = max(args.layers, 2)
    if cfg0.hybrid:
        kw["n_layers"] = cfg0.hybrid.attn_period
    cfg = dataclasses.replace(cfg0, **kw)
    shape = SHAPES[args.shape]
    model = build(cfg)
    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if shape.kind != "train":
        cdt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[
            cfg.compute_dtype]
        params_sds = jax.tree.map(
            lambda l: (jax.ShapeDtypeStruct(l.shape, cdt)
                       if jnp.issubdtype(l.dtype, jnp.floating) else l),
            params_sds)
    fsdp_now = cfg.fsdp and shape.kind == "train"
    p_sh = shardlib.param_shardings(mesh, params_sds, fsdp=fsdp_now)
    with mesh:
        if shape.kind == "train":
            opt_sds = jax.eval_shape(adamw.init, params_sds)
            o_sh = shardlib.opt_state_shardings(mesh, opt_sds, fsdp=fsdp_now)
            batch = specs_mod.train_batch_specs(cfg, shape)
            b_sh = specs_mod.batch_shardings(mesh, batch)
            step = steps_mod.make_train_step(model, adamw.AdamWConfig())
            compiled = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                               out_shardings=(p_sh, o_sh, None)).lower(
                                   params_sds, opt_sds, batch).compile()
        elif shape.kind == "prefill":
            batch = specs_mod.prefill_batch_specs(cfg, shape)
            b_sh = specs_mod.batch_shardings(mesh, batch)
            cache_sds = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_sh = shardlib.cache_shardings(mesh, cache_sds,
                                            shape.global_batch)
            step = steps_mod.make_prefill_step(model, shape.seq_len)
            compiled = jax.jit(step, in_shardings=(p_sh, b_sh),
                               out_shardings=(None, c_sh)).lower(
                                   params_sds, batch).compile()
        else:
            cache_sds, tok_sds = specs_mod.decode_specs(model, cfg, shape)
            c_sh = shardlib.cache_shardings(mesh, cache_sds,
                                            shape.global_batch)
            t_sh = specs_mod.batch_shardings(
                mesh, {"tokens": tok_sds})["tokens"]
            step = steps_mod.make_serve_step(model)
            compiled = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, None),
                               out_shardings=(t_sh, None, c_sh),
                               donate_argnums=(1,)).lower(
                                   params_sds, cache_sds, tok_sds,
                                   jax.ShapeDtypeStruct((), jnp.int32)
                               ).compile()

    cost = compat.cost_analysis(compiled)
    text = compiled.as_text()
    print(f"== {args.arch} / {args.shape} (unrolled depth {cfg.n_layers}) ==")
    print(f"flops/dev: {cost.get('flops', 0):.4g}   "
          f"bytes/dev: {cost.get('bytes accessed', 0):.4g}")
    print(f"collectives: {hlo_mod.collective_stats(text).summary()}")
    print("\n-- ENTRY op-kind output bytes --")
    for op, b in op_histogram(hlo_mod.entry_text(text)).most_common(args.top):
        print(f"  {op:26s} {b/2**30:9.3f} GiB")
    print("\n-- largest collectives --")
    for b, op, name in largest_collectives(text, args.top):
        print(f"  {b/2**20:9.1f} MiB {op:18s} {name}")


if __name__ == "__main__":
    main()
