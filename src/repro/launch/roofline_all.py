import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import json
import time
import traceback

from repro.configs import SHAPES, list_archs
from repro.launch import roofline

os.makedirs(roofline.RESULTS_DIR, exist_ok=True)

for arch in list_archs():
    for shape in SHAPES:
        tag = __import__("os").environ.get("ROOFLINE_TAG", "baseline")
        out = os.path.join(roofline.RESULTS_DIR,
                           f"{arch}__{shape}__{tag}.json")
        if os.path.exists(out):
            print(f"[cached ] {arch}/{shape}")
            continue
        t0 = time.time()
        try:
            rec = roofline.analyze(arch, shape, tag=tag)
        except Exception as e:
            rec = {"arch": arch, "shape": shape, "tag": tag,
                   "status": "FAILED", "error": str(e)[-1500:],
                   "traceback": traceback.format_exc()[-3000:]}
        with open(out, "w") as f:
            json.dump(rec, f, indent=1)
        s = rec["status"]
        extra = ""
        if s == "ok":
            extra = (f"dom={rec['dominant']} "
                     f"c={rec['compute_s']:.3g}s m={rec['memory_s']:.3g}s "
                     f"x={rec['collective_s']:.3g}s "
                     f"frac={rec['roofline_fraction']:.3f}")
        elif s == "FAILED":
            extra = rec["error"].splitlines()[-1][:140]
        print(f"[{s:7s}] {arch}/{shape} ({time.time()-t0:.0f}s) {extra}",
              flush=True)
print("roofline baselines done")
