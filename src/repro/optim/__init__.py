from . import adamw, compress, schedule
from .adamw import AdamWConfig, AdamWState

__all__ = ["adamw", "compress", "schedule", "AdamWConfig", "AdamWState"]
