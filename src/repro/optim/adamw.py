"""AdamW over pytrees (no optax dependency) with global-norm clipping.

Moments live in float32 regardless of param dtype (bf16-param models keep
fp32 optimizer state -- the standard mixed-precision recipe).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: PyTree
    v: PyTree


def init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def update(cfg: AdamWConfig, grads: PyTree, state: AdamWState, params: PyTree,
           lr_scale: jnp.ndarray = 1.0) -> Tuple[PyTree, AdamWState, dict]:
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state.m, grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state.v, grads)
    lr = cfg.lr * lr_scale

    def upd(p, m, v):
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + \
            cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(step, new_m, new_v), {"grad_norm": gnorm}
