"""int8 gradient compression with error feedback (EF-SGD style).

Distributed-optimization substrate: gradients are per-tensor-scaled,
quantized to int8 before the data-parallel all-reduce (4x wire reduction on
fp32, 2x on bf16), and the quantization residual is carried in an error-
feedback buffer so the bias vanishes over steps (property-tested: EF makes
quantized-SGD exact in accumulation).

Usage inside the train step (under shard_map or via psum of dequantized
values): q, scale = quantize(g + ef); g_hat = dequantize(q, scale);
new_ef = (g + ef) - g_hat.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class EFState(NamedTuple):
    residual: PyTree  # same structure/shapes as grads, float32


def init_ef(grads_like: PyTree) -> EFState:
    return EFState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def quantize_tensor(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantization."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_tensor(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_grads(grads: PyTree, ef: EFState
                   ) -> Tuple[PyTree, PyTree, EFState]:
    """Returns (quantized pytree of (q, scale), dequantized grads, new EF)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = quantize_tensor(corrected)
        g_hat = dequantize_tensor(q, scale)
        return (q, scale), g_hat, corrected - g_hat

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    qs, g_hats, residuals = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, g_hat, res = one(g, r)
        qs.append(q)
        g_hats.append(g_hat)
        residuals.append(res)
    return (jax.tree.unflatten(treedef, qs),
            jax.tree.unflatten(treedef, g_hats),
            EFState(jax.tree.unflatten(treedef, residuals)))
