"""Offline calibration of the decode workload, as a harness ApproxApp.

The QoS policy ladder needs an offline Pareto DB for the workload the
serving path actually runs: decode-time TAF at various RSD thresholds.
`make_decode_app` wraps a short, seeded greedy generation as an
`ApproxApp`, so the calibration IS a `harness.sweep` -- resumable, keyed
by workload fingerprint, and consumable by `QosPolicy.from_db` exactly
like any other sweep database.

Because the model's decode threshold is a traced cache entry (see
models/lm.py `_taf_init_cache`), every threshold in the grid runs through
the SAME compiled prefill/decode pair -- a whole calibration sweep costs
one compile.

QoI, per `metric`:

  "mape" -- the stacked per-step logits (the paper's relative output
            error). Beware: logits cross zero, so relative error is
            heavy-tailed -- fine for ranking a ladder, rough as an online
            bound;
  "mcr"  -- the decoded token ids (paper Eq. 2): the trajectory token-
            mismatch rate, bounded [0, 1] and the statistic a serving
            deployment actually contracts on. The online canary compares
            the same QoI (`QosEngine.observe_decode` argmaxes for mcr).

`approx_fraction`: skipped layer-steps / total layer-steps;
`flop_fraction = 1 - approx_fraction` (decode cost is layer compute to
first order), so `modeled_speedup` is the structural bound the Pareto
front ranks when wall times are noisy.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.harness import AppResult, ApproxApp
from repro.core.types import ApproxSpec, Level, TAFParams, Technique
from repro.launch import steps as steps_mod
from repro.obs.timing import measure


def default_decode_cfg(arch: str = "qwen3-1.7b", *, history_size: int = 2,
                       prediction_size: int = 4,
                       rsd_threshold: float = 0.5):
    """A smoke config with decode-time TAF enabled (float32 so canary
    parity and calibration errors are deterministic)."""
    from repro.configs import get_smoke_config
    return dataclasses.replace(
        get_smoke_config(arch), remat=False, compute_dtype="float32",
        approx_decode=ApproxSpec(
            Technique.TAF, Level.BLOCK,
            taf=TAFParams(history_size=history_size,
                          prediction_size=prediction_size,
                          rsd_threshold=rsd_threshold)))


def threshold_grid(cfg, thresholds: Sequence[float]) -> List[ApproxSpec]:
    """TAF specs sharing the config's structural params (history/prediction
    size shape the decode cache and MUST match) across `thresholds`."""
    t = cfg.approx_decode.taf
    return [ApproxSpec(Technique.TAF, Level.BLOCK,
                       taf=TAFParams(t.history_size, t.prediction_size,
                                     float(th)))
            for th in thresholds]


def set_decode_threshold(cache, value):
    """Return `cache` with the decode-TAF threshold knob set to `value`
    (0.0 = precise: RSD < 0 never holds). A hard precise fallback also
    cancels in-flight predictions, otherwise up to prediction_size more
    approximated layer-steps would run after the knob move.

    `value` may be a scalar (every layer -- and, on a sharded cache, every
    shard -- gets the same knob) or a length-n_shards sequence for a cache
    whose TAF state has been through `models.lm.shard_taf_state` (leading
    shard dim): each shard gets its own threshold, and only shards set
    precise have their in-flight predictions cancelled. Either way this is
    a pure data write into traced leaves -- never a recompile."""
    taf = dict(cache["taf"])
    th = taf["threshold"]
    if np.ndim(value) == 0:
        taf["threshold"] = jnp.full_like(th, value)
        if float(value) == 0.0:
            taf["remaining"] = jnp.zeros_like(taf["remaining"])
        return dict(cache, taf=taf)
    vals = jnp.asarray(value, th.dtype)
    if th.ndim < 2 or vals.shape != (th.shape[0],):
        raise ValueError(
            f"per-shard thresholds need a sharded TAF cache: got "
            f"{vals.shape[0] if vals.ndim else '?'} values for threshold "
            f"leaf of shape {th.shape} (run models.lm.shard_taf_state "
            f"first)")
    shape = (vals.shape[0],) + (1,) * (th.ndim - 1)
    taf["threshold"] = jnp.broadcast_to(vals.reshape(shape), th.shape)
    rem = taf["remaining"]
    precise = (vals == 0.0).reshape((vals.shape[0],) + (1,) * (rem.ndim - 1))
    taf["remaining"] = jnp.where(precise, 0, rem)
    return dict(cache, taf=taf)


def decode_cost_model(cfg=None, *, batch: int = 2, gen: int = 16,
                      machine=None):
    """An `analysis.cost.AppCostModel` for the decode workload, built from
    the config's shape constants alone (no tracing, no model build).

    Per layer-step the decode does ~12*d_model^2 FLOPs per sequence
    (attention projections + MLP, weights-resident), and one TAF decision
    gates each layer-step. The per-site error amplification is
    `sqrt(gen)`: an approximated layer-step feeds subsequent steps
    through the KV cache, but per-step residuals are independently
    signed, so the first-order accumulation is a random walk, not the
    worst-case linear stack (which would reject every rung the measured
    ladders accept).

    `machine` accepts any `analysis.machine` name, including
    ``"measured"``: `get_machine` then calibrates a roofline profile on
    the backend actually running (matmul FLOP/s, copy bandwidth, dispatch
    floor) so prescreens and QoS ladder checks stop resting on catalog
    constants when real hardware numbers are a micro-benchmark away.
    """
    import math

    from repro.analysis.cost import AppCostModel, CostVector, Site
    from repro.analysis.machine import get_machine

    cfg = cfg if cfg is not None else default_decode_cfg()
    d = int(getattr(cfg, "d_model", 64))
    n_layers = int(getattr(cfg, "n_layers", 2))
    flops_per_step = 12.0 * d * d * batch
    weight_bytes = 12.0 * d * d * 4.0
    region = CostVector(flops_per_step, weight_bytes)
    invocations = float(n_layers * gen)
    site = Site(region=region, invocations=invocations,
                in_dim=d, amplification=math.sqrt(gen))
    return AppCostModel(
        name="taf_decode",
        total=region * invocations,
        sites={Technique.TAF: site},
        machine=get_machine(machine),
        dispatches=float(gen))


def prescreen_thresholds(cfg, thresholds: Sequence[float], *,
                         batch: int = 2, gen: int = 16, machine=None,
                         min_speedup: float = 1.0,
                         max_error: float = None) -> List[ApproxSpec]:
    """Cost-model pre-screen for a calibration sweep: the threshold grid
    with statically hopeless rungs removed (predicted speedup below
    `min_speedup`, or predicted error bound over `max_error`), so
    `harness.sweep(make_decode_app(cfg), ...)` measures only plausible
    candidates. The kept/dropped count is logged by the shared
    `analysis.cost.filter_specs` path. Pass ``machine="measured"`` to
    prescreen against a profile calibrated on the running backend instead
    of the static catalog."""
    from repro.analysis.cost import filter_specs

    model = decode_cost_model(cfg, batch=batch, gen=gen, machine=machine)
    kept, _ = filter_specs(model, threshold_grid(cfg, thresholds),
                           min_speedup=min_speedup, max_error=max_error,
                           context="qos.calibrate")
    return kept


def make_decode_app(cfg=None, *, batch: int = 2, prompt_len: int = 8,
                    gen: int = 16, seed: int = 0,
                    metric: str = "mape") -> ApproxApp:
    """The decode workload as an ApproxApp: run(spec) greedily generates
    `gen` tokens under spec's TAF threshold and returns the stacked logits.

    Specs must be NONE (precise) or TAF with the config's structural
    params; anything else raises (this app calibrates the decode knob, not
    the full technique space).
    """
    from repro.models import build
    if metric not in ("mape", "mcr"):
        raise ValueError(f"metric must be 'mape' or 'mcr', got {metric!r}")
    cfg = cfg if cfg is not None else default_decode_cfg()
    taf_cfg = cfg.approx_decode.taf
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed)
    prompts = jnp.asarray(
        rng.randint(0, cfg.vocab_size, (batch, prompt_len)), jnp.int32)
    prefill = jax.jit(steps_mod.make_prefill_step(model, prompt_len + gen))
    serve = jax.jit(steps_mod.make_serve_step(model))

    def _threshold(spec: ApproxSpec) -> float:
        if spec.technique == Technique.NONE:
            return 0.0
        if spec.technique != Technique.TAF:
            raise ValueError(
                f"decode calibration sweeps TAF thresholds; got {spec}")
        t = spec.taf
        if (t.history_size, t.prediction_size) != (taf_cfg.history_size,
                                                   taf_cfg.prediction_size):
            raise ValueError(
                "history/prediction size are structural (they shape the "
                f"decode cache): spec has ({t.history_size}, "
                f"{t.prediction_size}), config has "
                f"({taf_cfg.history_size}, {taf_cfg.prediction_size})")
        return float(t.rsd_threshold)

    warmed = []

    def run(spec: ApproxSpec) -> AppResult:
        th = _threshold(spec)
        logits, cache = prefill(params, {"tokens": prompts})
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        if not warmed:
            # compile the shared serve step OUTSIDE the timed loop (the
            # exact baseline runs first and would otherwise absorb it)
            jax.block_until_ready(
                serve(params, cache, tokens, jnp.int32(prompt_len))[0])
            warmed.append(True)
        cache = set_decode_threshold(cache, th)
        jax.block_until_ready(tokens)
        outs = []
        state = {"tokens": tokens, "cache": cache, "skipped": 0, "total": 0}

        def decode_loop():
            toks, c = state["tokens"], state["cache"]
            for t in range(gen):
                toks, logits, c = serve(params, c, toks,
                                        jnp.int32(prompt_len + t))
                outs.append(logits)
                rem = np.asarray(c["taf"]["remaining"])
                state["skipped"] += int((rem > 0).sum())
                state["total"] += rem.size
            state["tokens"], state["cache"] = toks, c
            # the per-step np.asarray above already synced every device
            # step, so returning host ints keeps measure()'s trailing
            # block_until_ready a no-op
            return state["total"]

        # timed via the shared helper, but NOT its warmup/median loop:
        # the serve step is pre-warmed above and the wall must stamp
        # BEFORE QoI host assembly -- np.stack/argmax add a constant host
        # term that would compress every speedup toward 1 (fast rungs
        # measured <= 1x get pruned from the policy ladder).
        wall = measure(decode_loop, warmup=0, repeats=1,
                       span="calibrate.decode").seconds
        skipped, total = state["skipped"], state["total"]
        qoi = np.stack([np.asarray(o) for o in outs], axis=0)
        if metric == "mcr":
            qoi = np.argmax(qoi, axis=-1)
        frac = skipped / max(total, 1)
        return AppResult(qoi=qoi, wall_time_s=wall, approx_fraction=frac,
                         flop_fraction=max(1.0 - frac, 1e-3),
                         extra={"skipped_layer_steps": skipped,
                                "layer_steps": total})

    return ApproxApp(
        name="taf_decode", run=run, error_metric=metric,
        workload=dict(arch=getattr(cfg, "name", ""), metric=metric,
                      batch=batch, prompt_len=prompt_len, gen=gen, seed=seed,
                      hSize=taf_cfg.history_size,
                      pSize=taf_cfg.prediction_size))
