"""Offline Pareto DB -> online approximation policy.

The harness establishes the paper's quality bound OFFLINE: `harness.sweep`
measures error after the fact and `pareto.pareto_front` extracts the
error/speedup trade-off curve. This module turns that curve into something
the serving path can act on: a **policy ladder** -- the front ordered from
precise to aggressive -- plus the `best_speedup_under_error`-style selection
that maps a quality target (max error under a metric, per request class) to
a concrete `ApproxSpec` and substrate choice.

Ladder invariants (what the controller relies on):

  * rung 0 is ALWAYS the precise spec (`ApproxSpec()`), error 0, speedup 1 --
    the hard-fallback anchor;
  * rungs ascend in offline error and (being a Pareto front) ascend in
    speedup, so "one rung toward 0" is strictly quality-improving and "one
    rung away" is strictly performance-improving;
  * every rung is serializable (the spec dict schema of `harness.Record`),
    so a chosen policy can be shipped, diffed, and reloaded.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Sequence, Union

from repro.core import pareto as pareto_mod
from repro.core.harness import (ERROR_METRICS, load_db, spec_from_dict,
                                spec_hash, workload_hash)
from repro.core.types import ApproxSpec, Technique

_PRECISE_SPEC = {"technique": "none", "level": "element"}


def _get(r, field, default=None):
    if isinstance(r, dict):
        return r.get(field, default)
    return getattr(r, field, default)


@dataclasses.dataclass(frozen=True)
class QosTarget:
    """A per-request-class quality contract: keep `metric` error below
    `max_error` (strict, matching `best_speedup_under_error`)."""

    max_error: float
    metric: str = "mape"
    request_class: str = "default"

    def __post_init__(self):
        if self.max_error <= 0:
            raise ValueError(
                "max_error must be > 0: the violation test is est >= "
                "max_error, so a 0 bound flags even bit-exact precise "
                "canaries (error 0.0) as violations -- serve without a "
                "QoS engine to run always-precise")
        if self.metric not in ERROR_METRICS:
            raise ValueError(
                f"unknown metric {self.metric!r}; expected one of "
                f"{sorted(ERROR_METRICS)}")

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class PolicyEntry:
    """One ladder rung: a spec and its offline-measured coordinates."""

    spec: Dict                   # harness spec-dict schema
    error: float                 # offline error under the policy's metric
    speedup: float               # measured wall-time speedup
    modeled_speedup: float       # structural (FLOP-bound) speedup
    spec_hash: str = ""

    def __post_init__(self):
        if not self.spec_hash:
            object.__setattr__(self, "spec_hash", spec_hash(self.spec))

    @property
    def precise(self) -> bool:
        return self.spec.get("technique", "none") == "none"

    def to_spec(self) -> ApproxSpec:
        return spec_from_dict(self.spec)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class PolicyChoice:
    """A serialized selection: what `choose` picked for a target. This is
    the artifact a deployment ships (the spec the serving path will run,
    where it runs, and the contract it was picked under)."""

    entry: PolicyEntry
    index: int                   # ladder rung
    substrate: Optional[str]
    target: QosTarget

    def to_json(self) -> Dict:
        return {"entry": self.entry.to_json(), "index": self.index,
                "substrate": self.substrate, "target": self.target.to_json()}


class QosPolicy:
    """The ladder + selection logic. Build from records (`from_records`) or
    a harness DB (`from_db`); serialize with `save`/`load`."""

    def __init__(self, entries: Sequence[PolicyEntry], *, metric: str = "mape",
                 app: str = "", substrate: Optional[str] = None,
                 use_modeled: bool = False):
        if metric not in ERROR_METRICS:
            raise ValueError(f"unknown metric {metric!r}")
        self.metric = metric
        self.app = app
        self.substrate = substrate
        self.use_modeled = use_modeled
        self.entries: List[PolicyEntry] = self._ladder(entries)
        # rung index -> ApproxSpec, parsed once: spec_at sits in the
        # serving tick's hot path (every lane, every tick)
        self._specs: List[ApproxSpec] = [e.to_spec() for e in self.entries]

    def _ladder(self, entries: Sequence[PolicyEntry]) -> List[PolicyEntry]:
        """Normalize to the ladder invariants: rung 0 precise, the rest the
        non-dominated subset ascending in error, no duplicate spec hashes,
        nothing the precise rung dominates (paying error for < 1x speedup
        is never a rung). Applied on EVERY construction path -- including
        direct `QosPolicy(entries)` and `load` of a hand-edited file -- so
        the controller's "one rung away is strictly better on one axis"
        assumption cannot be violated by a merged or stale policy file."""
        precise = PolicyEntry(spec=dict(_PRECISE_SPEC), error=0.0,
                              speedup=1.0, modeled_speedup=1.0)
        cands = [e for e in entries
                 if not e.precise and self._perf(e) > 1.0]
        front = pareto_mod.pareto_front(cands, use_modeled=self.use_modeled)
        rest = sorted(front, key=lambda e: (e.error, self._perf(e)))
        out, seen = [precise], {precise.spec_hash}
        for e in rest:
            if e.spec_hash not in seen:
                seen.add(e.spec_hash)
                out.append(e)
        return out

    def __len__(self) -> int:
        return len(self.entries)

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------

    @classmethod
    def from_records(cls, records: Sequence, *, metric: str = "mape",
                     app: str = "", substrate: Optional[str] = None,
                     use_modeled: bool = False) -> "QosPolicy":
        """Ladder = the Pareto front of `records` (Record objects or DB
        rows) -- extracted by the `_ladder` normalization every
        construction path runs, so the front is computed exactly once.
        Dominated configurations never become rungs: the controller only
        ever trades error for speedup along the front."""
        entries = [PolicyEntry(
            spec=dict(_get(r, "spec")),
            error=float(_get(r, "error")),
            speedup=float(_get(r, "speedup", 1.0)),
            modeled_speedup=float(_get(r, "modeled_speedup", 1.0)),
        ) for r in records]
        if substrate is None:
            subs = {(_get(r, "workload") or {}).get("substrate")
                    for r in records}
            subs.discard(None)
            substrate = subs.pop() if len(subs) == 1 else None
        if not app:
            apps = {_get(r, "app", "") for r in records}
            app = apps.pop() if len(apps) == 1 else ""
        return cls(entries, metric=metric, app=app, substrate=substrate,
                   use_modeled=use_modeled)

    @classmethod
    def from_db(cls, db_path: str, *, app: Optional[str] = None,
                workload: Optional[Dict] = None, metric: str = "mape",
                substrate: Optional[str] = None,
                use_modeled: bool = False) -> "QosPolicy":
        """Build from a `harness.sweep` database, optionally scoped to one
        app name and one workload fingerprint (so a shared DB holding many
        apps / problem sizes yields the right ladder)."""
        rows = load_db(db_path)
        if app is not None:
            rows = [r for r in rows if r.get("app") == app]
        if workload is not None:
            wkey = workload_hash(workload)
            rows = [r for r in rows
                    if workload_hash(r.get("workload", {})) == wkey]
        if not rows:
            raise ValueError(
                f"no rows in {db_path!r} match app={app!r} "
                f"workload={workload!r}")
        return cls.from_records(rows, metric=metric, app=app or "",
                                substrate=substrate, use_modeled=use_modeled)

    # ------------------------------------------------------------------
    # selection
    # ------------------------------------------------------------------

    def _perf(self, e: PolicyEntry) -> float:
        return e.modeled_speedup if self.use_modeled else e.speedup

    def select(self, target: Union[QosTarget, float]) -> int:
        """Rung index of the fastest entry whose offline error is strictly
        under the target (`best_speedup_under_error` semantics). Rung 0
        (precise) always qualifies, so selection never fails."""
        if not isinstance(target, QosTarget):
            target = QosTarget(max_error=float(target), metric=self.metric)
        if target.metric != self.metric:
            raise ValueError(
                f"target metric {target.metric!r} does not match the "
                f"policy's offline metric {self.metric!r}")
        ok = [i for i, e in enumerate(self.entries)
              if e.error < target.max_error or i == 0]
        return max(ok, key=lambda i: (self._perf(self.entries[i]), i))

    def choose(self, target: Union[QosTarget, float]) -> PolicyChoice:
        """`select`, packaged with the substrate and contract -- the
        serializable deployment artifact."""
        if not isinstance(target, QosTarget):
            target = QosTarget(max_error=float(target), metric=self.metric)
        i = self.select(target)
        return PolicyChoice(entry=self.entries[i], index=i,
                            substrate=self.substrate, target=target)

    def spec_at(self, index: int) -> ApproxSpec:
        return self._specs[index]

    # ------------------------------------------------------------------
    # serialization
    # ------------------------------------------------------------------

    def to_json(self) -> Dict:
        return {
            "version": 1,
            "app": self.app,
            "metric": self.metric,
            "substrate": self.substrate,
            "use_modeled": self.use_modeled,
            "entries": [e.to_json() for e in self.entries],
        }

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self.to_json(), f, indent=1)
        os.replace(tmp, path)

    @classmethod
    def load(cls, path: str) -> "QosPolicy":
        with open(path) as f:
            d = json.load(f)
        entries = [PolicyEntry(**e) for e in d["entries"]]
        return cls(entries, metric=d["metric"], app=d.get("app", ""),
                   substrate=d.get("substrate"),
                   use_modeled=d.get("use_modeled", False))


def precise_entry() -> PolicyEntry:
    """The rung-0 spec as a standalone entry (used by tests/benchmarks)."""
    return PolicyEntry(spec=dict(_PRECISE_SPEC), error=0.0, speedup=1.0,
                       modeled_speedup=1.0)


def spec_knob(spec: Optional[ApproxSpec]):
    """The spec's online actuator value -- the traced scalar a controller
    moves without recompiling -- or None for the precise spec. Raises for
    specs with no traced knob (skip-driven perforation): those cannot be
    walked online and should not appear on a serving ladder."""
    from repro.core import batching
    if spec is None or not spec.enabled:
        return None
    return batching.traced_param(spec)


def validate_ladder_knobs(policy: QosPolicy) -> None:
    """Every rung must be actuable online (precise or traced-knob-backed);
    called by QosEngine at construction so a bad ladder fails fast."""
    for i, e in enumerate(policy.entries):
        try:
            spec_knob(e.to_spec())
        except ValueError as err:
            raise ValueError(
                f"policy rung {i} ({e.spec}) has no traced quality knob "
                f"and cannot be actuated online: {err}") from err


def validate_ladder_taf(policy: QosPolicy, taf_params) -> None:
    """Every non-precise rung must be decode-TAF matching `taf_params`'s
    structural fields (history/prediction size). The serving engine's only
    online actuator is the TAF threshold scalar: a rung calibrated under
    different structural params describes a DIFFERENT stability detector,
    so its offline error -- which `select` and the `trust_offline` prior
    gate knob moves on -- misdescribes the running decode step. Called by
    `ServingEngine` at construction; the offline analogue is the check in
    `calibrate.make_decode_app`."""
    for i in range(len(policy)):
        spec = policy.spec_at(i)
        if not spec.enabled:
            continue
        if spec.technique != Technique.TAF or spec.taf is None:
            raise ValueError(
                f"policy rung {i} ({spec.technique.value}) is not "
                "decode-TAF: the serving engine's only online actuator "
                "is the TAF threshold")
        if (spec.taf.history_size, spec.taf.prediction_size) != \
                (taf_params.history_size, taf_params.prediction_size):
            raise ValueError(
                f"policy rung {i} was calibrated with structural TAF "
                f"params ({spec.taf.history_size}, "
                f"{spec.taf.prediction_size}) but the model runs "
                f"({taf_params.history_size}, "
                f"{taf_params.prediction_size}): its offline error does "
                "not describe this decode step")
