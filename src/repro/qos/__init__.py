"""QoS control plane: online quality-guarded approximation (docs/qos.md).

The offline harness proves "speedup with bounded quality loss" after the
fact; this subsystem enforces the bound at run time by closing the loop:

  policy.py     -- offline Pareto DB -> policy ladder (precise ... most
                   aggressive) + best-speedup-under-error selection per
                   quality target and request class;
  monitor.py    -- online error estimation: deterministic canary sampling
                   against the precise oracle, scored with the SAME
                   harness.mape/mcr, RSD drift over a sliding window;
  controller.py -- the feedback loop: tighten under pressure, loosen under
                   steady headroom, hard precise fallback on violation;
  engine.py     -- QosEngine, the serving-side bundle (per-request-class
                   controllers, per-tick lane grouping and actuation);
  calibrate.py  -- the decode workload as a harness ApproxApp, so policy
                   DBs come from ordinary resumable sweeps.
"""
from .calibrate import (default_decode_cfg, make_decode_app,
                        set_decode_threshold, threshold_grid)
from .controller import ControllerConfig, QosController, TrajectoryPoint
from .engine import QosEngine, TickPlan
from .monitor import MonitorStats, QualityMonitor
from .policy import (PolicyChoice, PolicyEntry, QosPolicy, QosTarget,
                     spec_knob, validate_ladder_knobs, validate_ladder_taf)

__all__ = [
    "ControllerConfig", "MonitorStats", "PolicyChoice", "PolicyEntry",
    "QosController", "QosEngine", "QosPolicy", "QosTarget",
    "QualityMonitor", "TickPlan", "TrajectoryPoint", "default_decode_cfg",
    "make_decode_app", "set_decode_threshold", "spec_knob",
    "threshold_grid", "validate_ladder_knobs", "validate_ladder_taf",
]
