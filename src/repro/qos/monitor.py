"""Online quality estimation via canary sampling.

The offline harness knows the error of a configuration because it ran the
exact baseline; the serving path cannot afford that per request. The
standing AC answer (Leon et al. Part I, section "quality control"; Ben
Khadra's survey) is CANARY SAMPLING: re-execute a small, configurable
fraction of requests/steps through the precise path (the host-substrate
oracle) and compare.

`QualityMonitor` owns three things:

  * the deterministic sampling schedule -- fire on every floor-crossing
    of n * fraction, so canaries are evenly spaced, reproducible, and hit
    exactly floor(n * fraction) of the first n steps (no RNG, no seed drift between
    runs: an injected fault replays bit-identically);
  * the per-pair error, computed by the SAME `harness.mape` / `harness.mcr`
    functions the offline sweep used -- monitor estimates therefore match
    offline numbers bit for bit on the sampled pairs (pinned by
    tests/test_qos.py);
  * RSD-style drift statistics over a sliding window (the same
    sigma/|mu| statistic TAF itself uses to detect regime changes), which
    the controller uses to distinguish "steady headroom" (safe to loosen)
    from "drifting" (hold).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque

import numpy as np

from repro.core.harness import ERROR_METRICS
from repro.obs import trace


@dataclasses.dataclass(frozen=True)
class MonitorStats:
    """Snapshot of the monitor's state (all derived from the window except
    the lifetime aggregates)."""

    samples: int                 # lifetime canary pairs observed
    window_size: int             # pairs currently in the sliding window
    estimate: float              # mean error over the window
    drift: float                 # RSD (sigma/|mu|) of the window errors
    last: float                  # most recent canary error
    mean_error: float            # lifetime mean canary error (faults incl.)
    injected: int                # fault-injected samples among `samples`
    genuine_mean_error: float    # lifetime mean over NON-injected canaries


class QualityMonitor:
    """Sliding-window canary quality estimator.

    `sample_fraction` is the canary rate; `window` bounds how much history
    the estimate reacts to (smaller = faster fallback, noisier loosening).
    `phase` offsets the deterministic schedule (two monitors with different
    phases canary different steps).
    """

    def __init__(self, *, metric: str = "mape", sample_fraction: float = 0.1,
                 window: int = 32, phase: float = 0.0, eps: float = 1e-12):
        if metric not in ERROR_METRICS:
            raise ValueError(f"unknown metric {metric!r}; expected one of "
                             f"{sorted(ERROR_METRICS)}")
        if not (0.0 <= sample_fraction <= 1.0):
            raise ValueError("sample_fraction must be in [0, 1]")
        if window < 1:
            raise ValueError("window must be >= 1")
        self.metric = metric
        self.metric_fn = ERROR_METRICS[metric]
        self.sample_fraction = float(sample_fraction)
        self.eps = eps
        self._phase = float(phase) % 1.0
        self._schedule_steps = 0
        self._window: Deque[float] = collections.deque(maxlen=window)
        self.samples = 0
        self._err_sum = 0.0
        self.injected = 0
        self._injected_sum = 0.0

    # ------------------------------------------------------------------
    # canary schedule
    # ------------------------------------------------------------------

    def should_sample(self) -> bool:
        """Advance the schedule one step; True on canary steps.

        A canary fires whenever floor(n * fraction + phase) increments --
        evenly spaced and deterministic, and the first n steps contain
        EXACTLY floor(n * fraction + phase) - floor(phase) canaries. The
        product is computed fresh each step (one float rounding) rather
        than by accumulating `fraction` (n roundings): an accumulator
        drifts below the crossing points, e.g. ten additions of 0.1 sum
        to 0.9999999999999999 and the promised 1-in-10 canary never fires.
        """
        n = self._schedule_steps = self._schedule_steps + 1
        f, ph = self.sample_fraction, self._phase
        return int(n * f + ph) > int((n - 1) * f + ph)

    # ------------------------------------------------------------------
    # observation
    # ------------------------------------------------------------------

    def observe(self, exact_qoi, approx_qoi) -> float:
        """Score one canary pair with the offline error metric and fold it
        into the window. Returns the pair's error (bit-identical to
        `harness.mape(exact, approx)` / `harness.mcr(...)`)."""
        err = float(self.metric_fn(np.asarray(exact_qoi),
                                   np.asarray(approx_qoi)))
        self._record(err)
        if trace.enabled():
            trace.event("canary", metric=self.metric, error=err,
                        estimate=self.estimate(),
                        window=len(self._window))
        return err

    def record(self, error: float) -> None:
        """Fold an externally scored GENUINE canary error into the window
        (unlike `inject`, not counted as a fault). The sharded engine's
        per-class evidence monitors are fed this way: each canary pair is
        scored ONCE (by the shared monitor's metric) and the resulting
        error fans out to every class exposed to that shard's knob."""
        self._record(float(error))

    def inject(self, error: float) -> None:
        """Fold a pre-computed canary error into the window. The fault-
        injection hook: tests and the QoS benchmark use it to stage a
        deterministic quality spike and assert the controller's response.
        Injected samples are tracked separately so reports can tell genuine
        measured quality from drill faults."""
        self.injected += 1
        self._injected_sum += float(error)
        self._record(float(error))
        trace.event("fault_injected", metric=self.metric,
                    error=float(error))

    def _record(self, error: float) -> None:
        self._window.append(error)
        self.samples += 1
        self._err_sum += error

    # ------------------------------------------------------------------
    # statistics
    # ------------------------------------------------------------------

    @property
    def window(self) -> int:
        """Configured window capacity (the deque's maxlen) -- what a clone
        with the same evidence horizon should be constructed with."""
        return self._window.maxlen

    @property
    def window_size(self) -> int:
        """Canary pairs currently in the window -- the EVIDENCE count for
        the running configuration (unlike `samples`, it drops to zero on
        `reset_window`, so controllers gate moves on it)."""
        return len(self._window)

    def estimate(self) -> float:
        """Mean error over the sliding window (0.0 before any canary)."""
        if not self._window:
            return 0.0
        return float(np.mean(np.asarray(self._window, np.float64)))

    def drift(self) -> float:
        """RSD of the window errors: population sigma / max(|mu|, eps) --
        the same statistic TAF's stability detector uses. High drift means
        the estimate is not trustworthy enough to loosen on."""
        if len(self._window) < 2:
            return 0.0
        w = np.asarray(self._window, np.float64)
        mu = float(np.mean(w))
        sigma = float(np.std(w))
        return sigma / max(abs(mu), self.eps)

    def stats(self) -> MonitorStats:
        genuine = self.samples - self.injected
        return MonitorStats(
            samples=self.samples,
            window_size=len(self._window),
            estimate=self.estimate(),
            drift=self.drift(),
            last=self._window[-1] if self._window else 0.0,
            mean_error=self._err_sum / self.samples if self.samples else 0.0,
            injected=self.injected,
            genuine_mean_error=((self._err_sum - self._injected_sum)
                                / genuine if genuine else 0.0),
        )

    def reset_window(self) -> None:
        """Drop the window (lifetime aggregates survive). Used when the
        actuator moves so far that stale canaries no longer describe the
        running configuration (e.g. the hard precise fallback)."""
        self._window.clear()
