"""The feedback loop: walk the policy ladder against the monitored error.

The controller closes the loop the offline harness leaves open. Its state
is ONE integer -- the current ladder rung -- moved by three rules evaluated
each update, strictest first:

  violation   monitored estimate >= target       -> HARD FALLBACK: jump to
                                                    rung 0 (precise) and pin
                                                    there for `fallback_hold`
                                                    updates;
  pressure    estimate > headroom * target       -> step ONE rung toward
                                                    precise;
  headroom    estimate < backoff * target AND    -> step ONE rung toward
              drift (window RSD) <= drift_limit     aggressive (gated by
                                                    the offline prior; see
                                                    `trust_offline`).

Single-rung moves plus the `hold_ticks` hysteresis keep the actuator from
oscillating; the drift gate keeps it from loosening on a noisy estimate.
Because the ladder is a Pareto front, every tighten is the cheapest
quality-improving move available and every loosen the cheapest
performance-improving one.

Everything is deterministic: the same canary stream produces the same
trajectory (the closed-loop demo in tests/test_qos.py replays an injected
error spike and asserts the exact back-off sequence).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Union

from repro.core.types import ApproxSpec
from repro.obs import trace

from .monitor import QualityMonitor
from .policy import PolicyEntry, QosPolicy, QosTarget


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    """Feedback-loop knobs (fractions are of the target's max_error)."""

    headroom: float = 0.8     # tighten above this fraction of the bound
    backoff: float = 0.5      # loosen below this fraction of the bound
    min_samples: int = 4      # no moves before this many canary pairs
    hold_ticks: int = 4       # min updates between consecutive moves
    fallback_hold: int = 8    # updates pinned precise after a violation
    drift_limit: float = 1.5  # max window RSD at which loosening is allowed
    # With trust_offline (default), loosening never steps onto a rung whose
    # OFFLINE error already violates the target: the sweep DB is a prior,
    # and probing a rung the harness measured as out-of-bound costs real
    # quality before the canary can react. The controller then loosens at
    # most back to the offline `select` choice (recovery after tighten/
    # fallback). trust_offline=False allows exploration past the prior --
    # for workloads whose offline error (e.g. trajectory-level) is known to
    # be pessimistic vs the online estimate (one-step canaries).
    trust_offline: bool = True

    def __post_init__(self):
        if not (0.0 < self.backoff < self.headroom <= 1.0):
            raise ValueError(
                "need 0 < backoff < headroom <= 1 "
                f"(got backoff={self.backoff}, headroom={self.headroom})")


@dataclasses.dataclass(frozen=True)
class TrajectoryPoint:
    """One update's outcome (the knob trajectory the benchmark emits)."""

    step: int
    index: int                # rung AFTER this update
    estimate: float
    drift: float
    event: str                # hold|warmup|tighten|loosen|fallback|cooldown

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


class QosController:
    """One request class's closed loop over a shared policy + monitor."""

    def __init__(self, policy: QosPolicy, monitor: QualityMonitor,
                 target: Union[QosTarget, float],
                 config: ControllerConfig = ControllerConfig()):
        if not isinstance(target, QosTarget):
            target = QosTarget(max_error=float(target), metric=policy.metric)
        if target.metric != monitor.metric:
            raise ValueError(
                f"target metric {target.metric!r} does not match the "
                f"monitor metric {monitor.metric!r}")
        self.policy = policy
        self.monitor = monitor
        self.target = target
        self.config = config
        # start from the OFFLINE choice: the fastest rung whose sweep-time
        # error met the bound -- the controller then corrects online.
        self.index = policy.select(target)
        self.steps = 0
        self._last_move = -config.hold_ticks
        self._cooldown = 0
        self.violations = 0
        self.fallback_ticks = 0
        self.moves = 0
        self.trajectory: List[TrajectoryPoint] = []

    # ------------------------------------------------------------------

    def rebind_monitor(self, monitor: QualityMonitor) -> None:
        """Point this controller's evidence reads -- and, crucially, its
        hard-fallback `reset_window` -- at a different monitor.
        `QosEngine.enable_sharding` gives each request class its own
        evidence monitor this way, so one class's fallback no longer wipes
        the shared window every other class judges its bound against. The
        metric contract is re-checked, same as at construction."""
        if monitor.metric != self.target.metric:
            raise ValueError(
                f"target metric {self.target.metric!r} does not match the "
                f"monitor metric {monitor.metric!r}")
        self.monitor = monitor

    # ------------------------------------------------------------------

    def entry(self) -> PolicyEntry:
        return self.policy.entries[self.index]

    def spec(self) -> ApproxSpec:
        return self.policy.spec_at(self.index)

    @property
    def in_fallback(self) -> bool:
        return self._cooldown > 0

    @property
    def fallback_rate(self) -> float:
        """Fraction of updates spent pinned precise by a violation."""
        return self.fallback_ticks / self.steps if self.steps else 0.0

    # ------------------------------------------------------------------

    def update(self, *, est: Optional[float] = None,
               drift: Optional[float] = None,
               window_size: Optional[int] = None) -> PolicyEntry:
        """One feedback evaluation; returns the (possibly new) rung.

        `est`/`drift`/`window_size` override the monitor reads: the engine
        snapshots them once per tick so that every class's controller
        judges the SAME evidence -- without the snapshot, one controller's
        fallback would reset the shared window and silently swallow a
        concurrent violation of another class's bound."""
        self.steps += 1
        cfg, bound = self.config, self.target.max_error
        if est is None:
            est = self.monitor.estimate()
        if drift is None:
            drift = self.monitor.drift()
        if window_size is None:
            window_size = self.monitor.window_size
        event = "hold"

        # Branch order: a violation preempts everything (the WINDOW
        # ESTIMATE at or over the bound triggers the hard fallback on
        # however little evidence -- it is not made to wait out the
        # min_samples gate; note it is the window mean, so a lone bad
        # canary in a full clean window must be large enough to move the
        # mean over the bound); the cooldown ticks
        # down ahead of the warmup gate so the pinned-precise duration is
        # `fallback_hold` updates as documented (the window reset below
        # empties the window, and a warmup-first order would freeze the
        # cooldown until min_samples fresh canaries arrived). The warmup
        # gate covers the move branches only: after a reset an empty
        # window estimates 0.0, which must read as "no evidence yet",
        # not "perfect quality".
        if est >= bound and window_size > 0:
            event = "fallback"
            self.violations += 1
            self._cooldown = cfg.fallback_hold
            if self.index != 0:
                self.index = 0
                self.moves += 1
                self._last_move = self.steps
            # The actuator just jumped to precise: the window's samples no
            # longer describe the running configuration. Dropping them
            # makes one spike count as ONE violation instead of repeating
            # the fallback until the spike ages out of the window.
            self.monitor.reset_window()
        elif self._cooldown > 0:
            event = "cooldown"
            self._cooldown -= 1
        elif window_size < cfg.min_samples:
            event = "warmup"
        elif est > cfg.headroom * bound:
            if (self.index > 0
                    and self.steps - self._last_move >= cfg.hold_ticks):
                self.index -= 1
                self.moves += 1
                self._last_move = self.steps
                event = "tighten"
        elif est < cfg.backoff * bound and drift <= cfg.drift_limit:
            admissible = (self.index < len(self.policy) - 1 and
                          (not cfg.trust_offline or
                           self.policy.entries[self.index + 1].error < bound))
            if (admissible
                    and self.steps - self._last_move >= cfg.hold_ticks):
                self.index += 1
                self.moves += 1
                self._last_move = self.steps
                event = "loosen"

        if event in ("fallback", "cooldown"):
            self.fallback_ticks += 1
        self.trajectory.append(TrajectoryPoint(
            step=self.steps, index=self.index, estimate=est, drift=drift,
            event=event))
        # decision events with reasons, for the Perfetto timeline; steady
        # "hold" steps stay out of the trace (they carry no decision) but
        # every state change -- including warmup/cooldown transitions --
        # lands with the evidence that drove it
        if event != "hold" and trace.enabled():
            trace.event("qos_decision",
                        request_class=self.target.request_class,
                        reason=event, index=self.index, estimate=est,
                        drift=drift, window=window_size,
                        bound=bound)
        return self.entry()

    # ------------------------------------------------------------------

    def trajectory_json(self) -> List[Dict]:
        return [p.to_json() for p in self.trajectory]

    def summary(self) -> Dict:
        ms = self.monitor.stats()
        return {
            "target": self.target.to_json(),
            "index": self.index,
            "spec": self.entry().spec,
            "updates": self.steps,
            "moves": self.moves,
            "violations": self.violations,
            "fallback_rate": self.fallback_rate,
            "estimate": ms.estimate,
            "mean_error": ms.mean_error,
            "canary_samples": ms.samples,
        }
