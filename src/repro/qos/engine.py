"""QosEngine: the serving-side bundle of policy + monitor + controllers.

One engine serves one continuous-batching loop. It owns:

  * a shared `QualityMonitor` (the decode loop has ONE canary stream --
    the precise re-execution of a sampled tick);
  * one `QosController` per REQUEST CLASS, each walking the shared policy
    ladder under its own error bound (per-request quality targets, the
    ROADMAP's "millions of users" requirement, not per-paper figures);
  * the per-tick actuation plan: live lanes are grouped by their class's
    current knob (`batching.group_lanes`), and -- because the decode loop
    runs ONE shared step per tick -- the engine actuates the STRICTEST live
    rung (min ladder index), which satisfies every live class's bound
    simultaneously. A multi-timeline engine would instead run one decode
    call per knob group; the plan exposes the groups so schedulers can.

The knob itself is a traced scalar (the model's TAF threshold lives in the
decode cache; the Pallas kernels take theirs in scalar memory), so knob
moves never recompile.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import batching
from repro.core.types import ApproxSpec

from .controller import ControllerConfig, QosController
from .monitor import QualityMonitor
from .policy import (QosPolicy, QosTarget, spec_knob, validate_ladder_knobs)

TargetLike = Union[QosTarget, float]


@dataclasses.dataclass(frozen=True)
class TickPlan:
    """What one engine tick should run.

    `index`/`spec`/`knob` describe the chosen (strictest-live) rung; `knob`
    is None for precise. `groups` maps each static-structure key to the
    lane indices + stacked knobs that COULD run as one vmapped call;
    `precise_lanes` are the lanes whose class currently demands rung 0.
    """

    index: int
    spec: ApproxSpec
    knob: Optional[float]
    groups: Dict[Tuple, Tuple[List[int], List[float]]]
    precise_lanes: List[int]

    @property
    def n_groups(self) -> int:
        return len(self.groups) + (1 if self.precise_lanes else 0)


class QosEngine:
    """Quality-of-service control plane for a serving loop.

    targets: one bound (QosTarget or float max_error) or a dict mapping
    request-class names to bounds. A request whose class is missing from
    the dict is served under the "default" class (required when a dict is
    given).
    """

    def __init__(self, policy: QosPolicy,
                 targets: Union[TargetLike, Dict[str, TargetLike]], *,
                 sample_fraction: float = 0.1, window: int = 16,
                 config: ControllerConfig = ControllerConfig(),
                 monitor: Optional[QualityMonitor] = None):
        validate_ladder_knobs(policy)
        self.policy = policy
        self.monitor = monitor or QualityMonitor(
            metric=policy.metric, sample_fraction=sample_fraction,
            window=window)
        if not isinstance(targets, dict):
            targets = {"default": targets}
        if "default" not in targets:
            raise ValueError(
                "targets must include a 'default' request class "
                f"(got classes {sorted(targets)})")
        self.controllers: Dict[str, QosController] = {
            cls: QosController(policy, self.monitor, self._target(cls, t),
                               config)
            for cls, t in targets.items()}
        # per-class canary EXPOSURE: errors observed while the class had
        # live lanes. This is what the class's requests actually got --
        # the global monitor mean mixes phases served under other classes'
        # knobs, so it cannot show a per-class contract held.
        self._exposure: Dict[str, List[float]] = {
            cls: [] for cls in self.controllers}
        self._actuated_index: Optional[int] = None

    def _target(self, cls: str, t: TargetLike) -> QosTarget:
        """Normalize a bound to a QosTarget stamped with its class name
        (so serialized targets in reports name the class they bind)."""
        if not isinstance(t, QosTarget):
            t = QosTarget(max_error=float(t), metric=self.policy.metric)
        return dataclasses.replace(t, request_class=cls)

    # ------------------------------------------------------------------
    # per-class access
    # ------------------------------------------------------------------

    def controller(self, request_class: str = "default") -> QosController:
        return self.controllers.get(request_class,
                                    self.controllers["default"])

    def spec_for(self, request_class: str = "default") -> ApproxSpec:
        return self.controller(request_class).spec()

    # ------------------------------------------------------------------
    # the per-tick loop
    # ------------------------------------------------------------------

    def plan_tick(self, lane_classes: Sequence[str]) -> TickPlan:
        """Actuation plan for one tick given the live lanes' classes.

        Empty `lane_classes` plans the default class (an idle engine keeps
        its default posture)."""
        classes = list(lane_classes) or ["default"]
        specs = [self.spec_for(c) for c in classes]
        groups, precise = batching.group_lanes(specs)
        index = min(self.controller(c).index for c in classes)
        if index != self._actuated_index:
            # knob-regime change (a controller moved, or the live class
            # mix changed the strictest rung): the window's canaries
            # describe the OLD regime -- judging any class's bound against
            # them would fabricate violations (or headroom). Drop them;
            # the min_samples evidence gate holds moves until fresh ones.
            # EXCEPT when the stale window already crosses a live class's
            # bound (e.g. a fault injected since the last update): a
            # violation is never discarded -- the window survives so this
            # tick's update() fires the hard fallback. The asymmetry is
            # deliberate: a stale-evidence fallback costs speed, a
            # discarded violation costs the quality contract.
            if self._actuated_index is not None:
                bound = min(self.controller(c).target.max_error
                            for c in classes)
                if not (self.monitor.window_size > 0
                        and self.monitor.estimate() >= bound):
                    self.monitor.reset_window()
            self._actuated_index = index
        spec = self.policy.spec_at(index)
        return TickPlan(index=index, spec=spec, knob=spec_knob(spec),
                        groups=groups, precise_lanes=precise)

    def should_sample(self) -> bool:
        """Advance the canary schedule (call exactly once per tick)."""
        return self.monitor.should_sample()

    def observe_decode(self, exact_logits, approx_logits,
                       lane_classes: Sequence[str] = ()) -> float:
        """Score one canary tick. For "mape" the QoI is the logits tensor;
        for "mcr" it is the decoded token ids (argmax) -- the serving
        analogues of the offline metrics' QoI choices. `lane_classes` (the
        live lanes' classes) attributes the canary to every class exposed
        to this tick's knob."""
        if self.monitor.metric == "mcr":
            exact_q = np.argmax(np.asarray(exact_logits), axis=-1)
            approx_q = np.argmax(np.asarray(approx_logits), axis=-1)
        else:
            exact_q = np.asarray(exact_logits)
            approx_q = np.asarray(approx_logits)
        err = self.monitor.observe(exact_q, approx_q)
        for cls in {c if c in self.controllers else "default"
                    for c in lane_classes}:
            self._exposure[cls].append(err)
        return err

    def update(self, lane_classes: Optional[Sequence[str]] = None) -> None:
        """One feedback evaluation. With `lane_classes` (the tick's live
        lanes), only the EXPOSED classes' controllers step: canary errors
        are measured under the actuated knob, and judging an absent class's
        bound against another class's phase would log spurious violations.
        `None` (no lane information) updates every controller."""
        if lane_classes is None:
            live = set(self.controllers)
        else:
            live = {c if c in self.controllers else "default"
                    for c in lane_classes}
        # Snapshot the evidence ONCE: a controller's hard fallback resets
        # the shared monitor window, and without the snapshot the classes
        # updating after it would see an empty window -- a concurrent
        # violation of their own bound silently swallowed, and the
        # trajectory dependent on set iteration order (hash-seed salted).
        # sorted() keeps the trajectory append order deterministic too.
        est = self.monitor.estimate()
        drift = self.monitor.drift()
        wsize = self.monitor.window_size
        for cls in sorted(live):
            self.controllers[cls].update(est=est, drift=drift,
                                         window_size=wsize)

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    @property
    def fallback_rate(self) -> float:
        return max((c.fallback_rate for c in self.controllers.values()),
                   default=0.0)

    def summary(self) -> Dict:
        ms = self.monitor.stats()
        return {
            "metric": self.monitor.metric,
            "sample_fraction": self.monitor.sample_fraction,
            "canary_samples": ms.samples,
            "mean_error": ms.mean_error,
            "genuine_mean_error": ms.genuine_mean_error,
            "injected_faults": ms.injected,
            "estimate": ms.estimate,
            "fallback_rate": self.fallback_rate,
            "classes": {cls: dict(
                ctl.summary(),
                exposed_canaries=len(self._exposure[cls]),
                exposed_mean_error=(float(np.mean(self._exposure[cls]))
                                    if self._exposure[cls] else 0.0))
                for cls, ctl in self.controllers.items()},
        }
