"""QosEngine: the serving-side bundle of policy + monitor + controllers.

One engine serves one continuous-batching loop. It owns:

  * a shared `QualityMonitor` (the decode loop has ONE canary stream --
    the precise re-execution of a sampled tick);
  * one `QosController` per REQUEST CLASS, each walking the shared policy
    ladder under its own error bound (per-request quality targets, the
    ROADMAP's "millions of users" requirement, not per-paper figures);
  * the per-tick actuation plan: live lanes are grouped by their class's
    current knob (`batching.group_lanes`), and -- because the decode loop
    runs ONE shared step per tick -- the engine actuates the STRICTEST live
    rung (min ladder index), which satisfies every live class's bound
    simultaneously. A multi-timeline engine would instead run one decode
    call per knob group; the plan exposes the groups so schedulers can.

The knob itself is a traced scalar (the model's TAF threshold lives in the
decode cache; the Pallas kernels take theirs in scalar memory), so knob
moves never recompile.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import batching
from repro.core.types import ApproxSpec
from repro.obs import recorder as obs_recorder

from .controller import ControllerConfig, QosController
from .monitor import QualityMonitor
from .policy import (QosPolicy, QosTarget, spec_knob, validate_ladder_knobs)

TargetLike = Union[QosTarget, float]


@dataclasses.dataclass(frozen=True)
class TickPlan:
    """What one engine tick should run.

    `index`/`spec`/`knob` describe the chosen (strictest-live) rung; `knob`
    is None for precise. `groups` maps each static-structure key to the
    lane indices + stacked knobs that COULD run as one vmapped call;
    `precise_lanes` are the lanes whose class currently demands rung 0.

    Sharded engines (`plan_shards`) additionally fill `shard_indices` /
    `shard_knobs`: each shard's OWN strictest-live rung and its knob value
    (0.0 for precise -- the per-shard threshold vector is written into the
    cache as one traced leaf, so None has no slot there). For those plans
    `index` is the strictest-live-rung reduction ACROSS shards: min over
    shards with live lanes -- commutative and associative, so the reduction
    is independent of shard enumeration order (pinned by property tests).
    """

    index: int
    spec: ApproxSpec
    knob: Optional[float]
    groups: Dict[Tuple, Tuple[List[int], List[float]]]
    precise_lanes: List[int]
    shard_indices: Optional[Tuple[int, ...]] = None
    shard_knobs: Optional[Tuple[float, ...]] = None

    @property
    def sharded(self) -> bool:
        return self.shard_indices is not None

    @property
    def n_groups(self) -> int:
        return len(self.groups) + (1 if self.precise_lanes else 0)


class QosEngine:
    """Quality-of-service control plane for a serving loop.

    targets: one bound (QosTarget or float max_error) or a dict mapping
    request-class names to bounds. A request whose class is missing from
    the dict is served under the "default" class (required when a dict is
    given).
    """

    def __init__(self, policy: QosPolicy,
                 targets: Union[TargetLike, Dict[str, TargetLike]], *,
                 sample_fraction: float = 0.1, window: int = 16,
                 config: ControllerConfig = ControllerConfig(),
                 monitor: Optional[QualityMonitor] = None):
        validate_ladder_knobs(policy)
        self.policy = policy
        self.monitor = monitor or QualityMonitor(
            metric=policy.metric, sample_fraction=sample_fraction,
            window=window)
        if not isinstance(targets, dict):
            targets = {"default": targets}
        if "default" not in targets:
            raise ValueError(
                "targets must include a 'default' request class "
                f"(got classes {sorted(targets)})")
        self.controllers: Dict[str, QosController] = {
            cls: QosController(policy, self.monitor, self._target(cls, t),
                               config)
            for cls, t in targets.items()}
        # per-class canary EXPOSURE: errors observed while the class had
        # live lanes. This is what the class's requests actually got --
        # the global monitor mean mixes phases served under other classes'
        # knobs, so it cannot show a per-class contract held.
        self._exposure: Dict[str, List[float]] = {
            cls: [] for cls in self.controllers}
        self._actuated_index: Optional[int] = None
        # sharded mode (enable_sharding): per-class evidence monitors,
        # per-shard exposure, and the last actuated per-shard rung vector
        self._n_shards: Optional[int] = None
        self.class_monitors: Dict[str, QualityMonitor] = {}
        self._shard_exposure: Dict[int, List[float]] = {}
        self._actuated_shards: Optional[Tuple[int, ...]] = None
        self._last_shard_classes: List[List[str]] = []

    def _target(self, cls: str, t: TargetLike) -> QosTarget:
        """Normalize a bound to a QosTarget stamped with its class name
        (so serialized targets in reports name the class they bind)."""
        if not isinstance(t, QosTarget):
            t = QosTarget(max_error=float(t), metric=self.policy.metric)
        return dataclasses.replace(t, request_class=cls)

    # ------------------------------------------------------------------
    # per-class access
    # ------------------------------------------------------------------

    def controller(self, request_class: str = "default") -> QosController:
        return self.controllers.get(request_class,
                                    self.controllers["default"])

    def spec_for(self, request_class: str = "default") -> ApproxSpec:
        return self.controller(request_class).spec()

    # ------------------------------------------------------------------
    # sharded mode
    # ------------------------------------------------------------------

    @property
    def n_shards(self) -> Optional[int]:
        """Shard count in sharded mode, None in single-lane-group mode."""
        return self._n_shards

    def enable_sharding(self, n_shards: int) -> None:
        """Switch to per-shard actuation (the sharded ServingEngine calls
        this at construction).

        Evidence becomes per CLASS: each controller is rebound to its own
        `QualityMonitor` (same metric/fraction/window as the shared one),
        fed only by canaries from shards where the class had live lanes.
        The shared window would mix errors measured under OTHER shards'
        knobs -- with per-shard rungs those are genuinely different
        configurations, so a shared estimate would fabricate violations
        for a class that never ran the offending rung (and hide real
        ones). The shared monitor keeps the canary SCHEDULE and the
        lifetime/injection accounting, so reports stay comparable with
        the single-shard engine's."""
        if n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {n_shards}")
        if self._n_shards is not None:
            if self._n_shards == int(n_shards):
                return
            raise ValueError(
                f"engine already sharded at {self._n_shards}; cannot "
                f"re-shard to {n_shards} (controller evidence windows "
                f"would be misattributed)")
        self._n_shards = int(n_shards)
        self.class_monitors = {
            cls: QualityMonitor(metric=self.monitor.metric,
                                sample_fraction=self.monitor.sample_fraction,
                                window=self.monitor.window)
            for cls in self.controllers}
        for cls, ctl in self.controllers.items():
            ctl.rebind_monitor(self.class_monitors[cls])
        self._shard_exposure = {s: [] for s in range(self._n_shards)}
        self._last_shard_classes = [[] for _ in range(self._n_shards)]

    def _norm_class(self, cls: str) -> str:
        return cls if cls in self.controllers else "default"

    def plan_shards(self, shard_classes: Sequence[Sequence[str]]) -> TickPlan:
        """Per-shard actuation plan: one entry of `shard_classes` per
        shard, holding that shard's live lanes' classes (empty = idle
        shard, which keeps the default class's posture but does not vote
        in the global reduction).

        Each shard's rung is the strictest among ITS live classes; the
        plan's global `index` is the strictest-live-rung reduction across
        shards (min over shards with live lanes). Per-shard knob-regime
        changes reset the stale evidence of the classes live on that
        shard -- same violation-preserving asymmetry as `plan_tick`: a
        class whose window already crosses its bound keeps it, so this
        tick's update fires the fallback instead of discarding the fault.
        """
        if self._n_shards is None:
            raise ValueError("call enable_sharding() before plan_shards()")
        if len(shard_classes) != self._n_shards:
            raise ValueError(
                f"expected {self._n_shards} shard class lists, got "
                f"{len(shard_classes)}")
        norm = [[self._norm_class(c) for c in sc] for sc in shard_classes]
        per = [min(self.controller(c).index for c in (sc or ["default"]))
               for sc in norm]
        live = [per[s] for s in range(self._n_shards) if norm[s]]
        index = min(live) if live else self.controllers["default"].index
        if self._actuated_shards is not None:
            for s, sc in enumerate(norm):
                if per[s] == self._actuated_shards[s]:
                    continue
                for cls in sorted(set(sc)):
                    mon = self.class_monitors[cls]
                    bound = self.controllers[cls].target.max_error
                    if not (mon.window_size > 0 and mon.estimate() >= bound):
                        mon.reset_window()
        self._actuated_shards = tuple(per)
        self._last_shard_classes = [list(sc) for sc in norm]
        # lane-order grouping: shards are contiguous lane ranges, so the
        # flattened per-lane specs line up with the engine's lane indices
        flat_specs = [self.policy.spec_at(per[s])
                      for s, sc in enumerate(norm) for _ in sc]
        groups, precise = batching.group_lanes(flat_specs)
        spec = self.policy.spec_at(index)
        return TickPlan(
            index=index, spec=spec, knob=spec_knob(spec), groups=groups,
            precise_lanes=precise, shard_indices=tuple(per),
            shard_knobs=tuple(spec_knob(self.policy.spec_at(i)) or 0.0
                              for i in per))

    def observe_shard(self, shard: int, exact_logits, approx_logits,
                      lane_classes: Sequence[str]) -> float:
        """Score one shard's slice of a canary tick. The error feeds three
        places: the shared monitor (lifetime stats + the report estimate),
        the per-class evidence monitors of the classes live on THIS shard
        (each class judges its bound only against canaries measured under
        a knob it was actually exposed to), and the shard's exposure
        record (per-shard canary attribution in `summary()`)."""
        if self._n_shards is None:
            raise ValueError("call enable_sharding() before observe_shard()")
        exact_q, approx_q = self._qoi(exact_logits, approx_logits)
        err = self.monitor.observe(exact_q, approx_q)
        for cls in sorted({self._norm_class(c) for c in lane_classes}):
            self._exposure[cls].append(err)
            self.class_monitors[cls].record(err)
        self._shard_exposure[shard].append(err)
        return err

    def update_shards(self,
                      shard_classes: Sequence[Sequence[str]]) -> None:
        """Per-tick feedback in sharded mode: every class with live lanes
        on ANY shard steps its controller against ITS OWN evidence monitor.
        No cross-class snapshot is needed here -- that dance in `update()`
        guards the SHARED window against one controller's fallback reset;
        per-class monitors cannot interfere with each other."""
        if self._n_shards is None:
            raise ValueError("call enable_sharding() before update_shards()")
        live = ({self._norm_class(c) for sc in shard_classes for c in sc}
                or {"default"})
        for cls in sorted(live):
            mon = self.class_monitors[cls]
            self.controllers[cls].update(est=mon.estimate(),
                                         drift=mon.drift(),
                                         window_size=mon.window_size)
        self._flight_note(sorted(live), shard_rungs=self._actuated_shards)

    def inject(self, error: float, shard: Optional[int] = None) -> None:
        """Stage a deterministic fault. Without `shard`, equivalent to
        `monitor.inject` (the single-engine drill). With `shard` (sharded
        mode), the fault also lands on the evidence monitors of the
        classes live on that shard at the last plan -- the drill models
        one shard's canary stream going bad, so only the classes exposed
        there react (pinned by tests/test_qos_sharded.py)."""
        self.monitor.inject(error)
        if shard is None:
            return
        if self._n_shards is None:
            raise ValueError("per-shard inject needs enable_sharding()")
        classes = set(self._last_shard_classes[shard]) or {"default"}
        for cls in sorted(classes):
            self.class_monitors[cls].inject(error)

    # ------------------------------------------------------------------
    # the per-tick loop
    # ------------------------------------------------------------------

    def plan_tick(self, lane_classes: Sequence[str]) -> TickPlan:
        """Actuation plan for one tick given the live lanes' classes.

        Empty `lane_classes` plans the default class (an idle engine keeps
        its default posture)."""
        classes = list(lane_classes) or ["default"]
        specs = [self.spec_for(c) for c in classes]
        groups, precise = batching.group_lanes(specs)
        index = min(self.controller(c).index for c in classes)
        if index != self._actuated_index:
            # knob-regime change (a controller moved, or the live class
            # mix changed the strictest rung): the window's canaries
            # describe the OLD regime -- judging any class's bound against
            # them would fabricate violations (or headroom). Drop them;
            # the min_samples evidence gate holds moves until fresh ones.
            # EXCEPT when the stale window already crosses a live class's
            # bound (e.g. a fault injected since the last update): a
            # violation is never discarded -- the window survives so this
            # tick's update() fires the hard fallback. The asymmetry is
            # deliberate: a stale-evidence fallback costs speed, a
            # discarded violation costs the quality contract.
            if self._actuated_index is not None:
                bound = min(self.controller(c).target.max_error
                            for c in classes)
                if not (self.monitor.window_size > 0
                        and self.monitor.estimate() >= bound):
                    self.monitor.reset_window()
            self._actuated_index = index
        spec = self.policy.spec_at(index)
        return TickPlan(index=index, spec=spec, knob=spec_knob(spec),
                        groups=groups, precise_lanes=precise)

    def should_sample(self) -> bool:
        """Advance the canary schedule (call exactly once per tick)."""
        return self.monitor.should_sample()

    def _qoi(self, exact_logits, approx_logits):
        """Metric-specific QoI: for "mape" the logits tensor; for "mcr"
        the decoded token ids (argmax) -- the serving analogues of the
        offline metrics' QoI choices."""
        if self.monitor.metric == "mcr":
            return (np.argmax(np.asarray(exact_logits), axis=-1),
                    np.argmax(np.asarray(approx_logits), axis=-1))
        return np.asarray(exact_logits), np.asarray(approx_logits)

    def observe_decode(self, exact_logits, approx_logits,
                       lane_classes: Sequence[str] = ()) -> float:
        """Score one canary tick (single-lane-group mode; sharded engines
        use `observe_shard`). `lane_classes` (the live lanes' classes)
        attributes the canary to every class exposed to this tick's
        knob."""
        exact_q, approx_q = self._qoi(exact_logits, approx_logits)
        err = self.monitor.observe(exact_q, approx_q)
        for cls in {self._norm_class(c) for c in lane_classes}:
            self._exposure[cls].append(err)
        return err

    def update(self, lane_classes: Optional[Sequence[str]] = None) -> None:
        """One feedback evaluation. With `lane_classes` (the tick's live
        lanes), only the EXPOSED classes' controllers step: canary errors
        are measured under the actuated knob, and judging an absent class's
        bound against another class's phase would log spurious violations.
        `None` (no lane information) updates every controller."""
        if lane_classes is None:
            live = set(self.controllers)
        else:
            live = {c if c in self.controllers else "default"
                    for c in lane_classes}
        # Snapshot the evidence ONCE: a controller's hard fallback resets
        # the shared monitor window, and without the snapshot the classes
        # updating after it would see an empty window -- a concurrent
        # violation of their own bound silently swallowed, and the
        # trajectory dependent on set iteration order (hash-seed salted).
        # sorted() keeps the trajectory append order deterministic too.
        est = self.monitor.estimate()
        drift = self.monitor.drift()
        wsize = self.monitor.window_size
        for cls in sorted(live):
            self.controllers[cls].update(est=est, drift=drift,
                                         window_size=wsize)
        self._flight_note(sorted(live))

    def _flight_note(self, stepped: Sequence[str],
                     shard_rungs: Optional[Tuple[int, ...]] = None) -> None:
        """Feed the flight recorder (when one is installed): one per-tick
        note of per-class control state, and a `trip()` dump on the tick a
        controller fires its hard fallback -- the incident the ring buffer
        exists for. Host-side dict work only; no-op without a recorder."""
        rec = obs_recorder.get_recorder()
        if rec is None:
            return
        classes = {}
        for cls, ctl in self.controllers.items():
            mon = self.class_monitors.get(cls, self.monitor)
            last = ctl.trajectory[-1] if ctl.trajectory else None
            classes[cls] = {
                "index": ctl.index,
                "knob": spec_knob(ctl.spec()),
                "bound": ctl.target.max_error,
                "estimate": mon.estimate(),
                "drift": mon.drift(),
                "window": mon.window_size,
                "event": last.event if last else None,
            }
        note = {"classes": classes}
        if shard_rungs is not None:
            note["shard_rungs"] = list(shard_rungs)
        rec.note(**note)
        for cls in stepped:
            t = self.controllers[cls].trajectory
            if t and t[-1].event == "fallback":
                rec.trip("fallback", request_class=cls,
                         estimate=t[-1].estimate, drift=t[-1].drift,
                         bound=self.controllers[cls].target.max_error,
                         step=t[-1].step)

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------

    @property
    def fallback_rate(self) -> float:
        return max((c.fallback_rate for c in self.controllers.values()),
                   default=0.0)

    def summary(self) -> Dict:
        ms = self.monitor.stats()
        out = {
            "metric": self.monitor.metric,
            "sample_fraction": self.monitor.sample_fraction,
            "canary_samples": ms.samples,
            "mean_error": ms.mean_error,
            "genuine_mean_error": ms.genuine_mean_error,
            "injected_faults": ms.injected,
            "estimate": ms.estimate,
            "fallback_rate": self.fallback_rate,
            "classes": {cls: dict(
                ctl.summary(),
                exposed_canaries=len(self._exposure[cls]),
                exposed_mean_error=(float(np.mean(self._exposure[cls]))
                                    if self._exposure[cls] else 0.0))
                for cls, ctl in self.controllers.items()},
        }
        if self._n_shards is not None:
            out["shards"] = self._n_shards
            out["shard_exposure"] = {
                s: {"exposed_canaries": len(v),
                    "exposed_mean_error": (float(np.mean(v)) if v else 0.0)}
                for s, v in self._shard_exposure.items()}
        return out
