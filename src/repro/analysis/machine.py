"""Named machine profiles: the single source of truth for hardware
constants.

Both the roofline analyzer (`repro.launch.roofline`) and the analytical
cost model (`repro.analysis.cost`) compose time estimates from the same
three roofline terms:

  compute_s    = FLOPs / peak_flops
  memory_s     = bytes / hbm_bw
  collective_s = collective_bytes / ici_bw

Before this module those constants lived (twice -- docstring and body) in
`launch/roofline.py`; now every consumer resolves a profile by name from
``MACHINES``, lumos-style: a small named-parameter table instead of
scattered literals.  Profiles are frozen dataclasses so a profile object
is hashable and safe to close over in cached model builders.

``dispatch_s`` models the fixed per-invocation launch/dispatch overhead
that floors the runtime of tiny regions: an approximation that removes
FLOPs but not invocations cannot beat ``t >= dispatch_s``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Union


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    """Roofline parameters of one execution substrate."""

    name: str
    peak_flops: float        # FLOP/s per chip (bf16 for TPU profiles)
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link
    dispatch_s: float = 0.0  # fixed per-invocation dispatch overhead

    def time_s(self, flops: float, bytes_: float = 0.0,
               coll_bytes: float = 0.0, invocations: float = 1.0) -> float:
        """Roofline time: max of the three terms, floored by dispatch."""
        t = max(flops / self.peak_flops,
                bytes_ / self.hbm_bw,
                coll_bytes / self.ici_bw)
        return t + invocations * self.dispatch_s


MACHINES: Dict[str, MachineProfile] = {
    # TPU v5e-class chip (constants from the brief): the target substrate
    # for roofline analysis and the default for cost prediction.
    "tpu-v5e": MachineProfile(name="tpu-v5e", peak_flops=197e12,
                              hbm_bw=819e9, ici_bw=50e9,
                              dispatch_s=2e-6),
    # Host interpreter (CPU emulation of the techniques): orders of
    # magnitude slower, dispatch-dominated for small regions.  Used when
    # predicting for the host substrate so sub-1x overhead regimes (e.g.
    # oversized iACT tables) surface at realistic scales.
    "host-sim": MachineProfile(name="host-sim", peak_flops=100e9,
                               hbm_bw=40e9, ici_bw=10e9,
                               dispatch_s=20e-6),
}

DEFAULT_MACHINE = "tpu-v5e"

# substrate name (repro.core.substrate) -> machine profile name
SUBSTRATE_MACHINES: Dict[str, str] = {
    "pallas": "tpu-v5e",
    "host": "host-sim",
}

# the calibrated profile's reserved name: get_machine("measured") measures
# the running backend on first use (see measure_machine)
MEASURED_MACHINE = "measured"


def measure_machine(name: str = MEASURED_MACHINE, *, size: int = 384,
                    copy_mb: int = 8, repeats: int = 3,
                    register: bool = True) -> MachineProfile:
    """Calibrate a roofline profile on the backend actually running.

    Three micro-measurements (median-of-k, warmed, blocked on results):

      peak_flops -- a jitted (size, size) f32 matmul: 2*size^3 FLOPs;
      hbm_bw     -- a jitted copy-scaled array op over ~copy_mb MiB
                    (read + write = 2x the buffer);
      dispatch_s -- a jitted scalar op: pure launch/dispatch floor.

    ``ici_bw`` is inherited from the static profile of the running
    substrate (interconnect bandwidth needs a multi-device collective to
    measure; single-host calibration cannot observe it). The result is
    registered in ``MACHINES`` under `name` so `AppCostModel(machine=
    "measured")`, ladder prescreens, and the kernel autotuner's pre-prune
    all sharpen to measured numbers instead of catalog constants.
    Committed tuning caches still key on the *static* profile names --
    "measured" is session-local by construction.
    """
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.obs.timing import measure

    def _med(fn, *args):
        return measure(fn, *args, warmup=1, repeats=max(1, repeats),
                       stat="median", span="machine.calibrate").seconds

    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.randn(size, size).astype(np.float32))
    t_mm = _med(jax.jit(lambda x: x @ x), a)
    peak_flops = max(2.0 * size ** 3 / max(t_mm, 1e-9), 1e9)

    buf = jnp.asarray(rng.randn(copy_mb * (1 << 20) // 4)
                      .astype(np.float32))
    t_cp = _med(jax.jit(lambda x: x * 1.0000001 + 1.0), buf)
    hbm_bw = max(2.0 * buf.nbytes / max(t_cp, 1e-9), 1e8)

    dispatch_s = max(_med(jax.jit(lambda x: x + 1.0), jnp.float32(0.0)),
                     1e-7)

    base_name = ("tpu-v5e" if jax.default_backend() == "tpu"
                 else "host-sim")
    profile = MachineProfile(name=name, peak_flops=peak_flops,
                             hbm_bw=hbm_bw,
                             ici_bw=MACHINES[base_name].ici_bw,
                             dispatch_s=dispatch_s)
    if register:
        MACHINES[name] = profile
    return profile


def get_machine(machine: Union[str, MachineProfile, None] = None
                ) -> MachineProfile:
    """Resolve a profile by name (or pass one through). ``None`` gives the
    default profile; substrate names ("host" / "pallas") are accepted and
    mapped through ``SUBSTRATE_MACHINES``; ``"measured"`` calibrates the
    running backend on first use (`measure_machine`) and is cached in
    ``MACHINES`` for the rest of the process."""
    if machine is None:
        machine = DEFAULT_MACHINE
    if isinstance(machine, MachineProfile):
        return machine
    name = SUBSTRATE_MACHINES.get(machine, machine)
    if name == MEASURED_MACHINE and name not in MACHINES:
        return measure_machine()
    if name not in MACHINES:
        raise KeyError(
            f"unknown machine profile {machine!r} "
            f"(choose from: {', '.join(sorted(MACHINES))} "
            f"or '{MEASURED_MACHINE}')")
    return MACHINES[name]
