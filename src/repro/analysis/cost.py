"""approxcost: a jaxpr-level analytical speedup / error predictor.

HPAC-Offload's central observation is that approximation pays off only
when the *skipped* work is the *bottleneck* work: perforation removes
whole Pallas blocks (real FLOPs), while an oversized iACT table can cost
more in probe distance computations than the region it memoizes.  That
is a cost-model question, and this module answers it statically -- no
execution -- by walking jaxprs:

* ``jaxpr_cost`` / ``trace_cost`` count FLOPs and bytes per equation
  (dot_general contraction math, transcendental polynomial weight,
  scan bodies multiplied by trip count), the same accounting the
  roofline analyzer applies to whole models, here applied to a region.
* ``AppCostModel.predict`` maps an ``ApproxSpec`` to a
  ``CostPrediction``: estimated speedup -- composed through the shared
  machine table (`repro.analysis.machine`) as roofline terms over the
  FLOP/byte *delta* between the precise and approximated programs plus
  each technique's bookkeeping overhead -- and a conservative relative
  error bound, the per-site residual scaled by the predicted activation
  fraction and amplified through the jaxpr by
  `repro.analysis.errorprop`'s abstract interpretation.
* ``filter_specs`` / ``select_band`` turn predictions into sweep
  pruning (``harness.sweep(predict=)``, ``autotune``) and
  measurement-budget seeding (``pareto.refine(predict=)``).

The skip-fraction models (what fraction of decision invocations the
technique approximates, before any input is seen):

  TAF    f = p_act * duty * warmup
           p_act  = thresh / (thresh + rsd_scale)   -- how often the RSD
                    test passes, against the site's typical signal RSD
           duty   = pSize / (pSize + 1)             -- each detect buys
                    pSize approximated invocations
           warmup = max(0, 1 - hSize / invocations) -- window fill time
  iACT   f = thresh / (thresh + dist_scale)         -- table-hit rate
                    against the site's typical input spread
  perfo  f = drop_fraction(n_iters, params)         -- exact, structural

and the per-decision overheads that make sub-1x predictions real
(rule A006's signal):

  TAF    ~ (3*hSize + 8) FLOPs   -- RSD window update + stability test
  iACT   ~ tSize * 3 * in_dim    -- distance probe against every entry
  perfo    0                     -- bounds change at trace time

Everything here is deliberately first-order: the model's job is to
*rank* candidate specs and *bound* their error so measurement budget is
spent only where it can matter, not to replace measurement.  See
docs/analysis.md ("Cost & error model") for the assumptions.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.analysis.machine import MachineProfile, get_machine
from repro.core.types import ApproxSpec, Technique

log = logging.getLogger("repro.analysis.cost")

# Transcendentals lower to polynomial/rational kernels; weight them as a
# handful of fused multiply-adds rather than one flop.
TRANS_FLOPS = 8.0
# Bytes per element: the repo's arrays are f32 end to end.
_ELEM_BYTES = 4.0
# Trip-count assumption for `while` loops, whose bound is not static.  It
# appears on both sides of every speedup ratio, so its exact value only
# matters for absolute times.
DEFAULT_WHILE_TRIP = 32.0
# Multiplicative headroom on every error bound: the skip-fraction and
# residual models are first-order, the bound must not be.
SITE_HEADROOM = 4.0


# --------------------------------------------------------------------------
# FLOP / byte counting over jaxprs
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostVector:
    """FLOPs and bytes moved -- the two roofline numerators."""

    flops: float = 0.0
    bytes: float = 0.0

    def __add__(self, other: "CostVector") -> "CostVector":
        return CostVector(self.flops + other.flops, self.bytes + other.bytes)

    def __mul__(self, k: float) -> "CostVector":
        return CostVector(self.flops * k, self.bytes * k)

    __rmul__ = __mul__

    def to_json(self) -> Dict:
        return {"flops": self.flops, "bytes": self.bytes}


_TRANS = {
    "exp", "exp2", "log", "log1p", "expm1", "sin", "cos", "tan", "asin",
    "acos", "atan", "sinh", "cosh", "tanh", "asinh", "acosh", "atanh",
    "erf", "erfc", "erf_inv", "logistic", "rsqrt", "sqrt", "cbrt", "pow",
    "integer_pow", "lgamma", "digamma", "regularized_incomplete_beta",
}

# Layout / data-movement primitives: bytes but no arithmetic.
_MOVE = {
    "broadcast_in_dim", "reshape", "transpose", "rev", "slice",
    "dynamic_slice", "dynamic_update_slice", "squeeze", "expand_dims",
    "concatenate", "pad", "gather", "scatter", "copy", "convert_element_type",
    "bitcast_convert_type", "iota", "stop_gradient", "device_put",
    "split", "select_n",
}


def _size(var) -> float:
    aval = getattr(var, "aval", None)
    shape = getattr(aval, "shape", None)
    if shape is None:
        return 0.0
    return float(np.prod(shape, dtype=np.float64)) if shape else 1.0


def _io_bytes(eqn) -> float:
    n = sum(_size(v) for v in eqn.invars if hasattr(v, "aval"))
    n += sum(_size(v) for v in eqn.outvars)
    return n * _ELEM_BYTES


def _dot_flops(eqn) -> float:
    dims = eqn.params.get("dimension_numbers")
    out = sum(_size(v) for v in eqn.outvars)
    if dims is None:
        return 2.0 * out
    (lhs_c, _), _ = dims
    lhs_shape = getattr(eqn.invars[0].aval, "shape", ())
    contract = 1.0
    for ax in lhs_c:
        if ax < len(lhs_shape):
            contract *= float(lhs_shape[ax])
    return 2.0 * out * contract


def _sub_jaxprs(eqn) -> List:
    """All (closed or open) sub-jaxprs of a higher-order equation."""
    subs = []
    for key in ("jaxpr", "cond_jaxpr", "body_jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in eqn.params:
            subs.append(eqn.params[key])
    if "branches" in eqn.params:
        subs.extend(eqn.params["branches"])
    return subs


def _as_open(j):
    return getattr(j, "jaxpr", j)


def eqn_cost(eqn) -> CostVector:
    """FLOP/byte cost of one equation (recursing into control flow)."""
    name = eqn.primitive.name
    bytes_ = _io_bytes(eqn)
    out = sum(_size(v) for v in eqn.outvars)

    if name in ("dot_general", "conv_general_dilated"):
        return CostVector(_dot_flops(eqn), bytes_)
    if name == "scan":
        body = jaxpr_cost(_as_open(eqn.params["jaxpr"]))
        length = float(eqn.params.get("length", 1) or 1)
        return CostVector(body.flops * length, body.bytes * length + bytes_)
    if name == "while":
        body = jaxpr_cost(_as_open(eqn.params["body_jaxpr"]))
        cond = jaxpr_cost(_as_open(eqn.params["cond_jaxpr"]))
        trip = DEFAULT_WHILE_TRIP
        return CostVector((body.flops + cond.flops) * trip,
                          (body.bytes + cond.bytes) * trip + bytes_)
    if name in ("cond", "switch") and "branches" in eqn.params:
        branches = [jaxpr_cost(_as_open(b)) for b in eqn.params["branches"]]
        return CostVector(max(b.flops for b in branches),
                          max(b.bytes for b in branches) + bytes_)
    if name == "pallas_call":
        inner = eqn.params.get("jaxpr")
        if inner is not None:
            body = jaxpr_cost(_as_open(inner))
            grid_mapping = eqn.params.get("grid_mapping")
            grid = getattr(grid_mapping, "grid", ()) or ()
            n_blocks = float(np.prod([g for g in grid if isinstance(g, int)],
                                     dtype=np.float64)) if grid else 1.0
            return CostVector(body.flops * n_blocks, bytes_)
        return CostVector(0.0, bytes_)
    subs = _sub_jaxprs(eqn)
    if subs:  # pjit / remat / custom_*_call / closed_call ...
        total = CostVector()
        for sub in subs:
            total = total + jaxpr_cost(_as_open(sub))
        return total
    if name in _MOVE:
        return CostVector(0.0, bytes_)
    if name in _TRANS:
        return CostVector(out * TRANS_FLOPS, bytes_)
    if name.startswith("reduce_") or name in ("argmax", "argmin",
                                              "cumsum", "cumprod",
                                              "cumlogsumexp", "cummax",
                                              "cummin", "sort"):
        inp = sum(_size(v) for v in eqn.invars if hasattr(v, "aval"))
        return CostVector(inp, bytes_)
    # default: one flop per output element (elementwise arithmetic,
    # comparisons, selects, integer ops, RNG, ...)
    return CostVector(out, bytes_)


def jaxpr_cost(jaxpr) -> CostVector:
    """Total FLOP/byte cost of an (open) jaxpr."""
    total = CostVector()
    for eqn in jaxpr.eqns:
        total = total + eqn_cost(eqn)
    return total


def trace_cost(fn: Callable, *example_args) -> CostVector:
    """Trace ``fn`` at ``example_args`` and count its cost."""
    import jax
    closed = jax.make_jaxpr(fn)(*example_args)
    return jaxpr_cost(closed.jaxpr)


# --------------------------------------------------------------------------
# Per-site skip-fraction + overhead + residual models
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Site:
    """One approximation site of an app, as the cost model sees it.

    region:        cost of the approximable work *per decision invocation*
                   (for perforation: the whole perforable loop per run).
    invocations:   decision invocations over the whole workload.
    in_dim:        input width per invocation (iACT probe cost scales
                   with it).
    rsd_scale:     the site's typical signal RSD -- calibrates how often a
                   TAF threshold fires (p_act = t / (t + rsd_scale)).
    dist_scale:    the site's typical input spread -- calibrates the iACT
                   table-hit rate the same way.
    n_iters:       perforable-loop length (drop_fraction needs it).
    amplification: relative-error gain from this site to the QoI, from
                   `errorprop.amplification` (or 1.0 when the region IS
                   the QoI).
    qoi_condition: additive residual floor for ill-conditioned QoIs --
                   when the QoI crosses zero (option prices, logits),
                   MAPE is heavy-tailed and even a vanishing absolute
                   perturbation costs this much relative error.
    """

    region: CostVector = dataclasses.field(default_factory=CostVector)
    invocations: float = 1.0
    in_dim: int = 8
    rsd_scale: float = 0.5
    dist_scale: float = 0.5
    n_iters: int = 8
    amplification: float = 1.0
    qoi_condition: float = 0.0


def _taf_fraction(spec: ApproxSpec, site: Site) -> float:
    t = spec.taf
    p_act = t.rsd_threshold / (t.rsd_threshold + site.rsd_scale + 1e-30)
    duty = t.prediction_size / (t.prediction_size + 1.0)
    warmup = max(0.0, 1.0 - t.history_size / max(site.invocations, 1.0))
    return p_act * duty * warmup


def _iact_fraction(spec: ApproxSpec, site: Site) -> float:
    t = spec.iact
    return t.threshold / (t.threshold + site.dist_scale + 1e-30)


def _skip_fraction(spec: ApproxSpec, site: Site) -> float:
    if spec.technique == Technique.TAF:
        return min(1.0, _taf_fraction(spec, site))
    if spec.technique == Technique.IACT:
        return min(1.0, _iact_fraction(spec, site))
    if spec.technique == Technique.PERFORATION:
        from repro.core.perforation import drop_fraction
        return drop_fraction(site.n_iters, spec.perforation)
    return 0.0


def _skip_fraction_upper(spec: ApproxSpec, site: Site) -> float:
    """Upper bound on the skip fraction, for the ERROR side of the
    prediction. The speedup estimate wants the expected activation (the
    `rsd_scale`/`dist_scale`-calibrated models above), but a bound must
    survive the worst case: on highly redundant data the detector fires
    at every opportunity, capped only by the technique's structure (TAF's
    duty cycle and warmup; nothing for iACT). Perforation is structural,
    so expected == upper."""
    if spec.technique == Technique.TAF:
        t = spec.taf
        duty = t.prediction_size / (t.prediction_size + 1.0)
        warmup = max(0.0, 1.0 - t.history_size / max(site.invocations, 1.0))
        return duty * warmup
    if spec.technique == Technique.IACT:
        return 1.0
    return _skip_fraction(spec, site)


def _overhead(spec: ApproxSpec, site: Site) -> CostVector:
    """Per-decision bookkeeping the technique adds (never skipped)."""
    if spec.technique == Technique.TAF:
        return CostVector(3.0 * spec.taf.history_size + 8.0,
                          _ELEM_BYTES * spec.taf.history_size)
    if spec.technique == Technique.IACT:
        probe = spec.iact.table_size * 3.0 * site.in_dim
        return CostVector(probe, _ELEM_BYTES * spec.iact.table_size
                          * site.in_dim)
    return CostVector()


def _site_residual(spec: ApproxSpec, site: Site) -> float:
    """Relative error introduced per approximated invocation."""
    if spec.technique == Technique.TAF:
        # RSD threshold bounds the window's spread; each of the pSize
        # predicted invocations can drift by up to that much again.
        return (site.qoi_condition
                + spec.taf.rsd_threshold * (1.0 + spec.taf.prediction_size))
    if spec.technique == Technique.IACT:
        # An input within `threshold` of a table entry reuses its output;
        # with the site's spread as the scale, the relative input (and,
        # to first order, output) perturbation is their ratio.
        return (site.qoi_condition
                + spec.iact.threshold / max(site.dist_scale, 1e-30))
    if spec.technique == Technique.PERFORATION:
        return 1.0  # a dropped iteration's contribution is fully lost
    return 0.0


# --------------------------------------------------------------------------
# The predictor
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CostPrediction:
    """What the model claims about one spec, before any execution."""

    speedup: float            # t_precise / t_approx on the target machine
    error_bound: float        # conservative relative QoI error
    skip_fraction: float      # predicted fraction of work approximated
    flop_fraction: float      # approx FLOPs / precise FLOPs
    t_precise_s: float
    t_approx_s: float
    modeled: bool = True      # False: no site for this technique -> neutral

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


_NEUTRAL = CostPrediction(speedup=1.0, error_bound=0.0, skip_fraction=0.0,
                          flop_fraction=1.0, t_precise_s=0.0,
                          t_approx_s=0.0, modeled=False)


@dataclasses.dataclass(frozen=True)
class AppCostModel:
    """Static speedup/error predictor for one app on one machine.

    total:       whole-workload precise cost (must contain every site's
                 ``region * invocations``).
    sites:       per-technique approximation sites.
    dispatches:  kernel dispatch count (identical on both sides; floors
                 the runtime of tiny regions via ``dispatch_s``).
    """

    name: str
    total: CostVector
    sites: Dict[Technique, Site]
    machine: MachineProfile = dataclasses.field(
        default_factory=lambda: get_machine())
    dispatches: float = 1.0

    def predict(self, spec: ApproxSpec) -> CostPrediction:
        if not spec.enabled:
            t = self.machine.time_s(self.total.flops, self.total.bytes,
                                    invocations=self.dispatches)
            return CostPrediction(1.0, 0.0, 0.0, 1.0, t, t)
        site = self.sites.get(spec.technique)
        if site is None:
            return _NEUTRAL
        f = _skip_fraction(spec, site)
        over = _overhead(spec, site) * site.invocations
        saved = site.region * (f * site.invocations)
        apx_flops = max(self.total.flops - saved.flops + over.flops, 0.0)
        apx_bytes = max(self.total.bytes - saved.bytes + over.bytes, 0.0)
        t_pre = self.machine.time_s(self.total.flops, self.total.bytes,
                                    invocations=self.dispatches)
        t_apx = self.machine.time_s(apx_flops, apx_bytes,
                                    invocations=self.dispatches)
        err = (SITE_HEADROOM * site.amplification
               * _skip_fraction_upper(spec, site)
               * _site_residual(spec, site))
        return CostPrediction(
            speedup=t_pre / max(t_apx, 1e-30),
            error_bound=err,
            skip_fraction=f,
            flop_fraction=apx_flops / max(self.total.flops, 1e-30),
            t_precise_s=t_pre,
            t_approx_s=t_apx)

    # -- pruning / seeding -------------------------------------------------

    def select(self, specs: Sequence[ApproxSpec], *,
               min_speedup: float = 1.0,
               max_error: Optional[float] = None
               ) -> Tuple[List[ApproxSpec], List[ApproxSpec]]:
        """(kept, dropped): drop specs predicted sub-``min_speedup`` or
        above ``max_error``. NONE and unmodeled specs are always kept."""
        kept, dropped = [], []
        for spec in specs:
            p = self.predict(spec)
            if not spec.enabled or not p.modeled:
                kept.append(spec)
            elif p.speedup < min_speedup:
                dropped.append(spec)
            elif max_error is not None and p.error_bound > max_error:
                dropped.append(spec)
            else:
                kept.append(spec)
        return kept, dropped

    def select_band(self, specs: Sequence[ApproxSpec], *,
                    budget: Optional[int] = None,
                    band: float = 0.10) -> List[ApproxSpec]:
        """Specs inside the predicted-front band, best (lowest regret)
        first.

        A spec's regret is its relative speedup deficit against the
        predicted-(error_bound, speedup) Pareto front: 0 on the front,
        else the smallest gap to a dominating prediction.  Specs within
        ``band`` relative regret survive; ``budget`` truncates the
        ranking.  NONE / unmodeled specs rank first (they anchor sweeps
        and cost the model nothing to keep).
        """
        from repro.core.harness import spec_key

        scored = []
        preds = [(spec, self.predict(spec)) for spec in specs]
        modeled = [(s, p) for s, p in preds if s.enabled and p.modeled]
        for spec, p in preds:
            if not spec.enabled or not p.modeled:
                scored.append((-1.0, spec_key(spec), spec))
                continue
            regret = 0.0
            for _, q in modeled:
                if (q.error_bound <= p.error_bound
                        and q.speedup > p.speedup):
                    gap = (q.speedup - p.speedup) / max(q.speedup, 1e-30)
                    regret = max(regret, gap)
            scored.append((regret, spec_key(spec), spec))
        scored.sort(key=lambda t: (t[0], t[1]))
        picked = [s for r, _, s in scored if r <= band]
        if budget is not None:
            picked = picked[:max(budget, 0)]
        return picked


def filter_specs(model: Union[AppCostModel,
                              Callable[[ApproxSpec], CostPrediction]],
                 specs: Sequence[ApproxSpec], *,
                 min_speedup: float = 1.0,
                 max_error: Optional[float] = None,
                 context: str = "sweep"
                 ) -> Tuple[List[ApproxSpec], List[ApproxSpec]]:
    """Shared pruning entry point for sweep/autotune/calibrate.

    Accepts an ``AppCostModel`` or any ``spec -> CostPrediction``
    callable; logs the kept/dropped count so pruned sweeps are auditable.
    """
    specs = list(specs)
    if isinstance(model, AppCostModel):
        kept, dropped = model.select(specs, min_speedup=min_speedup,
                                     max_error=max_error)
    else:
        kept, dropped = [], []
        for spec in specs:
            p = model(spec)
            if not spec.enabled or not getattr(p, "modeled", True):
                kept.append(spec)
            elif p.speedup < min_speedup:
                dropped.append(spec)
            elif max_error is not None and p.error_bound > max_error:
                dropped.append(spec)
            else:
                kept.append(spec)
    log.info("predict[%s]: kept %d / dropped %d of %d specs "
             "(min_speedup=%.3g%s)", context, len(kept), len(dropped),
             len(specs), min_speedup,
             "" if max_error is None else f", max_error={max_error:.3g}")
    return kept, dropped


# --------------------------------------------------------------------------
# Generic ladder model (rule A006 / qos pre-screen fallback)
# --------------------------------------------------------------------------

def ladder_model(machine=None, *, region_flops: float = 4096.0,
                 invocations: float = 256.0, in_dim: int = 16,
                 n_iters: int = 8, name: str = "ladder") -> AppCostModel:
    """A deliberately generic single-site-per-technique model for
    screening QoS ladders whose app is not in hand (rule A006).

    The defaults describe a small serving region: ~4k FLOPs per decision
    invocation over a 16-wide input.  At that scale the technique
    *overheads* dominate the screen -- an iACT rung with an oversized
    table (probe cost ``tSize * 3 * in_dim`` > region FLOPs) or a TAF
    rung whose window upkeep exceeds what it skips predicts sub-1x
    regardless of threshold, which is exactly the class of
    misconfiguration a static pre-screen can reject.
    """
    prof = get_machine(machine)
    region = CostVector(region_flops, region_flops * _ELEM_BYTES / 2.0)
    site = Site(region=region, invocations=invocations, in_dim=in_dim,
                n_iters=n_iters)
    return AppCostModel(
        name=name,
        total=region * invocations,
        sites={Technique.TAF: site, Technique.IACT: site,
               Technique.PERFORATION: site},
        machine=prof,
        # one fused launch for the whole ladder region: decision
        # invocations live inside the traced program, not as dispatches
        dispatches=1.0)
