"""jaxpr taint analysis for A003 (unsafe approximation sink).

Sources are the *approximate value* leaves of a traced program (memoized
TAF outputs, perforated partial sums). Sinks are positions where a tainted
value steers the PROGRAM rather than flowing through arithmetic:

  * the predicate operand of `cond`/`switch`,
  * the carry positions feeding a `while` loop's cond_jaxpr output,
  * the index operands of `gather` / `dynamic_slice` /
    `dynamic_update_slice` / `scatter*`.

Arithmetic on approximate data is the *point* of approximate computing --
bounded error in, bounded error out. Indices and predicates are different:
a 1-ulp error flips a branch or reads a different row, so the error model
becomes discontinuous. That asymmetry (safe-to-perturb dataflow vs
unsafe-to-perturb control flow) is the classic AC safety condition, and it
is checkable purely on the jaxpr.

The walk is conservative: any tainted input taints every output of an eqn
unless the primitive is handled structurally (pjit / cond / while / scan
recurse into their subjaxprs; while/scan carries run to a fixpoint).
Detector STATE (e.g. TAF's `remaining` counter) steering a `cond` is the
approximation *mechanism*, not a defect -- callers control that by choosing
which leaves they mark tainted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set

from jax import core as jcore

try:  # jax >= 0.4.x moved Literal around; import defensively
    Literal = jcore.Literal
except AttributeError:  # pragma: no cover
    from jax._src.core import Literal  # type: ignore

# sink primitive -> (operand slice holding indices, sink kind)
_INDEX_SINKS = {
    "gather": (slice(1, 2), "gather indices"),
    "dynamic_slice": (slice(1, None), "dynamic_slice start indices"),
    "dynamic_update_slice": (slice(2, None),
                             "dynamic_update_slice start indices"),
    "scatter": (slice(1, 2), "scatter indices"),
    "scatter-add": (slice(1, 2), "scatter indices"),
    "scatter_add": (slice(1, 2), "scatter indices"),
    "scatter-mul": (slice(1, 2), "scatter indices"),
    "scatter-min": (slice(1, 2), "scatter indices"),
    "scatter-max": (slice(1, 2), "scatter indices"),
}


@dataclasses.dataclass(frozen=True)
class TaintSink:
    primitive: str
    kind: str        # "branch predicate" | "while predicate" | "... indices"
    path: str        # subjaxpr path, e.g. "pjit/cond[1]"
    eqn_repr: str

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def _tainted_in(eqn, tainted: Set) -> List[int]:
    return [i for i, v in enumerate(eqn.invars)
            if not isinstance(v, Literal) and v in tainted]


def _walk(jaxpr, tainted: Set, path: str, sinks: List[TaintSink]) -> Set:
    """Propagate taint through one (open) jaxpr; `tainted` holds Var
    objects of this jaxpr's scope. Returns the set of tainted outvars
    (by position index into jaxpr.outvars)."""
    tainted = set(tainted)
    for eqn in jaxpr.eqns:
        hit = _tainted_in(eqn, tainted)
        name = eqn.primitive.name

        if name in _INDEX_SINKS:
            sl, kind = _INDEX_SINKS[name]
            idx_positions = range(*sl.indices(len(eqn.invars)))
            if any(i in hit for i in idx_positions):
                sinks.append(TaintSink(primitive=name, kind=kind, path=path,
                                       eqn_repr=str(eqn)[:200]))

        if name in ("cond", "switch"):
            # invars[0] is the predicate/branch index; the rest are operands.
            if 0 in hit:
                sinks.append(TaintSink(primitive=name,
                                       kind="branch predicate", path=path,
                                       eqn_repr=str(eqn)[:200]))
            branches = eqn.params.get("branches", ())
            out_taint = set()
            for bi, br in enumerate(branches):
                inner = br.jaxpr
                sub = {iv for iv, ov in zip(inner.invars, eqn.invars[1:])
                       if not isinstance(ov, Literal) and ov in tainted}
                touts = _walk(inner, sub, f"{path}/cond[{bi}]", sinks)
                out_taint |= touts
            for oi in out_taint:
                tainted.add(eqn.outvars[oi])
            continue

        if name in ("pjit", "closed_call", "core_call", "xla_call",
                    "custom_jvp_call", "custom_vjp_call", "remat", "remat2",
                    "checkpoint", "custom_vjp_call_jaxpr"):
            closed = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if closed is not None:
                inner = getattr(closed, "jaxpr", closed)
                sub = {iv for iv, ov in zip(inner.invars, eqn.invars)
                       if not isinstance(ov, Literal) and ov in tainted}
                touts = _walk(inner, sub, f"{path}/{name}", sinks)
                for oi in touts:
                    tainted.add(eqn.outvars[oi])
                continue

        if name == "while":
            cj = eqn.params["cond_jaxpr"]
            bj = eqn.params["body_jaxpr"]
            cn = eqn.params["cond_nconsts"]
            bn = eqn.params["body_nconsts"]
            carry_ops = eqn.invars[cn + bn:]
            carry_taint = {i for i, ov in enumerate(carry_ops)
                           if not isinstance(ov, Literal) and ov in tainted}
            body_const_taint = {
                i for i, ov in enumerate(eqn.invars[cn:cn + bn])
                if not isinstance(ov, Literal) and ov in tainted}
            cond_const_taint = {
                i for i, ov in enumerate(eqn.invars[:cn])
                if not isinstance(ov, Literal) and ov in tainted}
            # fixpoint over the carry: one body pass can taint new slots
            for _ in range(len(carry_ops) + 1):
                bvars = bj.jaxpr.invars
                sub = {bvars[i] for i in body_const_taint}
                sub |= {bvars[bn + i] for i in carry_taint}
                new_carry = _walk(bj.jaxpr, sub, f"{path}/while.body", sinks)
                if new_carry <= carry_taint:
                    break
                carry_taint |= new_carry
            cvars = cj.jaxpr.invars
            csub = {cvars[i] for i in cond_const_taint}
            csub |= {cvars[cn + i] for i in carry_taint}
            pred_taint = _walk(cj.jaxpr, csub, f"{path}/while.cond", sinks)
            if pred_taint:
                sinks.append(TaintSink(primitive="while",
                                       kind="while predicate", path=path,
                                       eqn_repr=str(eqn)[:200]))
            for i in carry_taint:
                tainted.add(eqn.outvars[i])
            continue

        if name == "scan":
            closed = eqn.params["jaxpr"]
            nc = eqn.params["num_consts"]
            ncar = eqn.params["num_carry"]
            const_taint = {i for i in range(nc) if i in hit}
            carry_taint = {i - nc for i in hit if nc <= i < nc + ncar}
            x_taint = {i - nc - ncar for i in hit if i >= nc + ncar}
            for _ in range(ncar + 1):
                ivars = closed.jaxpr.invars
                sub = {ivars[i] for i in const_taint}
                sub |= {ivars[nc + i] for i in carry_taint}
                sub |= {ivars[nc + ncar + i] for i in x_taint}
                touts = _walk(closed.jaxpr, sub, f"{path}/scan", sinks)
                new_carry = {i for i in touts if i < ncar}
                ys = {i for i in touts if i >= ncar}
                if new_carry <= carry_taint:
                    for oi in (carry_taint | ys):
                        tainted.add(eqn.outvars[oi])
                    break
                carry_taint |= new_carry
            continue

        if hit:  # default conservative rule: any in -> all out
            for ov in eqn.outvars:
                tainted.add(ov)

    return {i for i, ov in enumerate(jaxpr.outvars)
            if not isinstance(ov, Literal) and ov in tainted}


def find_taint_sinks(closed_jaxpr,
                     tainted_inputs: Sequence[int]) -> List[TaintSink]:
    """Walk a ClosedJaxpr with the given input positions tainted and return
    every control-flow/index sink the taint reaches. Purely structural --
    nothing executes."""
    jaxpr = closed_jaxpr.jaxpr
    tainted = {jaxpr.invars[i] for i in tainted_inputs}
    sinks: List[TaintSink] = []
    _walk(jaxpr, tainted, "", sinks)
    # de-dup (fixpoint iterations can record the same sink twice)
    seen, out = set(), []
    for s in sinks:
        key = (s.primitive, s.kind, s.path, s.eqn_repr)
        if key not in seen:
            seen.add(key)
            out.append(s)
    return out
