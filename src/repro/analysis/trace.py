"""Abstract tracing utilities: jaxpr fingerprints and the knob-trace probe.

The A001 protocol
-----------------
A quality knob is *properly traced* iff tracing the target as a function OF
the knob succeeds and produces the same jaxpr for different knob values:

    jax.make_jaxpr(lambda th: target(th))(jnp.float32(v))

Passing the knob as the traced argument (rather than closing over a Python
float) is load-bearing: a closed-over float becomes a literal at the pjit
call site, so even a perfectly-traced kernel would show a textual diff.
With the knob as the argument there are exactly three outcomes, each a
distinct verdict:

  * identical fingerprints  -> traced (clean): one compiled artifact serves
    every knob value.
  * tracing RAISES          -> static (finding): the knob reaches a
    `static_argnames` parameter (Non-hashable static arguments) or Python
    control flow (TracerBoolConversionError) -- either way each value is a
    fresh compile or an outright trace failure.
  * differing fingerprints  -> baked (finding): the knob value was embedded
    in the program as a constant (e.g. captured before the trace), so
    sweeping it recompiles.

Fingerprints normalize hex object addresses (pallas_call params embed
function objects whose reprs contain `0x...`) so two traces of the same
program text compare equal.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

_HEX_ADDR = re.compile(r"0x[0-9a-fA-F]+")
# `let _tmp123 = ...` counters can differ across traces of *different*
# programs but are stable within one process for identical traces; the hex
# normalization is the only one that has shown up in practice.


def jaxpr_fingerprint(closed_jaxpr) -> str:
    """Comparable text form of a ClosedJaxpr: structure + consts' avals,
    with memory addresses normalized out."""
    text = str(closed_jaxpr)
    consts = ",".join(str(jax.api_util.shaped_abstractify(c))
                      if hasattr(jax.api_util, "shaped_abstractify")
                      else str(jnp.shape(c))
                      for c in closed_jaxpr.consts)
    return _HEX_ADDR.sub("0x", text + "\nconsts: " + consts)


@dataclasses.dataclass(frozen=True)
class KnobTraceResult:
    """Outcome of probing one knob on one target."""

    verdict: str                 # "traced" | "static" | "baked" | "error"
    knob_values: Sequence[float]
    error: Optional[str] = None  # for static/error: the exception text
    diff_excerpt: Optional[str] = None   # for baked: first differing region

    @property
    def clean(self) -> bool:
        return self.verdict == "traced"


def _first_diff(a: str, b: str, context: int = 80) -> str:
    n = min(len(a), len(b))
    i = next((i for i in range(n) if a[i] != b[i]), n)
    lo = max(0, i - context)
    return (f"...{a[lo:i + context]}... vs ...{b[lo:i + context]}...")


_STATIC_MARKERS = (
    "Non-hashable static arguments",
    "static argument",
    "TracerBoolConversionError",
    "concrete value is expected",
    "Abstract tracer value encountered",
)


def probe_knob(target: Callable[[jnp.ndarray], object],
               knob_values: Sequence[float] = (0.25, 0.75),
               dtype=jnp.float32) -> KnobTraceResult:
    """Trace `target` (a function of ONE scalar knob) at each value and
    classify. No computation runs: `jax.make_jaxpr` only traces.
    """
    fingerprints = []
    for v in knob_values:
        # a FRESH wrapper per value defeats jax's trace cache (keyed on
        # the function object + avals): a cached jaxpr would hide a
        # constant baked in at trace time, since the target would only
        # ever be traced once
        def _fresh(th, _t=target):
            return _t(th)
        try:
            closed = jax.make_jaxpr(_fresh)(jnp.asarray(v, dtype))
        except Exception as e:  # noqa: BLE001 - classify, don't crash
            text = f"{type(e).__name__}: {e}"
            if any(m in text for m in _STATIC_MARKERS) or \
                    isinstance(e, (TypeError, jax.errors.TracerBoolConversionError)):
                return KnobTraceResult(verdict="static",
                                       knob_values=tuple(knob_values),
                                       error=text[:500])
            return KnobTraceResult(verdict="error",
                                   knob_values=tuple(knob_values),
                                   error=text[:500])
        fingerprints.append(jaxpr_fingerprint(closed))
    if all(f == fingerprints[0] for f in fingerprints[1:]):
        return KnobTraceResult(verdict="traced", knob_values=tuple(knob_values))
    return KnobTraceResult(
        verdict="baked", knob_values=tuple(knob_values),
        diff_excerpt=_first_diff(fingerprints[0], fingerprints[1]))


def abstract_arrays(*shaped):
    """ShapeDtypeStructs for tracing without allocating real data.
    Each item is (shape, dtype)."""
    return tuple(jax.ShapeDtypeStruct(s, d) for s, d in shaped)
