"""Typed findings, severities, allowlists, and reports for approxlint.

A finding is one violated invariant at one subject. Subjects are dotted
paths naming what was analyzed ("kernels.taf_matmul.rsd_threshold",
"policy:benchmarks/policies/chat.json#rung3"), stable across runs so they
can be allowlisted. The allowlist is the mechanism for *intentional*
structural knobs: a `skip`-driven perforation kernel legitimately bakes
its kept set into the compiled program (the herded payoff), so its A001
finding is recorded with a reason instead of failing the lint.
"""
from __future__ import annotations

import dataclasses
import enum
import fnmatch
import json
import os
from typing import Dict, List, Optional, Sequence


class Severity(enum.IntEnum):
    """Ordered: gate thresholds compare with >=."""

    INFO = 10
    WARNING = 20
    ERROR = 30

    @classmethod
    def parse(cls, name: str) -> "Severity":
        try:
            return cls[name.strip().upper()]
        except KeyError:
            raise ValueError(
                f"unknown severity {name!r}; expected one of "
                f"{[s.name.lower() for s in cls]}") from None


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violated invariant.

    rule:     the rule id ("A001" .. "A005").
    severity: gate weight.
    subject:  dotted path of what was analyzed (allowlist match key).
    message:  one-line human statement of the defect.
    detail:   machine-readable evidence (jaxpr diff excerpt, offending
              rung index, uncommitted leaf path, ...).
    """

    rule: str
    severity: Severity
    subject: str
    message: str
    detail: Dict = dataclasses.field(default_factory=dict)

    @property
    def key(self) -> str:
        return f"{self.rule}:{self.subject}"

    def to_json(self) -> Dict:
        return {
            "rule": self.rule,
            "severity": self.severity.name.lower(),
            "subject": self.subject,
            "message": self.message,
            "detail": self.detail,
        }


@dataclasses.dataclass(frozen=True)
class AllowEntry:
    rule: str
    subject: str            # fnmatch pattern over Finding.subject
    reason: str = ""

    def matches(self, finding: Finding) -> bool:
        return (self.rule == finding.rule
                and fnmatch.fnmatchcase(finding.subject, self.subject))


class Allowlist:
    """Intentional-finding registry (the `.approxlint.json` file).

    Schema:

        {"version": 1,
         "allow": [{"rule": "A001",
                    "subject": "kernels.perforated_matmul.perfo",
                    "reason": "skip-driven kept set is structural"}]}

    Every entry MUST carry a reason: an allowlist without rationale decays
    into a mute button.
    """

    def __init__(self, entries: Sequence[AllowEntry] = ()):
        self.entries: List[AllowEntry] = list(entries)

    @classmethod
    def load(cls, path: str) -> "Allowlist":
        with open(path) as f:
            doc = json.load(f)
        if not isinstance(doc, dict) or "allow" not in doc:
            raise ValueError(
                f"{path}: allowlist must be an object with an 'allow' list")
        entries = []
        for i, e in enumerate(doc["allow"]):
            missing = {"rule", "subject", "reason"} - set(e)
            if missing:
                raise ValueError(
                    f"{path}: allow[{i}] is missing {sorted(missing)} "
                    "(every entry needs rule, subject, and a reason)")
            if not str(e["reason"]).strip():
                raise ValueError(
                    f"{path}: allow[{i}] has an empty reason; an "
                    "unexplained allowlist entry is a mute button")
            entries.append(AllowEntry(rule=e["rule"], subject=e["subject"],
                                      reason=e["reason"]))
        return cls(entries)

    def match(self, finding: Finding) -> Optional[AllowEntry]:
        for e in self.entries:
            if e.matches(finding):
                return e
        return None


def default_allowlist_path(start: Optional[str] = None) -> Optional[str]:
    """Walk up from `start` (default: cwd) looking for `.approxlint.json`
    -- the same discovery shape as every linter's config file."""
    cur = os.path.abspath(start or os.getcwd())
    while True:
        cand = os.path.join(cur, ".approxlint.json")
        if os.path.exists(cand):
            return cand
        parent = os.path.dirname(cur)
        if parent == cur:
            return None
        cur = parent


@dataclasses.dataclass
class Report:
    """The lint result: active findings plus the allowlisted ones (kept so
    the JSON artifact shows what was *deliberately* accepted, not just what
    failed)."""

    findings: List[Finding] = dataclasses.field(default_factory=list)
    allowlisted: List[Dict] = dataclasses.field(default_factory=list)
    errors: List[str] = dataclasses.field(default_factory=list)  # rule crashes

    def extend(self, findings: Sequence[Finding],
               allowlist: Optional[Allowlist] = None) -> None:
        for f in findings:
            entry = allowlist.match(f) if allowlist is not None else None
            if entry is not None:
                self.allowlisted.append(
                    {"finding": f.to_json(), "reason": entry.reason,
                     "pattern": entry.subject})
            else:
                self.findings.append(f)

    def count(self, at_least: Severity = Severity.INFO) -> int:
        return sum(1 for f in self.findings if f.severity >= at_least)

    def failed(self, fail_on: Severity = Severity.ERROR) -> bool:
        return bool(self.errors) or self.count(fail_on) > 0

    def to_json(self) -> Dict:
        by_rule: Dict[str, int] = {}
        for f in self.findings:
            by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
        return {
            "version": 1,
            "findings": [f.to_json() for f in self.findings],
            "allowlisted": self.allowlisted,
            "rule_errors": self.errors,
            "summary": {
                "total": len(self.findings),
                "errors": self.count(Severity.ERROR),
                "warnings": sum(1 for f in self.findings
                                if f.severity == Severity.WARNING),
                "by_rule": by_rule,
                "allowlisted": len(self.allowlisted),
            },
        }

    def render_text(self) -> str:
        lines = []
        order = {Severity.ERROR: 0, Severity.WARNING: 1, Severity.INFO: 2}
        for f in sorted(self.findings,
                        key=lambda f: (order[f.severity], f.rule, f.subject)):
            lines.append(f"{f.severity.name.lower():7s} {f.rule} "
                         f"{f.subject}: {f.message}")
            for k, v in f.detail.items():
                text = str(v)
                if len(text) > 200:
                    text = text[:200] + "..."
                lines.append(f"        {k}: {text}")
        for a in self.allowlisted:
            fj = a["finding"]
            lines.append(f"allowed {fj['rule']} {fj['subject']} "
                         f"({a['reason']})")
        for e in self.errors:
            lines.append(f"error   rule crashed: {e}")
        s = self.to_json()["summary"]
        lines.append(
            f"approxlint: {s['total']} finding(s) "
            f"({s['errors']} error, {s['warnings']} warning), "
            f"{s['allowlisted']} allowlisted")
        return "\n".join(lines)
