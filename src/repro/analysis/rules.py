"""The approxlint rules: A001-A005.

Every rule is a function `(config) -> List[Finding]` over the target
registry (`targets.py`). Rules trace, they do not execute -- except A005,
whose subject (mesh placement) only exists on concrete arrays.

  A001 recompile-leak            quality knob shapes the compiled artifact
  A002 substrate misconfiguration  kernel/grid/geometry/benchmark wiring
  A003 unsafe approximation sink   approximate values steering control flow
  A004 QoS ladder validity         saved policy files break the ladder
                                   invariants the controller relies on
  A005 sharding placement          leaves entering the sharded serve step
                                   without mesh commitment
"""
from __future__ import annotations

import glob as glob_mod
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import taint as taint_mod
from . import targets as targets_mod
from . import trace as trace_mod
from .findings import Finding, Severity

_KNOB_FIELDS = ("thresh", "fraction")  # quality-knob keys in the spec dict


def _repo_root() -> str:
    # this file lives at <root>/src/repro/analysis/rules.py (`repro` is a
    # namespace package, so its own __file__ is None)
    here = os.path.abspath(__file__)
    return os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(here))))


# --------------------------------------------------------------------------
# A001 -- recompile leak
# --------------------------------------------------------------------------

def _probe_targets(knob_targets) -> List[Finding]:
    out = []
    for t in knob_targets:
        try:
            fn = t.build()
        except Exception as e:  # noqa: BLE001
            out.append(Finding(
                "A001", Severity.WARNING, t.subject,
                "knob target failed to build (cannot verify tracing)",
                {"error": f"{type(e).__name__}: {e}"[:500]}))
            continue
        res = trace_mod.probe_knob(fn, t.values)
        if res.verdict == "static":
            out.append(Finding(
                "A001", Severity.ERROR, t.subject,
                "quality knob is a STATIC argument: every knob value is a "
                "fresh compile (or an outright trace failure)",
                {"trace_error": res.error}))
        elif res.verdict == "baked":
            out.append(Finding(
                "A001", Severity.ERROR, t.subject,
                "quality knob is BAKED into the program as a constant: "
                "sweeping it recompiles",
                {"jaxpr_diff": res.diff_excerpt}))
        elif res.verdict == "error":
            out.append(Finding(
                "A001", Severity.WARNING, t.subject,
                "knob trace crashed (neither clean nor a known leak shape)",
                {"error": res.error}))
    return out


def check_spec_grouping(specs, subject_prefix: str = "grids"
                        ) -> List[Finding]:
    """Host-side A001 over a spec population: specs that differ ONLY in
    their quality knob must share a batching static_key (one compile per
    structural group). A knob field leaking into `static_key` would give
    every grid point its own compile -- the PR 3 recompile storm. Pure
    host-side dict/tuple work; nothing traces. The `harness.run_specs`
    lint hook runs this over the caller's actual specs."""
    from repro.core import batching, harness
    from repro.core.perforation import FRACTION_KINDS
    from repro.core.types import Technique

    findings = []
    groups: Dict[str, set] = {}
    for spec in specs:
        d = harness.spec_to_dict(spec)
        key = batching.static_key(spec)
        tech = spec.technique
        fraction_perfo = (tech == Technique.PERFORATION
                          and spec.perforation.kind in FRACTION_KINDS)
        if tech in (Technique.TAF, Technique.IACT) or fraction_perfo:
            if key is None:
                findings.append(Finding(
                    "A001", Severity.ERROR,
                    f"{subject_prefix}.{tech.value}",
                    "spec has a traced quality knob but no batching "
                    "static_key: it falls out of the grouped runner and "
                    "compiles per grid point", {"spec": d}))
                continue
            stripped = json.dumps(
                {k: v for k, v in d.items() if k not in _KNOB_FIELDS},
                sort_keys=True)
            groups.setdefault(stripped, set()).add(key)
    for stripped, keys in groups.items():
        if len(keys) > 1:
            findings.append(Finding(
                "A001", Severity.ERROR, f"{subject_prefix}.static_key",
                "specs differing only in their quality knob map to "
                "DIFFERENT static keys: the knob leaks into the compiled "
                "structure", {"structural_group": stripped,
                              "keys": sorted(map(str, keys))}))
    return findings


def rule_a001(apps: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    if "kernels" in apps:
        findings += _probe_targets(targets_mod.kernel_knob_targets())
    if "regions" in apps:
        findings += _probe_targets(targets_mod.region_knob_targets())
    if "ffn" in apps:
        findings += check_spec_grouping(targets_mod.default_grids())
    if "decode" in apps:
        findings += _probe_targets([targets_mod.serve_knob_target()])
    return findings


# --------------------------------------------------------------------------
# A002 -- substrate / kernel misconfiguration
# --------------------------------------------------------------------------

def _check_kernel_configs() -> List[Finding]:
    findings = []
    for t in targets_mod.kernel_trace_targets():
        try:
            fn, args = t.build()
            jax.make_jaxpr(fn)(*args)
        except Exception as e:  # noqa: BLE001
            findings.append(Finding(
                "A002", Severity.ERROR, t.subject,
                "kernel fails to trace at its registered config "
                "(scalar-prefetch arity / BlockSpec / divisibility)",
                {"error": f"{type(e).__name__}: {e}"[:500]}))
    return findings


def _check_ffn_geometry() -> List[Finding]:
    findings = []
    try:
        geo = targets_mod.ffn_geometry()
    except Exception as e:  # noqa: BLE001
        return [Finding("A002", Severity.WARNING, "ffn.geometry",
                        "approx_ffn app unimportable; geometry unchecked",
                        {"error": f"{type(e).__name__}: {e}"[:300]})]
    seq = geo["seq"]
    for name in ("block_m", "block_rows", "block_attn"):
        if seq % geo[name]:
            findings.append(Finding(
                "A002", Severity.ERROR, f"ffn.geometry.{name}",
                f"app sequence length {seq} is not divisible by "
                f"{name}={geo[name]}: the Pallas path asserts at run time",
                {"seq": seq, name: geo[name]}))
    return findings


def _check_benchmarks_wiring() -> List[Finding]:
    import inspect
    import sys
    root = _repo_root()
    for p in (root, os.path.join(root, "examples")):
        if p not in sys.path:
            sys.path.insert(0, p)
    try:
        from benchmarks import run as bench_run
    except Exception as e:  # noqa: BLE001
        return [Finding("A002", Severity.INFO, "benchmarks.run",
                        "benchmarks package unimportable from here; "
                        "wiring unchecked",
                        {"error": f"{type(e).__name__}: {e}"[:300]})]
    findings = []
    support = bench_run.substrate_support()
    for key, mod in bench_run.MODULES.items():
        if key not in support:
            findings.append(Finding(
                "A002", Severity.ERROR, f"benchmarks.{key}",
                "module registered in MODULES but missing from the "
                "substrate_support table", {}))
            continue
        declares = "substrate" in inspect.signature(mod.main).parameters
        if key == "kernel":
            if support[key] != {"pallas"}:
                findings.append(Finding(
                    "A002", Severity.ERROR, "benchmarks.kernel",
                    "kernel_micro is pallas-native; its support entry "
                    "must be exactly {'pallas'}",
                    {"entry": sorted(support[key])}))
        elif declares and support[key] != {"host", "pallas"}:
            findings.append(Finding(
                "A002", Severity.ERROR, f"benchmarks.{key}",
                "module's main() accepts substrate= but the support "
                "table does not offer both substrates",
                {"entry": sorted(support[key])}))
        elif not declares and support[key] != {"host"}:
            findings.append(Finding(
                "A002", Severity.ERROR, f"benchmarks.{key}",
                "module's main() has no substrate parameter but the "
                "support table claims substrate choice",
                {"entry": sorted(support[key])}))
    base_dir = os.path.join(root, "benchmarks", "baselines")
    for bf in sorted(glob_mod.glob(os.path.join(base_dir, "BENCH_*.json"))):
        name = os.path.basename(bf)
        if name not in bench_run._BASELINE_CHECKS:
            findings.append(Finding(
                "A002", Severity.ERROR, f"benchmarks.baselines.{name}",
                "committed baseline has no check rules in "
                "_BASELINE_CHECKS: --check-regression would fail on it",
                {"path": bf}))
    for name in bench_run._BASELINE_CHECKS:
        if not os.path.exists(os.path.join(base_dir, name)):
            findings.append(Finding(
                "A002", Severity.WARNING, f"benchmarks.baselines.{name}",
                "check rules registered but no committed baseline file",
                {"expected": os.path.join(base_dir, name)}))
    return findings


def _check_tuning_cache() -> List[Finding]:
    """Audit the committed block-shape tuning cache (`kernels/tuning.py`).

    A cache entry is a shipped claim -- "this block shape is the measured
    winner for this workload on this machine" -- and claims rot: a kernel's
    search space changes, a machine profile is renamed, or someone
    hand-edits a JSON entry whose block no longer divides the recorded
    operand geometry. `kernels.ops` would silently run such an entry into
    a runtime ValueError (or, worse, a stale-machine entry would never be
    consulted again while still looking authoritative in review)."""
    from repro.analysis.machine import MACHINES, MEASURED_MACHINE, \
        SUBSTRATE_MACHINES
    from repro.kernels import tuning

    path = tuning.default_cache_path()
    if path is None or not os.path.exists(path):
        return []  # nothing committed/configured: nothing to audit
    sub = f"tuning_cache:{path}"
    try:
        cache = tuning.TuningCache.load(path)
    except Exception as e:  # noqa: BLE001
        return [Finding("A002", Severity.ERROR, sub,
                        "tuning cache unreadable",
                        {"error": f"{type(e).__name__}: {e}"[:300]})]
    # machines the substrate table (plus any statically registered
    # profile) can ever produce as a cache key; "measured" is session-
    # local by design and must never be a committed key
    known = (set(SUBSTRATE_MACHINES.values())
             | (set(MACHINES) - {MEASURED_MACHINE}))
    findings = []
    for key, entry in sorted(cache.entries.items()):
        esub = f"{sub}#{key}"
        err = tuning.validate_entry(key, entry)
        if err:
            findings.append(Finding(
                "A002", Severity.ERROR, esub,
                "tuning-cache entry is invalid (stale or hand-edited): "
                + err, {"entry": entry}))
            continue
        machine = entry.get("machine", "")
        if machine not in known:
            findings.append(Finding(
                "A002", Severity.ERROR, esub,
                f"tuning-cache entry keyed on machine {machine!r}, which "
                "no substrate maps to (stale vs SUBSTRATE_MACHINES): the "
                "entry can never be consulted",
                {"machine": machine, "known": sorted(known)}))
    return findings


def rule_a002(apps: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    if "kernels" in apps:
        findings += _check_kernel_configs()
        findings += _check_tuning_cache()
    if "ffn" in apps:
        findings += _check_ffn_geometry()
        findings += _check_benchmarks_wiring()
    return findings


# --------------------------------------------------------------------------
# A003 -- unsafe approximation sink
# --------------------------------------------------------------------------

def _taint_one(t: targets_mod.TraceTarget) -> List[Finding]:
    try:
        fn, args = t.build()
        closed = jax.make_jaxpr(fn)(*args)
    except Exception as e:  # noqa: BLE001
        return [Finding("A003", Severity.WARNING, t.subject,
                        "taint target failed to trace",
                        {"error": f"{type(e).__name__}: {e}"[:500]})]
    positions = targets_mod.tainted_positions(args, t.tainted)
    if not positions:
        return [Finding("A003", Severity.WARNING, t.subject,
                        "no tainted source leaves matched "
                        f"{t.tainted}: the walk checked nothing", {})]
    sinks = taint_mod.find_taint_sinks(closed, positions)
    return [Finding(
        "A003", Severity.ERROR, f"{t.subject}{s.path}",
        f"approximate value reaches a {s.kind} (`{s.primitive}`) with no "
        "precise fallback: a 1-ulp error becomes a discontinuous "
        "program change",
        {"eqn": s.eqn_repr, "sources": list(t.tainted)}) for s in sinks]


def rule_a003(apps: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    if "regions" in apps:
        for t in targets_mod.region_taint_targets():
            findings += _taint_one(t)
    if "decode" in apps:
        findings += _taint_one(targets_mod.serve_taint_target())
    return findings


# --------------------------------------------------------------------------
# A004 -- QoS ladder validity (raw saved-policy files)
# --------------------------------------------------------------------------

def check_policy_file(path: str,
                      model_taf: Optional[Tuple[int, int]] = None
                      ) -> List[Finding]:
    """Lint ONE saved QosPolicy file, on its RAW entries. `QosPolicy.load`
    re-normalizes the ladder on construction, so a broken file silently
    self-heals at load time -- which is exactly why the linter must read
    the JSON, not the loaded object: a policy that needs healing is a
    policy whose shipped artifact misdescribes what will run."""
    sub = f"policy:{path}"
    try:
        with open(path) as f:
            doc = json.load(f)
    except Exception as e:  # noqa: BLE001
        return [Finding("A004", Severity.ERROR, sub,
                        "policy file unreadable",
                        {"error": f"{type(e).__name__}: {e}"[:300]})]
    return check_policy_document(doc, subject=sub, model_taf=model_taf)


def check_policy_document(doc: Dict, *, subject: str = "policy",
                          model_taf: Optional[Tuple[int, int]] = None
                          ) -> List[Finding]:
    """The A004 ladder checks over a policy JSON document (the
    `QosPolicy.to_json` schema). Shared by the file pass above and the
    `ServingEngine(lint=True)` hook."""
    from repro.core.harness import spec_from_dict, spec_hash
    from repro.qos.policy import spec_knob

    sub = subject
    entries = doc.get("entries", [])
    if not entries:
        return [Finding("A004", Severity.ERROR, sub,
                        "policy has no entries (not even the precise rung)",
                        {})]
    use_modeled = bool(doc.get("use_modeled", False))
    perf_key = "modeled_speedup" if use_modeled else "speedup"
    findings: List[Finding] = []

    e0 = entries[0]
    if e0.get("spec", {}).get("technique", "none") != "none" or \
            e0.get("error", 1.0) != 0.0 or e0.get(perf_key, 0.0) != 1.0:
        findings.append(Finding(
            "A004", Severity.ERROR, f"{sub}#rung0",
            "rung 0 must be the precise anchor (technique none, error 0, "
            "speedup 1): the controller's hard fallback lands here",
            {"rung0": e0}))

    seen_hash: Dict[str, int] = {}
    structural: Dict[Tuple[int, int], List[int]] = {}
    for i, e in enumerate(entries):
        rsub = f"{sub}#rung{i}"
        spec_d = e.get("spec", {})
        err, perf = e.get("error"), e.get(perf_key)
        precise = spec_d.get("technique", "none") == "none"
        if i > 0 and precise:
            findings.append(Finding(
                "A004", Severity.ERROR, rsub,
                "precise spec on a non-zero rung (duplicate anchor)", {}))
        if i > 0 and isinstance(perf, (int, float)) and perf <= 1.0:
            findings.append(Finding(
                "A004", Severity.ERROR, rsub,
                "rung pays quality for <= 1x speedup: dominated by the "
                "precise rung", {"error": err, perf_key: perf}))
        stored = e.get("spec_hash", "")
        actual = spec_hash(spec_d)
        if stored and stored != actual:
            findings.append(Finding(
                "A004", Severity.ERROR, rsub,
                "stored spec_hash does not match the spec (stale or "
                "hand-edited entry)",
                {"stored": stored, "recomputed": actual}))
        if actual in seen_hash:
            findings.append(Finding(
                "A004", Severity.ERROR, rsub,
                f"duplicate spec (same spec_hash as rung "
                f"{seen_hash[actual]})", {"spec_hash": actual}))
        else:
            seen_hash[actual] = i
        try:
            spec = spec_from_dict(spec_d)
            spec_knob(spec)
        except Exception as ex:  # noqa: BLE001
            findings.append(Finding(
                "A004", Severity.ERROR, rsub,
                "spec is unparseable or has no online-actuable knob",
                {"error": f"{type(ex).__name__}: {ex}"[:300],
                 "spec": spec_d}))
            continue
        if spec_d.get("technique") == "taf":
            structural.setdefault(
                (int(spec_d.get("hSize", -1)), int(spec_d.get("pSize", -1))),
                []).append(i)

    for i in range(1, len(entries)):
        for j in range(i + 1, len(entries)):
            ei, ej = entries[i], entries[j]
            erri, errj = ei.get("error"), ej.get("error")
            pi, pj = ei.get(perf_key), ej.get(perf_key)
            if None in (erri, errj, pi, pj):
                continue
            if errj >= erri and pj <= pi:
                findings.append(Finding(
                    "A004", Severity.ERROR, f"{sub}#rung{j}",
                    f"rung dominated by rung {i} (more error, no more "
                    "speedup): 'one rung away is strictly faster' breaks",
                    {"rung": {"error": errj, perf_key: pj},
                     "dominator": {"error": erri, perf_key: pi}}))
            elif errj <= erri:
                findings.append(Finding(
                    "A004", Severity.ERROR, f"{sub}#rung{j}",
                    f"ladder not ascending in error after rung {i}: "
                    "'one rung toward 0 is strictly quality-improving' "
                    "breaks",
                    {"errors": [erri, errj]}))

    if len(structural) > 1:
        findings.append(Finding(
            "A004", Severity.ERROR, f"{sub}#ladder",
            "TAF rungs disagree on structural (history, prediction) "
            "params: they describe different stability detectors",
            {"groups": {str(k): v for k, v in structural.items()}}))
    if model_taf is not None and structural:
        mism = {k: v for k, v in structural.items() if k != tuple(model_taf)}
        if mism:
            findings.append(Finding(
                "A004", Severity.ERROR, f"{sub}#ladder",
                f"TAF rungs calibrated under structural params "
                f"{sorted(mism)} but the target model runs "
                f"{tuple(model_taf)}: offline error misdescribes the "
                "running decode step",
                {"rungs": sorted(v2 for v in mism.values() for v2 in v)}))
    return findings


def rule_a004(policy_paths: Sequence[str],
              model_taf: Optional[Tuple[int, int]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for path in policy_paths:
        findings += check_policy_file(path, model_taf=model_taf)
    return findings


# --------------------------------------------------------------------------
# A005 -- sharding placement
# --------------------------------------------------------------------------

def check_engine_placement(engine) -> List[Finding]:
    """Audit every leaf entering the engine's sharded serve step for mesh
    commitment. Uncommitted leaves make pjit re-shard (and possibly
    recompile) EVERY tick -- the PR 6 data-plane bug, generalized."""
    from jax.sharding import NamedSharding

    if engine.mesh is None:
        return []
    findings = []
    surfaces = {"params": engine.params, "cache": engine.cache,
                "tokens": engine.tokens}
    for name, tree in surfaces.items():
        if tree is None:
            continue
        bad = []
        for path, leaf in targets_mod.leaf_paths(tree):
            if not hasattr(leaf, "sharding"):
                continue
            sh = leaf.sharding
            if not (isinstance(sh, NamedSharding)
                    and sh.mesh.shape == engine.mesh.shape
                    and sh.mesh.axis_names == engine.mesh.axis_names):
                bad.append((path, type(sh).__name__))
        if bad:
            findings.append(Finding(
                "A005", Severity.ERROR, f"serving.engine.{name}",
                f"{len(bad)} leaf/leaves enter the shard_map'd serve step "
                "without mesh commitment: pjit re-shards them every tick",
                {"leaves": bad[:8],
                 "mesh": dict(engine.mesh.shape)}))
    return findings


def rule_a005(apps: Sequence[str]) -> List[Finding]:
    if "decode" not in apps:
        return []
    try:
        engine = targets_mod.engine_fixture()
    except Exception as e:  # noqa: BLE001
        return [Finding("A005", Severity.WARNING, "serving.engine",
                        "engine fixture failed to build; placement "
                        "unchecked",
                        {"error": f"{type(e).__name__}: {e}"[:500]})]
    return check_engine_placement(engine)


# --------------------------------------------------------------------------
# A006 -- ladder rung with predicted sub-1x speedup
# --------------------------------------------------------------------------

def check_policy_cost(doc: Dict, *, subject: str = "policy",
                      machine=None) -> List[Finding]:
    """The A006 pass over a policy JSON document: run every rung's spec
    through the analytical cost model (`repro.analysis.cost`) on the
    target machine and flag rungs whose PREDICTED speedup is sub-1x.

    A004 catches rungs whose *measured* numbers are dominated; A006
    catches the rungs nobody measured yet -- e.g. an iACT rung whose
    table-probe overhead (tSize * 3 * in_dim FLOPs per decision) exceeds
    the region it memoizes. Those rungs burn quality for a slowdown on
    the target substrate and should never ship."""
    from repro.analysis import cost as cost_mod
    from repro.core.harness import spec_from_dict

    model = cost_mod.ladder_model(machine or doc.get("substrate"))
    findings: List[Finding] = []
    for i, e in enumerate(doc.get("entries", [])):
        spec_d = e.get("spec", {})
        if spec_d.get("technique", "none") == "none":
            continue
        try:
            spec = spec_from_dict(spec_d)
        except Exception:  # noqa: BLE001 -- unparseable spec is A004's job
            continue
        pred = model.predict(spec)
        if pred.modeled and pred.speedup <= 1.0:
            findings.append(Finding(
                "A006", Severity.ERROR, f"{subject}#rung{i}",
                f"rung's predicted speedup on {model.machine.name} is "
                f"{pred.speedup:.3f}x (<= 1x): the technique's overhead "
                "exceeds the work it can skip -- the rung trades quality "
                "for a slowdown",
                {"spec": spec_d, "predicted_speedup": pred.speedup,
                 "skip_fraction": pred.skip_fraction,
                 "machine": model.machine.name}))
    return findings


def rule_a006(policy_paths: Sequence[str], machine=None) -> List[Finding]:
    findings: List[Finding] = []
    for path in policy_paths:
        sub = f"policy:{path}"
        try:
            with open(path) as f:
                doc = json.load(f)
        except Exception:  # noqa: BLE001 -- A004 reports unreadable files
            continue
        findings += check_policy_cost(doc, subject=sub, machine=machine)
    return findings


# --------------------------------------------------------------------------
# A007 -- error amplifies unboundedly through a loop carry
# --------------------------------------------------------------------------

def check_divergence(fn, example_args, tainted: Sequence[str],
                     subject: str) -> List[Finding]:
    """Inject unit relative error at the approximate-value leaves and
    propagate it through the traced jaxpr (`repro.analysis.errorprop`).
    A `while` carry whose per-iteration error gain stays > 1 at the
    fixpoint is statically divergent: the loop runs until a data-dependent
    condition, so no finite bound exists -- the paper's MiniFE pathology
    ('locally introduced errors propagate through subsequent iterations')
    lifted to lint time."""
    import jax

    from repro.analysis import errorprop

    closed = jax.make_jaxpr(fn)(*example_args)
    positions = targets_mod.tainted_positions(example_args, tainted)
    if not positions:
        return [Finding("A007", Severity.WARNING, subject,
                        "no tainted input leaves matched; divergence "
                        "unchecked", {"needles": list(tainted)})]
    findings = []
    for rep in errorprop.find_divergent_carries(closed, positions):
        findings.append(Finding(
            "A007", Severity.ERROR, subject,
            f"approximation error amplifies unboundedly through a "
            f"{rep.kind} carry (per-iteration gain {rep.gain:.3g} > 1, "
            "no static trip bound): locally small residuals diverge "
            "through subsequent iterations",
            {"loop": rep.to_json()}))
    return findings


def rule_a007(apps: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    tt = []
    if "regions" in apps:
        tt += targets_mod.region_taint_targets()
    if "decode" in apps:
        tt.append(targets_mod.serve_taint_target())
    for t in tt:
        fn, example_args = t.build()
        findings += check_divergence(fn, example_args, t.tainted, t.subject)
    return findings


# --------------------------------------------------------------------------
# A008 -- instrumentation safety (obs hooks in jitted hot paths)
# --------------------------------------------------------------------------

def check_instrumentation_safety(fn, example_args, subject: str
                                 ) -> List[Finding]:
    """Audit `fn`'s obs instrumentation by tracing it under an ACTIVE
    tracer (scoped; the caller's tracer is restored).

    Two failure modes, both of which silently destroy the serving plane's
    zero-sync contract (docs/observability.md):

      * the trace aborts with a ConcretizationTypeError -- an obs hook
        (or anything it calls) forces a traced value to a concrete host
        value (`float()`, `np.asarray`, bool coercion) INSIDE the jitted
        region: under jit that is a device->host transfer per call, and
        under `jax.jit` tracing it is an outright error;
      * the trace succeeds but an event/span payload captured a
        `jax.core.Tracer` -- legal at trace time, but the payload escapes
        to the Python-side record buffer, so serializing or even printing
        the trace later concretizes abstract values (crash) and, had the
        hook read it eagerly, would have synced the device every call.

    Because `repro.obs.trace` stores payloads AS GIVEN (never coerced),
    the probe sees exactly what leaked.
    """
    from repro.obs import trace as obs_trace

    tracer = obs_trace.Tracer()
    try:
        with obs_trace.use(tracer):
            jax.make_jaxpr(fn)(*example_args)
    except jax.errors.ConcretizationTypeError as e:
        return [Finding(
            "A008", Severity.ERROR, subject,
            "instrumentation concretizes a traced value inside the jitted "
            "region: a device->host transfer on every call",
            {"error": f"{type(e).__name__}: {e}"[:500]})]
    except Exception as e:  # noqa: BLE001
        return [Finding("A008", Severity.WARNING, subject,
                        "instrumentation-safety target failed to trace",
                        {"error": f"{type(e).__name__}: {e}"[:500]})]
    findings: List[Finding] = []
    for rec in tracer.records:
        for k, v in (rec.get("args") or {}).items():
            for leaf in jax.tree_util.tree_leaves(v):
                if isinstance(leaf, jax.core.Tracer):
                    findings.append(Finding(
                        "A008", Severity.ERROR,
                        f"{subject}.{rec['name']}",
                        f"obs payload {k!r} captures a traced value: the "
                        "abstract tracer escapes to the host-side event "
                        "buffer (device sync per call once read, crash on "
                        "export)",
                        {"event": rec["name"], "key": k,
                         "aval": str(getattr(leaf, "aval", ""))[:200]}))
    return findings


def rule_a008(apps: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    tt: List[targets_mod.TraceTarget] = []
    if "kernels" in apps:
        tt += targets_mod.kernel_trace_targets()
    if "decode" in apps:
        tt.append(targets_mod.serve_taint_target())
    for t in tt:
        try:
            fn, example_args = t.build()
        except Exception as e:  # noqa: BLE001
            findings.append(Finding(
                "A008", Severity.WARNING, t.subject,
                "instrumentation-safety target failed to build",
                {"error": f"{type(e).__name__}: {e}"[:500]}))
            continue
        findings += check_instrumentation_safety(fn, example_args,
                                                 t.subject)
    return findings


RULE_IDS = ("A001", "A002", "A003", "A004", "A005", "A006", "A007",
            "A008")
