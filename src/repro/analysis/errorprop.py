"""Interval/affine-form error propagation over jaxprs (the QoI half of
the approxcost predictor, and the engine behind lint rule A007).

Each variable carries ONE abstract value: a bound on its *relative* error
(first-order affine form: the error term's coefficient, with magnitudes
normalized out).  Approximation sites inject an initial bound -- a TAF
rung's threshold residual, an iACT distance residual, a perforation mask's
dropped mass -- and the walk pushes it through every primitive to the
program outputs.  The per-primitive transfer functions are first-order
relative-error algebra with conservative headroom constants:

  * mul / div          : errors ADD (exact to first order);
  * add / sub / dot    : relative error can grow under cancellation --
                         bounded by ``CANCEL_AMP`` (model assumption:
                         operands are not pathologically cancelling);
  * transcendentals    : bounded condition number ``TRANS_AMP``;
  * select / where     : max over the data branches (a flipped predicate
                         is a control-flow discontinuity -- rule A003's
                         domain, not an error-magnitude event);
  * comparisons, argmax, iota, integer ops: exact (relative error 0);
  * anything unknown   : ``DEFAULT_AMP`` x the worst input.

Loop carries (`scan` / `while`) run to a FIXPOINT exactly like
`taint.py`'s walk: the carry's error vector is iterated through the body
until it stabilizes.  A `scan` that fails to stabilize still has a finite
trip count, so the bound closes as ``err * gain^length`` (geometric -- bad,
but bounded).  A `while` whose carry error grows per iteration has NO
static trip bound: the injected error amplifies unboundedly, which is
exactly the paper's MiniFE pathology ("locally introduced errors propagate
through subsequent iterations") made statically detectable.  Those loops
are reported as divergent -- lint rule A007.

Everything here is structural: nothing executes, bounds hold under the
documented headroom assumptions (see docs/analysis.md "Cost & error
model").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

from jax import core as jcore

try:  # jax >= 0.4.x moved Literal around; import defensively
    Literal = jcore.Literal
except AttributeError:  # pragma: no cover
    from jax._src.core import Literal  # type: ignore

# Headroom constants (model assumptions, documented in docs/analysis.md).
CANCEL_AMP = 4.0    # additive cancellation headroom (add/sub/dot/reduce)
TRANS_AMP = 4.0     # transcendental condition-number headroom
DEFAULT_AMP = 4.0   # unknown-primitive fallback

_ERR_CAP = 1e30     # saturation value for divergent bounds
_MAX_FIX_ITERS = 40  # fixpoint iterations before declaring growth
_GROWTH_EPS = 1e-9   # relative growth below this counts as converged

# first-order-exact multiplicative primitives: errors add
_MUL_LIKE = {"mul", "div", "atan2", "nextafter"}
# additive / linear-combination primitives: cancellation headroom applies
_ADD_LIKE = {"add", "sub", "add_any", "complex"}
# contractions: (ra + rb) with cancellation headroom over the sum
_DOT_LIKE = {"dot_general", "conv_general_dilated"}
# bounded-condition-number nonlinearities
_TRANS = {"exp", "exp2", "expm1", "log", "log1p", "tanh", "erf", "erfc",
          "erf_inv", "rsqrt", "sqrt", "cbrt", "sin", "cos", "tan", "asin",
          "acos", "atan", "sinh", "cosh", "asinh", "acosh", "atanh",
          "logistic", "pow", "integer_pow", "regularized_incomplete_beta",
          "lgamma", "digamma", "square"}
# error-preserving data movement / selection: max over float-ish inputs
_PASS = {"neg", "abs", "real", "imag", "conj", "copy", "convert_element_type",
         "broadcast_in_dim", "reshape", "transpose", "squeeze", "rev",
         "slice", "dynamic_slice", "dynamic_update_slice", "concatenate",
         "pad", "gather", "scatter", "scatter-add", "scatter_add",
         "expand_dims", "tie_in", "stop_gradient", "reduce_sum",
         "reduce_max", "reduce_min", "cumsum", "cummax", "cummin",
         "reduce_precision", "max", "min", "clamp", "select_n", "select",
         "where", "sort", "top_k", "optimization_barrier", "copy_p",
         "device_put", "sharding_constraint", "reduce_mean", "mean",
         "transpose_p", "rem"}
# exact / discrete outputs: relative error 0 (discontinuities are A003's
# domain; discrete QoI error is the harness's MCR metric, not a bound here)
_EXACT = {"eq", "ne", "lt", "le", "gt", "ge", "and", "or", "xor", "not",
          "sign", "floor", "ceil", "round", "is_finite", "iota", "argmax",
          "argmin", "reduce_and", "reduce_or", "shift_left",
          "shift_right_logical", "shift_right_arithmetic", "population_count",
          "clz", "rng_bit_generator", "random_seed", "random_bits",
          "random_wrap", "random_fold_in", "threefry2x32", "eq_to", "nan"}


@dataclasses.dataclass(frozen=True)
class LoopReport:
    """One scan/while whose carry the injected error reaches."""

    kind: str        # "scan" | "while"
    path: str        # subjaxpr path, e.g. "pjit/while.body"
    gain: float      # per-iteration amplification of the carry error
    diverges: bool   # while-loop carry with gain > 1: statically unbounded
    eqn_repr: str

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class ErrorReport:
    """Propagation result: per-output relative-error bounds + loop audit."""

    out_rel: List[float]
    loops: List[LoopReport]

    @property
    def bound(self) -> float:
        """Worst output bound (inf when a divergent while is on the path)."""
        worst = max(self.out_rel, default=0.0)
        if any(lp.diverges for lp in self.loops):
            return math.inf
        return worst

    @property
    def divergent(self) -> List[LoopReport]:
        return [lp for lp in self.loops if lp.diverges]


def _in_rels(eqn, rel: Dict) -> List[float]:
    return [0.0 if isinstance(v, Literal) else rel.get(v, 0.0)
            for v in eqn.invars]


def _transfer(name: str, rels: Sequence[float]) -> float:
    """Relative-error bound of an eqn's outputs from its inputs' bounds."""
    worst = max(rels, default=0.0)
    if worst == 0.0:
        return 0.0
    if name in _EXACT:
        return 0.0
    if name in _MUL_LIKE:
        return min(sum(rels), _ERR_CAP)
    if name in _ADD_LIKE:
        return min(worst * CANCEL_AMP, _ERR_CAP)
    if name in _DOT_LIKE:
        return min(sum(rels) * CANCEL_AMP, _ERR_CAP)
    if name in _TRANS:
        return min(worst * TRANS_AMP, _ERR_CAP)
    if name in _PASS:
        return worst
    return min(worst * DEFAULT_AMP, _ERR_CAP)


def _sub_rel(inner_invars, outer_invars, rel: Dict) -> Dict:
    out: Dict = {}
    for iv, ov in zip(inner_invars, outer_invars):
        if not isinstance(ov, Literal):
            r = rel.get(ov, 0.0)
            if r:
                out[iv] = r
    return out


def _bind_out(eqn, out_rels: Sequence[float], rel: Dict) -> None:
    for ov, r in zip(eqn.outvars, out_rels):
        if r and not isinstance(ov, Literal):
            rel[ov] = max(rel.get(ov, 0.0), min(r, _ERR_CAP))


def _fixpoint(body_jaxpr, const_rels: Dict, carry0: List[float],
              x_rels: Dict, n_carry: int, carry_offset: int, path: str,
              loops: List[LoopReport]):
    """Iterate a loop body's carry error to a fixpoint.

    Returns (carry_final, other_out_rels, gain, converged): `carry_final`
    the stabilized (or last) carry bounds, `other_out_rels` the non-carry
    outputs from the final pass, `gain` the max per-iteration growth ratio
    observed on the last step, `converged` whether the carry stabilized
    within the iteration budget.
    """
    carry = list(carry0)
    gain = 1.0
    outs: List[float] = [0.0] * len(body_jaxpr.outvars)
    for _ in range(_MAX_FIX_ITERS):
        rel = dict(const_rels)
        rel.update(x_rels)
        outs = _walk_body(body_jaxpr, rel, carry, carry_offset, path, loops)
        new_carry = [max(c, o) for c, o in zip(carry, outs[:n_carry])]
        grew = [(n, c) for n, c in zip(new_carry, carry)
                if n > c * (1.0 + _GROWTH_EPS) + 1e-300]
        if not grew:
            return new_carry, outs[n_carry:], gain, True
        gain = max((n / c if c > 0 else math.inf) for n, c in grew)
        carry = new_carry
    return carry, outs[n_carry:], gain, False


def _walk_body(body_jaxpr, rel: Dict, carry: Sequence[float],
               carry_offset: int, path: str,
               loops: List[LoopReport]) -> List[float]:
    """One pass of a loop body with the carry slots bound to `carry`.
    Consts and xs were pre-bound into `rel` by the caller; the carry vars
    start at `carry_offset` (right after the body consts). Returns all
    outvar rels."""
    for i, c in enumerate(carry):
        v = body_jaxpr.invars[carry_offset + i]
        if c:
            rel[v] = c
    return _walk(body_jaxpr, rel, path, loops)


def _walk(jaxpr, rel: Dict, path: str, loops: List[LoopReport]
          ) -> List[float]:
    """Propagate relative-error bounds through one (open) jaxpr. `rel`
    maps this scope's Vars to bounds; returns per-outvar bounds."""
    rel = dict(rel)
    for eqn in jaxpr.eqns:
        rels = _in_rels(eqn, rel)
        name = eqn.primitive.name

        if name in ("cond", "switch"):
            branches = eqn.params.get("branches", ())
            outs = [0.0] * len(eqn.outvars)
            for br in branches:
                inner = br.jaxpr
                sub = _sub_rel(inner.invars, eqn.invars[1:], rel)
                bouts = _walk(inner, sub, f"{path}/cond", loops)
                outs = [max(a, b) for a, b in zip(outs, bouts)]
            _bind_out(eqn, outs, rel)
            continue

        if name in ("pjit", "closed_call", "core_call", "xla_call",
                    "custom_jvp_call", "custom_vjp_call", "remat", "remat2",
                    "checkpoint", "custom_vjp_call_jaxpr"):
            closed = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if closed is not None:
                inner = getattr(closed, "jaxpr", closed)
                sub = _sub_rel(inner.invars, eqn.invars, rel)
                outs = _walk(inner, sub, f"{path}/{name}", loops)
                _bind_out(eqn, outs, rel)
                continue

        if name == "while":
            cj = eqn.params["cond_jaxpr"].jaxpr
            bj = eqn.params["body_jaxpr"].jaxpr
            cn = eqn.params["cond_nconsts"]
            bn = eqn.params["body_nconsts"]
            n_carry = len(eqn.invars) - cn - bn
            carry0 = [0.0 if isinstance(v, Literal) else rel.get(v, 0.0)
                      for v in eqn.invars[cn + bn:]]
            const_rels = _sub_rel(bj.invars[:bn], eqn.invars[cn:cn + bn],
                                  rel)
            carry, _, gain, converged = _fixpoint(
                bj, const_rels, carry0, {}, n_carry, bn,
                f"{path}/while.body", loops)
            injected = any(c > 0 for c in carry0) or bool(const_rels)
            if injected:
                diverges = not converged and gain > 1.0 + _GROWTH_EPS
                loops.append(LoopReport(
                    kind="while", path=path or "/",
                    gain=float(gain if not converged else 1.0),
                    diverges=diverges, eqn_repr=str(eqn)[:200]))
                if diverges:
                    carry = [_ERR_CAP if c > 0 else c for c in carry]
            _bind_out(eqn, carry, rel)
            continue

        if name == "scan":
            closed = eqn.params["jaxpr"]
            inner = closed.jaxpr
            nc = eqn.params["num_consts"]
            ncar = eqn.params["num_carry"]
            length = int(eqn.params.get("length", 1) or 1)
            carry0 = [0.0 if isinstance(v, Literal) else rel.get(v, 0.0)
                      for v in eqn.invars[nc:nc + ncar]]
            const_rels = _sub_rel(inner.invars[:nc], eqn.invars[:nc], rel)
            x_rels = _sub_rel(inner.invars[nc + ncar:],
                              eqn.invars[nc + ncar:], rel)
            carry, ys, gain, converged = _fixpoint(
                inner, const_rels, carry0, x_rels, ncar, nc,
                f"{path}/scan", loops)
            injected = (any(c > 0 for c in carry0) or bool(const_rels)
                        or bool(x_rels))
            if injected and not converged:
                # finite trip count: geometric but bounded, err * gain^L
                grow = min(gain ** max(length - _MAX_FIX_ITERS, 0), _ERR_CAP)
                carry = [min(c * grow, _ERR_CAP) for c in carry]
                ys = [min(y * grow, _ERR_CAP) for y in ys]
                loops.append(LoopReport(
                    kind="scan", path=path or "/", gain=float(gain),
                    diverges=False, eqn_repr=str(eqn)[:200]))
            _bind_out(eqn, list(carry) + list(ys), rel)
            continue

        out = _transfer(name, rels)
        _bind_out(eqn, [out] * len(eqn.outvars), rel)

    return [0.0 if isinstance(ov, Literal) else rel.get(ov, 0.0)
            for ov in jaxpr.outvars]


def propagate(closed_jaxpr, inject: Dict[int, float]) -> ErrorReport:
    """Propagate injected relative-error bounds through a ClosedJaxpr.

    `inject` maps input POSITIONS to relative-error bounds (the
    approximation-site residuals).  Returns per-output bounds plus a
    report of every loop the error flowed through -- `while` loops whose
    carry amplifies per iteration are flagged divergent (A007). Purely
    structural: nothing executes.
    """
    jaxpr = closed_jaxpr.jaxpr
    rel: Dict = {}
    for pos, r in inject.items():
        if r:
            rel[jaxpr.invars[pos]] = float(r)
    loops: List[LoopReport] = []
    outs = _walk(jaxpr, rel, "", loops)
    # de-dup (fixpoint iterations can record the same loop twice)
    seen, uniq = set(), []
    for lp in loops:
        key = (lp.kind, lp.path, lp.eqn_repr, lp.diverges)
        if key not in seen:
            seen.add(key)
            uniq.append(lp)
    return ErrorReport(out_rel=outs, loops=uniq)


def amplification(fn, example_args, inject_positions: Sequence[int],
                  rel: float = 1.0) -> ErrorReport:
    """Trace `fn(*example_args)` and propagate a `rel` bound injected at
    the given argument positions. Convenience wrapper used by the cost
    model's site->QoI amplification factor and the A007 targets."""
    import jax
    closed = jax.make_jaxpr(fn)(*example_args)
    return propagate(closed, {p: rel for p in inject_positions})


def find_divergent_carries(closed_jaxpr,
                           inject_positions: Sequence[int]
                           ) -> List[LoopReport]:
    """A007 helper: while-loop carries that amplify an error injected at
    the given input positions without a static bound."""
    rep = propagate(closed_jaxpr, {p: 1.0 for p in inject_positions})
    return rep.divergent
