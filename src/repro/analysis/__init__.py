"""approxlint: jaxpr-level static analysis for approximation regions,
kernels, QoS ladders, and the serving data plane.

The HPAC-Offload compiler validates approximation directives before the
GPU runs them; this package is that stage for the JAX substrate. Rules:

  A001  recompile-leak: a quality knob shapes the compiled artifact
  A002  substrate/kernel misconfiguration
  A003  unsafe approximation sink (taint into control flow / indices)
  A004  QoS ladder validity (saved policy files)
  A005  sharding placement (uncommitted leaves into the sharded step)
  A006  ladder rung with predicted sub-1x speedup on the target machine
  A007  approximation error amplifying unboundedly through a loop carry

CLI: ``python -m repro.analysis.lint --apps all`` (docs/analysis.md).
Programmatic: `run_lint`; opt-in hooks: `harness.run_specs(lint=True)`,
`ServingEngine(..., lint=True)`.

The package also houses the analytical cost/error predictor the rules
lean on: `repro.analysis.machine` (named machine profiles),
`repro.analysis.cost` (FLOP/byte counting + speedup prediction), and
`repro.analysis.errorprop` (relative-error abstract interpretation).
"""
from .findings import Allowlist, Finding, Report, Severity  # noqa: F401

RULE_IDS = ("A001", "A002", "A003", "A004", "A005", "A006", "A007")


def __getattr__(name):
    # Lazy: `python -m repro.analysis.lint` imports this package first, and
    # an eager `from .lint import ...` here would both trigger runpy's
    # double-import warning and pull jax-heavy rule modules into callers
    # that only want the Finding/Report types.
    if name == "run_lint":
        from .lint import run_lint
        return run_lint
    if name in ("check_engine_placement", "check_policy_file",
                "check_policy_cost", "check_divergence"):
        from . import rules
        return getattr(rules, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
