"""The built-in lint targets: what approxlint analyzes out of the box.

Each target is the *smallest* configuration that exercises a lintable
surface -- tiny shapes, interpret-mode kernels, the smoke decode config --
because the rules only TRACE (``jax.make_jaxpr``); nothing here is sized
for throughput. Targets are grouped into named "apps" so the CLI's
``--apps`` flag can scope a run:

  kernels  -- the four Pallas kernels' quality knobs (A001) and their
              trace-time configuration (A002)
  regions  -- ApproxRegion step hooks + perforated_loop's fraction (A001)
              and their traced jaxprs (A003)
  ffn      -- the approx_ffn example app's block geometry (A002) and the
              default sweep grids' batching behavior (A001)
  decode   -- the serving decode step: knob tracing (A001), taint (A003),
              and engine mesh placement (A005). The only group that runs
              real (tiny) computation: A005 checks *placements*, which
              exist only on concrete arrays.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

APP_NAMES = ("kernels", "regions", "ffn", "decode")


@dataclasses.dataclass(frozen=True)
class KnobTarget:
    """One quality knob on one target: `build()` returns a function of a
    single scalar, traced by rules.probe (A001)."""

    subject: str
    build: Callable[[], Callable]
    values: Tuple[float, ...] = (0.25, 0.75)


@dataclasses.dataclass(frozen=True)
class TraceTarget:
    """A traceable program for structural rules (A002 config-trace, A003
    taint). `build()` returns (fn, example_args); `tainted` names the
    approximate-value leaves by path substring."""

    subject: str
    build: Callable[[], Tuple[Callable, tuple]]
    tainted: Tuple[str, ...] = ()


# --------------------------------------------------------------------------
# kernels
# --------------------------------------------------------------------------

def _kernel_data(m=16, k=16, n=16):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(m, k), jnp.float32)
    w = jnp.asarray(rng.randn(k, n), jnp.float32)
    return x, w


def kernel_knob_targets() -> List[KnobTarget]:
    from repro.core.types import PerforationKind, PerforationParams
    from repro.kernels import iact_memo, perforated_attention, \
        perforated_matmul, taf_matmul

    def taf():
        x, w = _kernel_data()
        return lambda th: taf_matmul.taf_matmul(
            x, w, block_m=8, block_n=8, history_size=2, prediction_size=2,
            rsd_threshold=th, interpret=True)

    def iact():
        x, _ = _kernel_data()
        rng = np.random.RandomState(1)
        w1 = jnp.asarray(rng.randn(16, 8), jnp.float32)
        w2 = jnp.asarray(rng.randn(8, 16), jnp.float32)
        return lambda th: iact_memo.iact_rowfn(
            x, w1, w2, block_rows=8, table_size=2, threshold=th,
            interpret=True)

    def attn():
        rng = np.random.RandomState(2)
        q = jnp.asarray(rng.randn(1, 1, 16, 8), jnp.float32)
        kv = jnp.asarray(rng.randn(1, 1, 16, 8), jnp.float32)
        p = PerforationParams(kind=PerforationKind.INI, fraction=0.0)
        return lambda f: perforated_attention.perforated_attention(
            q, kv, kv, block_q=8, block_kv=8, perfo=p, fraction=f,
            interpret=True)

    def pmm():
        x, w = _kernel_data()
        p = PerforationParams(kind=PerforationKind.INI, fraction=0.0)
        return lambda f: perforated_matmul.perforated_matmul(
            x, w, block_m=8, block_n=8, block_k=8, perfo=p, fraction=f,
            rescale=True, interpret=True)

    def pmm_structural():
        x, w = _kernel_data()

        def run(f):
            p = PerforationParams(kind=PerforationKind.INI,
                                  fraction=float(f))
            return perforated_matmul.perforated_matmul(
                x, w, block_m=8, block_n=8, block_k=8, perfo=p,
                interpret=True)

        return run

    def attn_structural():
        rng = np.random.RandomState(2)
        q = jnp.asarray(rng.randn(1, 1, 16, 8), jnp.float32)
        kv = jnp.asarray(rng.randn(1, 1, 16, 8), jnp.float32)

        def run(f):
            p = PerforationParams(kind=PerforationKind.INI,
                                  fraction=float(f))
            return perforated_attention.perforated_attention(
                q, kv, kv, block_q=8, block_kv=8, perfo=p, interpret=True)

        return run

    return [
        KnobTarget("kernels.taf_matmul.rsd_threshold", taf),
        KnobTarget("kernels.iact_memo.threshold", iact),
        KnobTarget("kernels.perforated_attention.fraction", attn),
        KnobTarget("kernels.perforated_matmul.fraction", pmm),
        # Structural perforation mode: the kept set SHAPES the grid -- the
        # herded payoff (dropped blocks are never scheduled). A001 flags it
        # as static by construction; the repo allowlist records it as
        # intentional, pointing sweeps at the masked fraction= mode.
        KnobTarget("kernels.perforated_matmul.perfo", pmm_structural),
        KnobTarget("kernels.perforated_attention.perfo", attn_structural),
    ]


def kernel_trace_targets() -> List[TraceTarget]:
    """Each kernel traced at a registered tiny config. `pallas_call`
    traces the kernel body, so a scalar-prefetch arity mismatch, a
    BlockSpec/index-map rank error, or a block-vs-array divisibility bug
    surfaces at trace time -- no execution (A002)."""
    targets = []
    for t in kernel_knob_targets():
        def build(t=t):
            fn = t.build()
            # plain python float: the structural-mode targets concretize
            # their knob (that is the point), and every kernel accepts a
            # python-float knob
            return (lambda: fn(float(t.values[0]))), ()
        targets.append(TraceTarget(t.subject.rsplit(".", 1)[0] + ".config",
                                   build))
    return targets


# --------------------------------------------------------------------------
# regions
# --------------------------------------------------------------------------

def region_knob_targets() -> List[KnobTarget]:
    from repro.core.approx import ApproxRegion, perforated_loop
    from repro.core.types import (ApproxSpec, IACTParams, PerforationKind,
                                  PerforationParams, TAFParams, Technique)

    def taf():
        spec = ApproxSpec(Technique.TAF, taf=TAFParams(2, 4, 0.5))
        region = ApproxRegion(spec, lambda x: x * 2.0, n_elements=8,
                              substrate="host")
        state = region.init_state()
        x = jnp.ones((8,), jnp.float32)
        return lambda th: region.step(state, x, rsd_threshold=th)

    def iact():
        spec = ApproxSpec(Technique.IACT, iact=IACTParams())
        region = ApproxRegion(spec, lambda x: x * 2.0, n_elements=8,
                              in_dim=1, substrate="host")
        state = region.init_state()
        x = jnp.ones((8,), jnp.float32)
        return lambda th: region.step(state, x, threshold=th)

    def perfo():
        spec = ApproxSpec(
            Technique.PERFORATION,
            perforation=PerforationParams(kind=PerforationKind.INI,
                                          fraction=0.0))
        body = lambda i, c: c + jnp.float32(i)
        return lambda f: perforated_loop(spec, 8, body, jnp.float32(0.0),
                                         fraction=f)[0]

    def perfo_skip():
        body = lambda i, c: c + jnp.float32(i)

        def run(s):
            spec = ApproxSpec(
                Technique.PERFORATION,
                perforation=PerforationParams(kind=PerforationKind.SMALL,
                                              skip=int(s)))
            return perforated_loop(spec, 8, body, jnp.float32(0.0))[0]

        return run

    return [
        KnobTarget("regions.taf.rsd_threshold", taf),
        KnobTarget("regions.iact.threshold", iact),
        KnobTarget("regions.perforated_loop.fraction", perfo),
        # skip-driven perforation's knob is the loop structure itself;
        # allowlisted as intentional (see .approxlint.json)
        KnobTarget("regions.perforated_loop.skip", perfo_skip,
                   values=(2.0, 4.0)),
    ]


def region_taint_targets() -> List[TraceTarget]:
    """Region steps with their MEMOIZED-VALUE state leaves tainted: the
    approximate outputs must not steer control flow or indexing (A003).
    Detector state (windows, counters) is deliberately NOT a source -- the
    detector steering a cond is the approximation mechanism itself."""
    from repro.core.approx import ApproxRegion
    from repro.core.types import ApproxSpec, TAFParams, Technique

    def taf():
        spec = ApproxSpec(Technique.TAF, taf=TAFParams(2, 4, 0.5))
        region = ApproxRegion(spec, lambda x: x * 2.0, n_elements=8,
                              substrate="host")
        state = region.init_state()
        x = jnp.ones((8,), jnp.float32)
        fn = lambda st, xx: region.step(st, xx, rsd_threshold=jnp.float32(0.5))
        return fn, (state, x)

    return [TraceTarget("regions.taf.step", taf, tainted=("memo",))]


# --------------------------------------------------------------------------
# ffn app geometry + sweep grids
# --------------------------------------------------------------------------

def default_grids():
    """The union Table-2 grid the sweep benchmarks actually run -- the
    spec population whose batched grouping A001 checks host-side."""
    from repro.core import harness
    return (list(harness.taf_grid()) + list(harness.iact_grid())
            + list(harness.perfo_grid()))


def ffn_geometry() -> Dict[str, int]:
    """The approx_ffn example's block geometry vs its array shapes --
    the divisibility preconditions its Pallas path asserts at run time,
    lifted to lint time (A002)."""
    import os
    import sys
    examples_dir = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))), "examples")
    if examples_dir not in sys.path:
        sys.path.insert(0, examples_dir)
    from apps import approx_ffn
    return {
        "seq": 128, "d": 32, "d_h": 64,
        "block_m": approx_ffn._BLOCK_M,
        "block_rows": approx_ffn._BLOCK_ROWS,
        "block_attn": approx_ffn._BLOCK_ATTN,
    }


# --------------------------------------------------------------------------
# decode / serving fixtures (lazy, cached: one tiny model per process)
# --------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def decode_fixture():
    """The smoke decode model with TAF enabled: the program the serving
    path runs. One construction serves A001/A003/A005."""
    from repro.launch import steps as steps_mod
    from repro.models import build
    from repro.qos import calibrate

    cfg = calibrate.default_decode_cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt_len, batch = 4, 2
    prompts = jnp.zeros((batch, prompt_len), jnp.int32)
    prefill = jax.jit(steps_mod.make_prefill_step(model, 16))
    logits, cache = prefill(params, {"tokens": prompts})
    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    serve = steps_mod.make_serve_step(model)
    return {"model": model, "params": params, "cache": cache,
            "tokens": tokens, "pos": jnp.int32(prompt_len), "serve": serve}


def serve_knob_target() -> KnobTarget:
    """The decode TAF threshold through the REAL serve step: writing the
    knob into the cache and tracing must not change the program (A001)."""

    def build():
        fx = decode_fixture()

        def run(th):
            taf = dict(fx["cache"]["taf"])
            taf["threshold"] = jnp.full_like(taf["threshold"], th)
            cache = dict(fx["cache"], taf=taf)
            return fx["serve"](fx["params"], cache, fx["tokens"], fx["pos"])

        return run

    return KnobTarget("decode.serve_step.rsd_threshold", build)


def serve_taint_target() -> TraceTarget:
    def build():
        fx = decode_fixture()
        fn = lambda params, cache, tokens, pos: fx["serve"](
            params, cache, tokens, pos)
        return fn, (fx["params"], fx["cache"], fx["tokens"], fx["pos"])

    return TraceTarget("decode.serve_step", build,
                       tainted=("memo_k", "memo_v", "memo_delta"))


@functools.lru_cache(maxsize=1)
def engine_fixture():
    """A 1-device sharded ServingEngine over the decode fixture's model,
    prefilled once -- the placement surface A005 audits. Mesh commitment
    is a property of concrete arrays, so this target genuinely executes
    (one tiny prefill)."""
    from repro.serving.scheduler import ServingEngine

    fx = decode_fixture()
    eng = ServingEngine(fx["model"], fx["params"], slots=2, max_len=16,
                        prompt_len=4, devices=1)
    prompts = jnp.zeros((eng.n_slots, eng.prompt_len), jnp.int32)
    logits, cache = eng._prefill(eng.params, {"tokens": prompts})
    eng.cache = eng._shard_cache(cache)
    eng.tokens = eng._place_tokens(
        jnp.argmax(logits, axis=-1).astype(jnp.int32))
    return eng


def leaf_paths(tree) -> List[Tuple[str, object]]:
    """(dotted-path, leaf) pairs for a pytree, for placement audits and
    taint-source selection."""
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append((jax.tree_util.keystr(path), leaf))
    return out


def tainted_positions(example_args: tuple,
                      needles: Sequence[str]) -> List[int]:
    """Flattened-input positions (== jaxpr invar positions) whose pytree
    path contains any needle."""
    leaves_with_path, _ = jax.tree_util.tree_flatten_with_path(example_args)
    return [i for i, (path, _) in enumerate(leaves_with_path)
            if any(n in jax.tree_util.keystr(path) for n in needles)]
