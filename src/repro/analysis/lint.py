"""approxlint CLI -- static analysis for approximation regions, kernels,
and QoS ladders.

    PYTHONPATH=src python -m repro.analysis.lint \
        --apps all --policies 'artifacts/policies/*.json' --format text

Exit codes: 0 = clean (below --fail-on), 1 = findings at/above --fail-on,
2 = a rule crashed (the lint itself is broken -- never mistake that for a
clean tree).

The allowlist (`.approxlint.json`, discovered upward from the CWD or named
with --allowlist) records INTENTIONAL findings with reasons; allowlisted
findings are reported but do not gate. See docs/analysis.md.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence, Tuple

from . import rules as rules_mod
from .findings import (Allowlist, Report, Severity, default_allowlist_path)
from .targets import APP_NAMES


def run_lint(*, apps: Sequence[str] = APP_NAMES,
             policies: Sequence[str] = (),
             rules: Sequence[str] = rules_mod.RULE_IDS,
             allowlist: Optional[Allowlist] = None,
             model_taf: Optional[Tuple[int, int]] = None) -> Report:
    """Programmatic entry point (the CLI, the harness/engine lint hooks,
    and the tests all come through here). Rule crashes are captured in
    `report.errors`, not raised: a broken rule must fail the lint loudly
    instead of silently checking nothing."""
    report = Report()
    runners = {
        "A001": lambda: rules_mod.rule_a001(apps),
        "A002": lambda: rules_mod.rule_a002(apps),
        "A003": lambda: rules_mod.rule_a003(apps),
        "A004": lambda: rules_mod.rule_a004(policies, model_taf=model_taf),
        "A005": lambda: rules_mod.rule_a005(apps),
        "A006": lambda: rules_mod.rule_a006(policies),
        "A007": lambda: rules_mod.rule_a007(apps),
        "A008": lambda: rules_mod.rule_a008(apps),
    }
    for rid in rules_mod.RULE_IDS:
        if rid not in rules:
            continue
        try:
            report.extend(runners[rid](), allowlist)
        except Exception as e:  # noqa: BLE001
            report.errors.append(f"{rid}: {type(e).__name__}: {e}"[:500])
    return report


def _expand_policies(patterns: Sequence[str]) -> List[str]:
    import glob
    out: List[str] = []
    for p in patterns:
        hits = sorted(glob.glob(p))
        if not hits:
            # a named-but-missing policy is a finding-shaped event; let
            # A004 report the unreadable path instead of silently passing
            out.append(p)
        out.extend(hits)
    return out


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="approxlint: static analysis for approximation "
        "regions, kernels, and QoS ladders (rules A001-A008)")
    ap.add_argument("--apps", default="all",
                    help="comma-separated target groups "
                    f"({','.join(APP_NAMES)}) or 'all'")
    ap.add_argument("--policies", nargs="*", default=[],
                    help="saved QosPolicy JSON files/globs for A004")
    ap.add_argument("--rules", default=",".join(rules_mod.RULE_IDS),
                    help="comma-separated rule ids to run")
    ap.add_argument("--format", default="text", choices=["text", "json"])
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this path")
    ap.add_argument("--allowlist", default=None,
                    help="allowlist file (default: nearest .approxlint.json)")
    ap.add_argument("--no-allowlist", action="store_true",
                    help="ignore any allowlist (report raw findings)")
    ap.add_argument("--fail-on", default="error",
                    choices=["info", "warning", "error"],
                    help="minimum severity that makes the exit code 1")
    ap.add_argument("--no-serve", action="store_true",
                    help="skip the decode/serving targets (the only group "
                    "that builds a model and runs a tiny prefill)")
    ap.add_argument("--model-taf", default=None, metavar="H,P",
                    help="structural TAF params the serving model runs; "
                    "A004 cross-checks every policy's rungs against them")
    args = ap.parse_args(argv)

    apps = list(APP_NAMES) if args.apps == "all" else \
        [a.strip() for a in args.apps.split(",") if a.strip()]
    for a in apps:
        if a not in APP_NAMES:
            ap.error(f"unknown app group {a!r} "
                     f"(choose from: {','.join(APP_NAMES)})")
    if args.no_serve:
        apps = [a for a in apps if a != "decode"]
    rules = [r.strip().upper() for r in args.rules.split(",") if r.strip()]
    for r in rules:
        if r not in rules_mod.RULE_IDS:
            ap.error(f"unknown rule {r!r} "
                     f"(choose from: {','.join(rules_mod.RULE_IDS)})")
    model_taf = None
    if args.model_taf:
        try:
            h, p = (int(v) for v in args.model_taf.split(","))
            model_taf = (h, p)
        except ValueError:
            ap.error("--model-taf expects 'H,P' (two integers)")

    allowlist = None
    if not args.no_allowlist:
        path = args.allowlist or default_allowlist_path()
        if args.allowlist and not path:
            ap.error(f"allowlist {args.allowlist!r} not found")
        if path:
            allowlist = Allowlist.load(path)

    report = run_lint(apps=apps, policies=_expand_policies(args.policies),
                      rules=rules, allowlist=allowlist, model_taf=model_taf)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(report.to_json(), f, indent=1)
    if args.format == "json":
        print(json.dumps(report.to_json(), indent=1))
    else:
        print(report.render_text())

    if report.errors:
        return 2
    return 1 if report.failed(Severity.parse(args.fail_on)) else 0


if __name__ == "__main__":
    sys.exit(main())
