"""Deterministic synthetic token pipeline, shard-aware.

Real deployments stream tokenized corpora; here the substrate is a
deterministic generator with LEARNABLE structure (an order-2 mixture chain)
so end-to-end training demonstrably reduces loss, while staying fully
reproducible across restarts and reshards:

  * batch `i` is a pure function of (seed, step, global example index) --
    restart-safe: resuming at step k regenerates exactly the batches k, k+1..
  * each data shard generates ONLY its slice (no host broadcasting).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    # learnable-structure knobs
    n_patterns: int = 64
    pattern_len: int = 32


class SyntheticLM:
    """Order-2 deterministic pattern corpus: each sequence stitches
    pseudo-random spans from a fixed pattern bank, so a model can reduce loss
    by memorizing bank statistics; tokens/labels are next-token shifted."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.RandomState(cfg.seed)
        self.bank = rng.randint(
            0, cfg.vocab_size,
            size=(cfg.n_patterns, cfg.pattern_len)).astype(np.int32)

    def example(self, index: int) -> np.ndarray:
        """Deterministic example by global index."""
        cfg = self.cfg
        rng = np.random.RandomState((cfg.seed * 1_000_003 + index) % 2**31)
        n_spans = cfg.seq_len // cfg.pattern_len + 2
        pats = rng.randint(0, cfg.n_patterns, size=n_spans)
        seq = np.concatenate([self.bank[p] for p in pats])[: cfg.seq_len + 1]
        return seq

    def batch(self, step: int, shard_index: int = 0,
              num_shards: int = 1) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        assert cfg.global_batch % num_shards == 0
        local = cfg.global_batch // num_shards
        base = step * cfg.global_batch + shard_index * local
        seqs = np.stack([self.example(base + i) for i in range(local)])
        return {"tokens": seqs[:, :-1].astype(np.int32),
                "labels": seqs[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class PrefetchIterator:
    """Single-slot lookahead prefetch (thread) -- overlaps host batch
    synthesis with device step execution."""

    def __init__(self, ds: SyntheticLM, start_step: int = 0,
                 shard_index: int = 0, num_shards: int = 1):
        import threading
        import queue
        self.ds = ds
        self.q: "queue.Queue" = queue.Queue(maxsize=2)
        self.step = start_step
        self.shard_index = shard_index
        self.num_shards = num_shards
        self._stop = False

        def worker():
            s = start_step
            while not self._stop:
                try:
                    self.q.put(ds.batch(s, shard_index, num_shards),
                               timeout=0.5)
                    s += 1
                except Exception:
                    continue

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop = True
