"""Sharded checkpointing with resharding restore, async writes, retention.

Fault-tolerance substrate:
  * save(): flattens the (params, opt_state, step) pytree to path-keyed
    arrays; each host writes its OWN addressable shards (here: one host) plus
    a manifest (tree structure, global shapes, dtypes, step). Writes go to a
    tmp dir + atomic rename, so a preempted save never corrupts the latest
    checkpoint.
  * restore(): reassembles global arrays and `jax.device_put`s them with the
    TARGET sharding -- the target mesh may differ from the save-time mesh
    (elastic scaling / node-failure re-provisioning): resharding happens on
    load.
  * async mode: serialization runs on a background thread; the train loop
    only blocks if a previous save is still in flight (single-slot queue).
  * retention: keep the newest `keep_n` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np
import jax

PyTree = Any
_SEP = "||"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_save: bool = False):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        self._inflight: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree) -> str:
        self.wait()
        # snapshot to host memory synchronously (cheap vs serialization)
        flat = _flatten(tree)
        treedef = jax.tree_util.tree_structure(tree)
        manifest = {
            "step": int(step),
            "keys": list(flat.keys()),
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "treedef": str(treedef),
        }

        def write():
            final = os.path.join(self.dir, f"step_{step:08d}")
            tmp = final + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"),
                     **{k: v for k, v in flat.items()})
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)          # atomic publish
            self._gc()

        if self.async_save:
            self._inflight = threading.Thread(target=write, daemon=True)
            self._inflight.start()
        else:
            write()
        return os.path.join(self.dir, f"step_{step:08d}")

    def wait(self):
        if self._inflight is not None:
            self._inflight.join()
            self._inflight = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep_n]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> Tuple[PyTree, int]:
        """Restore into the structure of `tree_like`. If `shardings` is
        given (a pytree of jax.sharding.Sharding matching tree_like), leaves
        are device_put with the TARGET sharding -- this is the elastic
        reshard-on-restore path."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(d, "shard_0.npz"))
        paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(paths))
        leaves = []
        for (path, like), shard in zip(paths, shard_leaves):
            key = _SEP.join(str(p) for p in path)
            if key not in data:
                raise KeyError(f"checkpoint missing leaf {key}")
            arr = data[key]
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"model {like.shape}")
            arr = arr.astype(like.dtype)
            leaves.append(jax.device_put(arr, shard) if shard is not None
                          else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves), step
