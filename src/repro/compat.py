"""Version-compatibility shims over the moving parts of the jax API.

The repo pins no exact jax version; it must run on the 0.4.x series (the
container ships 0.4.37) and on >= 0.5, which renamed or relocated several
distributed-runtime entry points. Every cross-version construct lives HERE,
in one helper per construct, so call sites never branch on the jax version
themselves:

* ``jax.sharding.AxisType`` (>= 0.5): explicit-sharding axis types. On
  0.4.x meshes are implicitly fully "auto", so omitting the argument is the
  exact equivalent.
* ``jax.shard_map`` (>= 0.6 top-level export; 0.4.x home is
  ``jax.experimental.shard_map``) and its replication-check kwarg
  (``check_vma``, formerly ``check_rep``).

The AC surveys (Leon et al.) call out exactly this kind of cross-version
fragility as a practical barrier to adopting approximation systems; keeping
the portability surface in one module is the mitigation.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)

try:  # newer jax exports shard_map at the top level
    from jax import shard_map as _shard_map
    _SHARD_MAP_CHECK_KWARG = "check_vma"
except ImportError:  # jax 0.4.x: experimental home, `check_rep` kwarg
    from jax.experimental.shard_map import shard_map as _shard_map
    _SHARD_MAP_CHECK_KWARG = "check_rep"


def make_mesh(shape: Tuple[int, ...], axis_names: Sequence[str], *,
              devices: Optional[Sequence] = None):
    """`jax.make_mesh` with auto axis types on every jax version.

    On jax >= 0.5 this passes ``axis_types=(AxisType.Auto, ...)`` explicitly;
    on 0.4.x (no ``AxisType``) the argument is omitted, which means the same
    thing.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if _AXIS_TYPE is not None:
        kwargs["axis_types"] = (_AXIS_TYPE.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(shape), tuple(axis_names), **kwargs)


def cost_analysis(compiled) -> dict:
    """Flat cost dict of a compiled computation on every jax version.

    jax 0.4.x returns a one-element list of per-computation dicts (or None);
    >= 0.5 returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost or {}


def shard_map(f, *, mesh, in_specs, out_specs, check_replication: bool = True):
    """`shard_map` with the replication check spelled portably.

    ``check_replication`` maps to ``check_vma`` (jax >= 0.6) or ``check_rep``
    (0.4.x) -- same semantics, renamed kwarg.
    """
    kwargs = {_SHARD_MAP_CHECK_KWARG: check_replication}
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
