"""Relative standard deviation (RSD) -- TAF's activation statistic.

Paper footnote 1: RSD = sigma / mu for *population* standard deviation sigma
and population mean mu, computed over the sliding window of the last
`history_size` outputs of the accurate path.
"""
from __future__ import annotations

import jax.numpy as jnp


def rsd(window: jnp.ndarray, axis: int = -1, eps: float = 1e-12) -> jnp.ndarray:
    """Population RSD along `axis`. Safe at mu ~ 0 (returns sigma/eps-scale).

    RSD is scale-invariant: rsd(c*x) == rsd(x) for c > 0 (property-tested).
    """
    mu = jnp.mean(window, axis=axis)
    sigma = jnp.std(window, axis=axis)  # population std (ddof=0)
    return sigma / jnp.maximum(jnp.abs(mu), eps)


def rsd_scalar_summary(outputs: jnp.ndarray) -> jnp.ndarray:
    """Reduce a (possibly vector-valued) region output to the scalar tracked
    by the TAF window.

    The paper's TAF tracks scalar function outputs. For tensor-valued code
    regions (FFN tiles, block outputs) we track the mean -- the natural
    region summary; the memoized *value* is still the full tensor.
    """
    return jnp.mean(outputs, axis=tuple(range(1, outputs.ndim))) if outputs.ndim > 1 \
        else outputs


def welford_update(count, mean, m2, new_value):
    """Streaming mean/variance update (Welford). Used by the O(1)-memory TAF
    variant in the Pallas kernel where a full window does not fit VMEM."""
    count = count + 1
    delta = new_value - mean
    mean = mean + delta / count
    delta2 = new_value - mean
    m2 = m2 + delta * delta2
    return count, mean, m2
