"""Pareto-front extraction and front-guided adaptive refinement.

The AC surveys (Leon et al., arXiv:2307.11124 / 2307.11128) frame technique
selection as a quality-vs-performance *Pareto* problem: no single "best"
configuration exists, only the non-dominated error/speedup trade-off curve.
This module makes the harness Pareto-aware:

  pareto_front(records)  -- the non-dominated subset (min error, max speedup)
  hypervolume(front)     -- 2-D dominated-area indicator (front quality)
  refine(app, records)   -- spend an extra evaluation budget subdividing
                            parameter neighborhoods around the current front
                            (successive-halving style: only front members
                            spawn candidates, fidelity grows per round),
                            replacing brute-force grid densification.

All functions consume/produce the same `Record` stream as `harness.sweep`,
and `refine` writes through the same keyed DB cache, so refinement is
resumable and benchmarks consume its output unchanged.
"""
from __future__ import annotations

import logging
import math
import os
from typing import Dict, List, Optional, Sequence, Union

from .harness import (ApproxApp, Record, db_index, load_db, spec_from_dict,
                      spec_hash, sweep)
from .types import ApproxSpec

log = logging.getLogger("repro.core.pareto")

RecordLike = Union[Record, Dict]

# Numeric knobs eligible for neighborhood subdivision, per technique.
# name -> (is_integer, hard_lower_bound)
_KNOBS = {
    "taf": {"hSize": (True, 1), "pSize": (True, 1), "thresh": (False, 0.0)},
    "iact": {"tSize": (True, 1), "thresh": (False, 0.0),
             "tPerBlock": (True, 0)},
    "perfo": {"skip": (True, 2), "fraction": (False, 0.0)},
}


def _get(r: RecordLike, field: str):
    return r[field] if isinstance(r, dict) else getattr(r, field)


def _hash_of(r: RecordLike) -> str:
    """Cache hash of a record or DB row (v1 rows lack spec_hash: recompute)."""
    h = r.get("spec_hash") if isinstance(r, dict) else r.spec_hash
    return h or spec_hash(_get(r, "spec"))


def _perf(r: RecordLike, use_modeled: bool) -> float:
    return _get(r, "modeled_speedup" if use_modeled else "speedup")


def dominates(a: RecordLike, b: RecordLike, *,
              use_modeled: bool = False) -> bool:
    """True iff `a` is at least as good as `b` on both axes (error down,
    speedup up) and strictly better on at least one."""
    ea, eb = _get(a, "error"), _get(b, "error")
    sa, sb = _perf(a, use_modeled), _perf(b, use_modeled)
    return (ea <= eb and sa >= sb) and (ea < eb or sa > sb)


def pareto_front(records: Sequence[RecordLike], *,
                 use_modeled: bool = False) -> List[RecordLike]:
    """Non-dominated subset of `records`, sorted by ascending error.

    Accepts Record objects or raw DB rows (dicts). Records with non-finite
    error are excluded (they cannot trade off against anything). Duplicate
    (error, speedup) points keep a single representative.
    """
    finite = [r for r in records if math.isfinite(_get(r, "error"))]
    ranked = sorted(finite,
                    key=lambda r: (_get(r, "error"), -_perf(r, use_modeled)))
    front: List[RecordLike] = []
    best = -math.inf
    for r in ranked:
        s = _perf(r, use_modeled)
        if s > best:
            front.append(r)
            best = s
    return front


def hypervolume(front: Sequence[RecordLike], *, ref_error: float = 1.0,
                ref_speedup: float = 1.0, use_modeled: bool = False) -> float:
    """Area dominated by `front` relative to reference point
    (ref_error, ref_speedup) -- larger is better. Points at or beyond the
    reference on either axis contribute nothing."""
    pts = sorted({(_get(r, "error"), _perf(r, use_modeled)) for r in front})
    hv, prev_spd = 0.0, ref_speedup
    for err, spd in pts:  # error ascending; on a front speedup ascends too
        if err >= ref_error or spd <= prev_spd:
            continue
        hv += (ref_error - err) * (spd - prev_spd)
        prev_spd = spd
    return hv


def _neighbor_values(value, seen: Sequence, is_int: bool, lower) -> List:
    """Midpoints between `value` and its nearest distinct seen values on
    each side; when a side has no neighbor, extrapolate by the half/1.5x
    rule so the search can escape the initial grid's hull."""
    out = []
    below = [v for v in seen if v < value]
    above = [v for v in seen if v > value]
    cands = []
    cands.append((value + max(below)) / 2 if below else value / 2)
    cands.append((value + min(above)) / 2 if above else value * 1.5)
    for c in cands:
        c = int(round(c)) if is_int else float(c)
        if c >= lower and c != value and c not in seen:
            out.append(c)
    return out


def propose_candidates(records: Sequence[RecordLike], *,
                       use_modeled: bool = False,
                       max_candidates: Optional[int] = None
                       ) -> List[ApproxSpec]:
    """Subdivision candidates around the current front.

    For every front member and every numeric knob of its technique, propose
    the midpoints between the member's value and the nearest distinct values
    observed anywhere in `records` (the coarse grid provides the bracket).
    Candidates are deduped by canonical spec hash and exclude anything
    already measured. With `max_candidates`, front members contribute
    round-robin so every front point keeps some of its neighborhood.
    """
    measured = {_hash_of(r) for r in records}
    front = pareto_front(records, use_modeled=use_modeled)

    seen_values: Dict[tuple, set] = {}
    for r in records:
        spec = _get(r, "spec")
        tech = spec.get("technique")
        for knob in _KNOBS.get(tech, {}):
            if knob in spec:
                seen_values.setdefault((tech, knob), set()).add(spec[knob])

    per_member: List[List[ApproxSpec]] = []
    proposed = set(measured)
    for r in front:
        spec = dict(_get(r, "spec"))
        tech = spec.get("technique")
        mine: List[ApproxSpec] = []
        for knob, (is_int, lower) in _KNOBS.get(tech, {}).items():
            if knob not in spec:
                continue
            seen = sorted(seen_values.get((tech, knob), set()))
            for v in _neighbor_values(spec[knob], seen, is_int, lower):
                cand = dict(spec)
                cand[knob] = v
                h = spec_hash(cand)
                if h in proposed:
                    continue
                try:
                    mine.append(spec_from_dict(cand))
                except (ValueError, KeyError):
                    continue  # violates a param constraint; not a candidate
                proposed.add(h)
        per_member.append(mine)

    # Round-robin interleave across front members, then cap.
    out: List[ApproxSpec] = []
    i = 0
    while any(per_member):
        for mine in per_member:
            if i < len(mine):
                out.append(mine[i])
        if not any(i < len(m) for m in per_member):
            break
        i += 1
    if max_candidates is not None:
        out = out[:max_candidates]
    return out


def refine(app: ApproxApp, records: Sequence[RecordLike], *,
           budget: int = 16, rounds: int = 2, repeats: int = 1, eta: int = 2,
           jobs: int = 1, db_path: Optional[str] = None,
           use_modeled: bool = False, verbose: bool = False,
           substrate: Optional[str] = None,
           predict=None, predict_band: float = 0.10) -> List[Record]:
    """Front-guided adaptive densification (successive-halving style).

    Starting from coarse-grid `records`, run up to `rounds` rounds; each
    round proposes subdivision candidates around the *current* front
    (non-front configurations never spawn work -- the halving), evaluates at
    most the remaining budget of them via the resumable `sweep`, folds the
    results in, and raises fidelity by `eta` for the next round.
    `substrate` scopes the ambient execution substrate for the sweeps.

    `predict` (an `analysis.cost.AppCostModel`) turns refinement into a
    predicted-front seeding strategy: each round's candidates are ranked
    by their regret against the PREDICTED (error bound, speedup) front
    and only those within `predict_band` relative regret -- capped at the
    remaining budget -- are measured. The measurement budget is spent
    inside the band the model believes can advance the front.

    Returns only the newly-EXECUTED Records: candidates served from the DB
    cache fold into the working front but cost no budget and are not
    returned. With `db_path`, new rows land in the shared DB cache, so
    refinement is itself resumable.
    """
    pool: List[RecordLike] = list(records)
    new: List[Record] = []
    remaining = budget
    fidelity = repeats
    for _ in range(max(1, rounds)):
        if remaining <= 0:
            break
        cands = propose_candidates(pool, use_modeled=use_modeled,
                                   max_candidates=None if predict is not None
                                   else remaining)
        if predict is not None and cands:
            n_all = len(cands)
            cands = predict.select_band(cands, budget=remaining,
                                        band=predict_band)
            log.info("predict[refine:%s]: kept %d / dropped %d of %d "
                     "candidates (band=%.3g)", app.name, len(cands),
                     n_all - len(cands), n_all, predict_band)
        if not cands:
            break
        already = set()
        if db_path and os.path.exists(db_path):
            already = {k[1] for k in db_index(load_db(db_path))
                       if k[0] == app.name and k[2] == app.workload_hash}
        recs = sweep(app, cands, repeats=fidelity, db_path=db_path,
                     verbose=verbose, jobs=jobs, resume=True,
                     substrate=substrate)
        fresh = [r for r in recs if r.spec_hash not in already]
        remaining -= len(fresh)
        pool.extend(recs)
        new.extend(fresh)
        fidelity *= eta
    return new


def front_summary(records: Sequence[RecordLike], *, use_modeled: bool = False,
                  ref_error: float = 1.0) -> Dict:
    """Compact description of a record set's front (used by benchmarks)."""
    front = pareto_front(records, use_modeled=use_modeled)
    return {
        "n_records": len(records),
        "n_front": len(front),
        "hypervolume": hypervolume(front, ref_error=ref_error,
                                   use_modeled=use_modeled),
        "best_error": min((_get(r, "error") for r in front), default=None),
        "best_speedup": max((_perf(r, use_modeled) for r in front),
                            default=None),
    }
