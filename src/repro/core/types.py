"""Parameter types for the HPAC-Offload programming model.

These dataclasses mirror the paper's pragma clauses one-to-one:

    #pragma approx memo(out:hSize:pSize:rsdThresh) level(thread)
        -> TAFParams(history_size=hSize, prediction_size=pSize,
                     rsd_threshold=rsdThresh), level=Level.ELEMENT

    #pragma approx memo(in:tsize:thresh:tperwarp) level(warp)
        -> IACTParams(table_size=tsize, threshold=thresh,
                      tables_per_block=tperwarp), level=Level.TILE

    #pragma approx perfo(small:M) / perfo(large:M) / perfo(ini:f) / perfo(fini:f)
        -> PerforationParams(kind=..., skip=M or fraction=f)

The GPU hierarchy (thread/warp/team) maps to the TPU hierarchy
(element / (8,128) VREG tile / Pallas block) per DESIGN.md section 2.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Technique(enum.Enum):
    """Which approximate-computing technique a region uses."""

    NONE = "none"
    TAF = "taf"          # output memoization (temporal approximate function)
    IACT = "iact"        # input memoization
    PERFORATION = "perfo"


class Level(enum.Enum):
    """Hierarchical decision level (paper: thread / warp / team).

    On TPU (DESIGN.md section 2):
      ELEMENT -- per vector-lane element. Quality knob only: masked lanes
                 still execute, so no FLOPs are saved.
      TILE    -- per (8, 128) VREG tile: the unit of uniform vector control.
      BLOCK   -- per Pallas grid block: decisions at this level gate
                 ``@pl.when`` and can skip whole MXU invocations.
    """

    ELEMENT = "element"  # paper: thread
    TILE = "tile"        # paper: warp
    BLOCK = "block"      # paper: team


# Paper's `warp` is 32 threads; our tile is 8 sublanes x 128 lanes. The vote
# granularity below is configurable but defaults to the hardware tile.
TILE_SHAPE = (8, 128)


class PerforationKind(enum.Enum):
    SMALL = "small"  # skip one of every M iterations
    LARGE = "large"  # execute one of every M iterations
    INI = "ini"      # skip the first `fraction` of iterations
    FINI = "fini"    # skip the last `fraction` of iterations
    RANDOM = "random"  # paper's HPAC also supports rand; kept for parity


@dataclasses.dataclass(frozen=True)
class TAFParams:
    """Temporal Approximate Function memoization (output memoization).

    history_size:    paper hSize -- sliding window length used for RSD.
    prediction_size: paper pSize -- number of approximated invocations once
                     the stable regime is entered.
    rsd_threshold:   enter the stable regime when RSD(window) < threshold.
    """

    history_size: int = 3
    prediction_size: int = 8
    rsd_threshold: float = 0.5

    def __post_init__(self):
        if self.history_size < 1:
            raise ValueError("history_size must be >= 1")
        if self.prediction_size < 1:
            raise ValueError("prediction_size must be >= 1")
        if self.rsd_threshold < 0:
            raise ValueError("rsd_threshold must be >= 0")


@dataclasses.dataclass(frozen=True)
class IACTParams:
    """Approximate input memoization (iACT).

    table_size:       paper tsize -- entries per memo table.
    threshold:        Euclidean-distance activation threshold.
    tables_per_block: paper tperwarp, remapped to the TPU tile (DESIGN.md
                      section 2): how many independent tables serve one
                      decision tile. 0 means "one table per element"
                      (paper default: one per thread).
    """

    table_size: int = 4
    threshold: float = 0.5
    tables_per_block: int = 1

    def __post_init__(self):
        if self.table_size < 1:
            raise ValueError("table_size must be >= 1")
        if self.threshold < 0:
            raise ValueError("threshold must be >= 0")
        if self.tables_per_block < 0:
            raise ValueError("tables_per_block must be >= 0")


@dataclasses.dataclass(frozen=True)
class PerforationParams:
    """Loop perforation.

    kind:     small / large / ini / fini / random.
    skip:     M for small ("skip 1 of every M") and large ("run 1 of every M").
    fraction: for ini/fini/random -- fraction of iterations dropped.
    herded:   paper section 3.1.5 -- when True every element drops the SAME
              iterations, keeping control flow uniform (no divergence; on TPU
              this is what makes the skipped tiles actually free).
    """

    kind: PerforationKind = PerforationKind.SMALL
    skip: int = 4
    fraction: float = 0.25
    herded: bool = True
    seed: int = 0  # for kind=RANDOM

    def __post_init__(self):
        if self.skip < 2 and self.kind in (PerforationKind.SMALL, PerforationKind.LARGE):
            raise ValueError("skip must be >= 2 for small/large perforation")
        if not (0.0 <= self.fraction < 1.0):
            raise ValueError("fraction must be in [0, 1)")


@dataclasses.dataclass(frozen=True)
class ApproxSpec:
    """Everything a `#pragma approx` line carries, as one object.

    This is the unit stored in architecture configs (`approx:` block) and
    consumed by `repro.core.approx.approx_region`.
    """

    technique: Technique = Technique.NONE
    level: Level = Level.ELEMENT
    taf: Optional[TAFParams] = None
    iact: Optional[IACTParams] = None
    perforation: Optional[PerforationParams] = None

    def __post_init__(self):
        if self.technique == Technique.TAF and self.taf is None:
            object.__setattr__(self, "taf", TAFParams())
        if self.technique == Technique.IACT and self.iact is None:
            object.__setattr__(self, "iact", IACTParams())
        if self.technique == Technique.PERFORATION and self.perforation is None:
            object.__setattr__(self, "perforation", PerforationParams())

    @property
    def enabled(self) -> bool:
        return self.technique != Technique.NONE


def parse_pragma(text: str) -> ApproxSpec:
    """Parse a paper-style pragma string into an ApproxSpec.

    Accepted grammar (whitespace-insensitive), mirroring Figure 5 of the paper:

        "memo(out:H:P:T) level(thread|warp|team)"
        "memo(in:S:T:W) level(...)"
        "perfo(small:M)" | "perfo(large:M)" | "perfo(ini:F)" | "perfo(fini:F)"

    This keeps the familiar idiom available to users porting HPAC pragmas.
    """
    text = text.strip()
    level = Level.ELEMENT
    lowered = text.replace(" ", "")
    if "level(" in lowered:
        inside = lowered.split("level(", 1)[1].split(")", 1)[0]
        level = {"thread": Level.ELEMENT, "warp": Level.TILE, "team": Level.BLOCK,
                 "element": Level.ELEMENT, "tile": Level.TILE, "block": Level.BLOCK}[inside]
    if "memo(out:" in lowered:
        args = lowered.split("memo(out:", 1)[1].split(")", 1)[0].split(":")
        h, p = int(args[0]), int(args[1])
        t = float(args[2]) if len(args) > 2 else 0.5
        return ApproxSpec(Technique.TAF, level,
                          taf=TAFParams(history_size=h, prediction_size=p, rsd_threshold=t))
    if "memo(in:" in lowered:
        args = lowered.split("memo(in:", 1)[1].split(")", 1)[0].split(":")
        s = int(args[0])
        t = float(args[1]) if len(args) > 1 else 0.5
        w = int(args[2]) if len(args) > 2 else 1
        return ApproxSpec(Technique.IACT, level,
                          iact=IACTParams(table_size=s, threshold=t, tables_per_block=w))
    if "perfo(" in lowered:
        args = lowered.split("perfo(", 1)[1].split(")", 1)[0].split(":")
        kind = PerforationKind(args[0])
        if kind in (PerforationKind.SMALL, PerforationKind.LARGE):
            return ApproxSpec(Technique.PERFORATION, level,
                              perforation=PerforationParams(kind=kind, skip=int(args[1])))
        return ApproxSpec(Technique.PERFORATION, level,
                          perforation=PerforationParams(kind=kind, fraction=float(args[1])))
    if lowered in ("", "none"):
        return ApproxSpec()
    raise ValueError(f"unrecognized pragma: {text!r}")
