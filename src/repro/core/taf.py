"""TAF: Temporal Approximate Function (output) memoization -- paper section 3.1.3.

State machine (paper section 2.3 + TAF [51]):

  ACCURATE: run the accurate path, push the output's scalar summary into a
            sliding window of the last `history_size` outputs. Once the window
            is full and RSD(window) < rsd_threshold, enter STABLE.
  STABLE:   approximate (return the last accurately-computed output) for the
            next `prediction_size` invocations, then fall back to ACCURATE.

GPU adaptation reproduced here (paper Figure 4d): each *element* (GPU thread ->
TPU lane slot) tracks its own state across its grid-stride iterations; no
inter-element dependencies, trading TAF's spatial-locality assumption for
parallelism. The state is a pytree so it can be carried through ``lax.scan``
(training/serving steps) or live in VMEM scratch (Pallas kernel variant).

Hierarchical voting (level=TILE/BLOCK) follows paper section 3.3: the group
approximates iff the majority of its elements' activation criteria hold.
BLOCK-level decisions are scalar and drive ``lax.cond`` -- the only mode that
actually skips FLOPs on a vector machine (DESIGN.md section 2).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import hierarchy
from .rsd import rsd
from .types import Level, TAFParams


class TAFState(NamedTuple):
    """Per-element TAF state. Leading dims = element slots (e.g. (N,))."""

    window: jnp.ndarray     # (..., history_size) recent accurate summaries
    filled: jnp.ndarray     # (...,) int32: valid entries in window (<= hSize)
    remaining: jnp.ndarray  # (...,) int32: approximations left in STABLE regime
    memo: jnp.ndarray       # (..., *out_shape) last accurate output

    @property
    def in_stable_regime(self) -> jnp.ndarray:
        return self.remaining > 0


def init(params: TAFParams, n_elements: int, out_shape: Tuple[int, ...] = (),
         dtype=jnp.float32) -> TAFState:
    """Fresh (all-ACCURATE) TAF state for `n_elements` slots.

    Memory per slot = history_size + prod(out_shape) scalars: this is the
    paper's Figure-3 argument -- state is sized by decision slots (bounded by
    what is resident), never by total logical iterations.
    """
    return TAFState(
        window=jnp.zeros((n_elements, params.history_size), jnp.float32),
        filled=jnp.zeros((n_elements,), jnp.int32),
        remaining=jnp.zeros((n_elements,), jnp.int32),
        memo=jnp.zeros((n_elements,) + tuple(out_shape), dtype),
    )


def activation(state: TAFState) -> jnp.ndarray:
    """Per-element activation criterion: approximate while in STABLE regime."""
    return state.remaining > 0


def _summary(y: jnp.ndarray) -> jnp.ndarray:
    """Scalar summary per element of a (N, ...) accurate output."""
    if y.ndim == 1:
        return y.astype(jnp.float32)
    return jnp.mean(y.astype(jnp.float32), axis=tuple(range(1, y.ndim)))


def _post_accurate(state: TAFState, y: jnp.ndarray, params: TAFParams,
                   updated_mask: jnp.ndarray,
                   rsd_threshold=None) -> TAFState:
    """Window push + regime evaluation for elements that ran accurately.

    `rsd_threshold` overrides params.rsd_threshold; it may be a traced
    scalar, which is what lets a batched runner `jax.vmap` one compiled
    sweep over a stack of thresholds (the structural params stay static).
    """
    if rsd_threshold is None:
        rsd_threshold = params.rsd_threshold
    s = _summary(y)
    new_window = jnp.concatenate(
        [state.window[:, 1:], s[:, None]], axis=1)
    window = jnp.where(updated_mask[:, None], new_window, state.window)
    filled = jnp.where(updated_mask,
                       jnp.minimum(state.filled + 1, params.history_size),
                       state.filled)
    # Regime check only for slots that just ran accurately with a full window.
    window_rsd = rsd(window, axis=1)
    stable = (window_rsd < rsd_threshold) & (filled >= params.history_size)
    remaining = jnp.where(updated_mask & stable,
                          jnp.int32(params.prediction_size), state.remaining)
    bmask = updated_mask.reshape(updated_mask.shape + (1,) * (y.ndim - 1))
    memo = jnp.where(bmask, y.astype(state.memo.dtype), state.memo)
    return TAFState(window, filled, remaining, memo)


def step(state: TAFState, accurate_fn: Callable[[], jnp.ndarray],
         params: TAFParams, level: Level = Level.ELEMENT,
         tile_size: Optional[int] = None,
         rsd_threshold=None) -> Tuple[jnp.ndarray, TAFState, jnp.ndarray]:
    """One invocation of a TAF-approximated region over all element slots.

    accurate_fn: () -> (N, ...) accurate outputs for every slot.

    Returns (outputs, new_state, approx_mask).

    ELEMENT/TILE levels: the accurate path is evaluated for all slots and
    masked (a TPU vector unit cannot skip per-lane work -- the paper's
    divergence cost, in masking form). BLOCK level: a scalar vote drives
    ``lax.cond`` so the accurate path is *genuinely skipped* when the block
    approximates -- the paper's divergence-free fast path.
    """
    elem_act = activation(state)
    approx_mask = hierarchy.vote(elem_act, level, tile_size=tile_size)

    if level == Level.BLOCK:
        block_decision = hierarchy.block_majority(elem_act)

        def approx_branch(st: TAFState):
            rem = jnp.maximum(st.remaining - 1, 0)
            return st.memo, st._replace(remaining=rem)

        def accurate_branch(st: TAFState):
            y = accurate_fn()
            new_st = _post_accurate(st, y, params,
                                    jnp.ones_like(elem_act),
                                    rsd_threshold=rsd_threshold)
            return y.astype(st.memo.dtype), new_st

        out, new_state = jax.lax.cond(block_decision, approx_branch,
                                      accurate_branch, state)
        return out, new_state, jnp.broadcast_to(block_decision, elem_act.shape)

    # ELEMENT / TILE: dense evaluation + select (masking semantics).
    y = accurate_fn()
    bmask = approx_mask.reshape(approx_mask.shape + (1,) * (y.ndim - 1))
    out = jnp.where(bmask, state.memo, y.astype(state.memo.dtype))
    # Approximating slots burn one prediction credit (even if group-forced
    # with remaining == 0: clamp at 0, matching the runtime's saturating
    # counter); accurate slots update window/memo/regime.
    new_state = _post_accurate(state, y, params, ~approx_mask,
                               rsd_threshold=rsd_threshold)
    remaining = jnp.where(approx_mask,
                          jnp.maximum(new_state.remaining - 1, 0),
                          new_state.remaining)
    return out, new_state._replace(remaining=remaining), approx_mask


def run_sequence(params: TAFParams, xs: jnp.ndarray,
                 fn: Callable[[jnp.ndarray], jnp.ndarray],
                 level: Level = Level.ELEMENT,
                 out_shape: Tuple[int, ...] = (),
                 tile_size: Optional[int] = None,
                 rsd_threshold=None):
    """Apply fn over a sequence of invocations (T, N, ...) with TAF, via scan.

    This is the grid-stride-loop shape of paper Figure 4(d): invocation t of
    element n corresponds to grid-stride iteration t of GPU thread n.
    Returns (outputs (T, N, ...), final_state, approx_fraction scalar).

    `rsd_threshold` (optional, possibly traced) overrides
    params.rsd_threshold -- the hook the harness's batched runners use to
    vmap one compiled sweep over a stack of thresholds.
    """
    n = xs.shape[1]
    probe = jax.eval_shape(fn, jax.ShapeDtypeStruct(xs.shape[1:], xs.dtype))
    state0 = init(params, n, probe.shape[1:], probe.dtype)

    def body(state, x_t):
        out, new_state, mask = step(state, lambda: fn(x_t), params, level,
                                    tile_size=tile_size,
                                    rsd_threshold=rsd_threshold)
        return new_state, (out, mask)

    final, (ys, masks) = jax.lax.scan(body, state0, xs)
    return ys, final, jnp.mean(masks.astype(jnp.float32))
