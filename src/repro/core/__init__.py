"""repro.core -- the paper's primary contribution in JAX.

HPAC-Offload (Fink et al., 2023): pragma-based approximate computing for
GPU-offloaded regions, re-derived for TPU execution (see DESIGN.md section 2).

Public surface:
  types        -- ApproxSpec / TAFParams / IACTParams / PerforationParams / Level
  approx       -- ApproxRegion (the "pragma"), parse_pragma, perforated_loop
  taf / iact   -- technique state machines (functional, scan- and Pallas-safe)
  perforation  -- skip-pattern generation (small/large/ini/fini, herded)
  hierarchy    -- element/tile/block majority-rules voting
  harness      -- the DSE execution harness + error metrics (MAPE, MCR):
                  resumable keyed-cache sweeps, parallel/batched evaluation
  batching     -- the batched-runner protocol: group specs by static
                  structure, vmap one compiled evaluation over the stacked
                  traced scalars
  pareto       -- error/speedup Pareto front + front-guided refinement
  substrate    -- host vs pallas execution-substrate selection + the
                  kernel-backed region evaluators
"""
from . import (approx, autotune, batching, harness, hierarchy, iact, pareto,
               perforation, rsd, substrate, taf, types)
from .approx import ApproxRegion, perforated_loop
from .types import (ApproxSpec, IACTParams, Level, PerforationKind,
                    PerforationParams, TAFParams, Technique, parse_pragma)

__all__ = [
    "approx", "autotune", "batching", "harness", "hierarchy", "iact",
    "pareto", "perforation", "rsd", "substrate", "taf",
    "types", "ApproxRegion", "perforated_loop", "ApproxSpec", "IACTParams",
    "Level", "PerforationKind", "PerforationParams", "TAFParams", "Technique",
    "parse_pragma",
]
