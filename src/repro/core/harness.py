"""The HPAC execution harness (paper section 2.3 "Design of HPAC").

"The HPAC execution harness exhaustively explores the space of user-provided
approximation techniques and parameters. [...] After executing the
approximated program, the harness calculates and saves runtime information
and error to a database."

`sweep` does exactly that over a grid of ApproxSpecs for an application that
follows the `ApproxApp` protocol; results land in a JSON "database" consumed
by benchmarks/ (one module per paper figure).

v2 engine (see docs/harness.md):

* **Resumable.** The database is a keyed cache: every row carries
  ``spec_hash``, the canonical hash of its spec dict, and ``sweep`` skips
  any (app, spec_hash) pair already present in ``db_path``. Interrupted or
  extended sweeps are therefore safe to re-invoke; re-running over a denser
  grid evaluates only the new points.
* **Parallel.** ``sweep(..., jobs=N)`` evaluates independent specs
  concurrently: through the app's opt-in batched runner
  (``ApproxApp.run_batch``, e.g. a ``jax.vmap`` over stacked spec
  parameters) when one is provided, otherwise via a thread pool.
* **Pareto-aware.** ``repro.core.pareto`` consumes the same Record stream:
  ``pareto_front`` extracts the error/speedup front and ``refine`` spends an
  extra budget subdividing parameter neighborhoods around it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import os
import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import (Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro import obs
from repro.obs import trace

from . import substrate as substrate_mod
from .types import (ApproxSpec, IACTParams, Level, PerforationKind,
                    PerforationParams, TAFParams, Technique)


def mape(o_ac: np.ndarray, o_ap: np.ndarray, eps: float = 1e-30) -> float:
    """Mean absolute percent error -- paper Eq. (1)."""
    o_ac = np.asarray(o_ac, np.float64).ravel()
    o_ap = np.asarray(o_ap, np.float64).ravel()
    return float(np.mean(np.abs(o_ac - o_ap) /
                         np.maximum(np.abs(o_ac), eps)))


def mcr(o_ac: np.ndarray, o_ap: np.ndarray) -> float:
    """Misclassification rate -- paper Eq. (2) (used for K-Means)."""
    o_ac = np.asarray(o_ac).ravel()
    o_ap = np.asarray(o_ap).ravel()
    return float(np.mean(o_ac != o_ap))


ERROR_METRICS = {"mape": mape, "mcr": mcr}


@dataclasses.dataclass
class AppResult:
    """What one approximated execution returns to the harness."""

    qoi: np.ndarray                   # quantity of interest (paper Table 1)
    wall_time_s: float                # measured end-to-end (or kernel) time
    approx_fraction: float = 0.0      # fraction of invocations approximated
    flop_fraction: float = 1.0        # executed FLOPs / accurate FLOPs
    extra: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ApproxApp:
    """An application under study (one row of paper Table 1).

    run_batch is the opt-in batchable-runner protocol: given a list of
    specs it returns one AppResult per spec, in order. Apps that can stack
    spec parameters into a single jitted/vmapped evaluation (see
    examples/apps/blackscholes.py) implement it to amortize compilation and
    device dispatch; `sweep(jobs>1)` uses it when present and falls back to
    a host thread pool otherwise.
    """

    name: str
    run: Callable[[ApproxSpec], AppResult]   # execute with a given spec
    error_metric: str = "mape"               # 'mape' or 'mcr'
    run_batch: Optional[
        Callable[[Sequence[ApproxSpec]], List[AppResult]]] = None
    # Workload fingerprint (problem sizes, seeds, ...). Part of the DB cache
    # key: the same app name at a different size must not share cached rows.
    workload: Dict = dataclasses.field(default_factory=dict)

    def exact(self) -> AppResult:
        return self.run(ApproxSpec())

    @property
    def workload_hash(self) -> str:
        return workload_hash(self.workload)


def workload_hash(workload: Dict) -> str:
    """Fingerprint of an app's workload parameters ("" = unspecified)."""
    if not workload:
        return ""
    d = {k: _norm_value(v) for k, v in workload.items()}
    return hashlib.sha1(json.dumps(
        d, sort_keys=True, separators=(",", ":"), default=str
    ).encode()).hexdigest()[:12]


@dataclasses.dataclass
class Record:
    app: str
    spec: Dict
    error: float
    speedup: float                 # measured wall-time speedup vs exact
    modeled_speedup: float         # 1 / flop_fraction: the TPU-roofline bound
    approx_fraction: float
    wall_time_s: float
    exact_time_s: float
    extra: Dict
    spec_hash: str = ""            # canonical cache key (filled by the engine)
    workload: Dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.spec_hash:
            self.spec_hash = spec_hash(self.spec)

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def spec_to_dict(spec: ApproxSpec) -> Dict:
    d: Dict = {"technique": spec.technique.value, "level": spec.level.value}
    if spec.taf:
        d.update(hSize=spec.taf.history_size, pSize=spec.taf.prediction_size,
                 thresh=spec.taf.rsd_threshold)
    if spec.iact:
        d.update(tSize=spec.iact.table_size, thresh=spec.iact.threshold,
                 tPerBlock=spec.iact.tables_per_block)
    if spec.perforation:
        d.update(kind=spec.perforation.kind.value, skip=spec.perforation.skip,
                 fraction=spec.perforation.fraction,
                 herded=spec.perforation.herded)
    return d


def spec_from_dict(d: Dict) -> ApproxSpec:
    """Inverse of spec_to_dict -- reconstruct the ApproxSpec a DB row or a
    Pareto-refinement candidate describes."""
    tech = Technique(d.get("technique", "none"))
    level = Level(d.get("level", "element"))
    if tech == Technique.TAF:
        return ApproxSpec(tech, level, taf=TAFParams(
            history_size=int(d["hSize"]), prediction_size=int(d["pSize"]),
            rsd_threshold=float(d["thresh"])))
    if tech == Technique.IACT:
        return ApproxSpec(tech, level, iact=IACTParams(
            table_size=int(d["tSize"]), threshold=float(d["thresh"]),
            tables_per_block=int(d["tPerBlock"])))
    if tech == Technique.PERFORATION:
        return ApproxSpec(tech, level, perforation=PerforationParams(
            kind=PerforationKind(d["kind"]), skip=int(d.get("skip", 4)),
            fraction=float(d.get("fraction", 0.25)),
            herded=bool(d.get("herded", True))))
    return ApproxSpec()


def _norm_value(v):
    """Value normalization for hashing: integral floats become ints so a
    spec hashes identically before and after a JSON round-trip (5 vs 5.0)."""
    if isinstance(v, bool):
        return v
    if isinstance(v, float) and v.is_integer():
        return int(v)
    return v


def spec_key(spec: Union[ApproxSpec, Dict]) -> str:
    """Canonical JSON form of a spec (sorted keys, value-normalized) -- the
    string that gets hashed into the DB cache key."""
    d = spec_to_dict(spec) if isinstance(spec, ApproxSpec) else dict(spec)
    d = {k: _norm_value(v) for k, v in d.items()}
    return json.dumps(d, sort_keys=True, separators=(",", ":"))


def spec_hash(spec: Union[ApproxSpec, Dict]) -> str:
    return hashlib.sha1(spec_key(spec).encode()).hexdigest()[:12]


def record_from_row(row: Dict) -> Record:
    """Rehydrate a DB row (schema v1 rows lack spec_hash: it is recomputed)."""
    fields = {f.name for f in dataclasses.fields(Record)}
    return Record(**{k: v for k, v in row.items() if k in fields})


def _timed(fn: Callable[[], AppResult], repeats: int) -> AppResult:
    """Best-of-N timing: the paper runs 3 trials (8 for Blackscholes) and
    reports means; on a shared CPU container min-of-N is the lower-noise
    statistic, and the result payload is identical across repeats."""
    best: Optional[AppResult] = None
    for _ in range(max(1, repeats)):
        r = fn()
        if best is None or r.wall_time_s < best.wall_time_s:
            best = r
    return best


def evaluate_spec(app: ApproxApp, spec: ApproxSpec, exact: AppResult,
                  repeats: int = 1) -> Record:
    """Evaluate one spec against a pre-measured exact baseline -> Record.

    The single scoring path shared by sweep, autotune, and pareto.refine.
    """
    res = _timed(lambda: app.run(spec), repeats)
    return _make_record(app, spec, res, exact)


def _make_record(app: ApproxApp, spec: ApproxSpec, res: AppResult,
                 exact: AppResult) -> Record:
    metric = ERROR_METRICS[app.error_metric]
    return Record(
        app=app.name,
        spec=spec_to_dict(spec),
        error=metric(exact.qoi, res.qoi),
        speedup=exact.wall_time_s / max(res.wall_time_s, 1e-12),
        modeled_speedup=1.0 / max(res.flop_fraction, 1e-12),
        approx_fraction=float(res.approx_fraction),
        wall_time_s=res.wall_time_s,
        exact_time_s=exact.wall_time_s,
        extra=res.extra,
        workload=dict(app.workload),
    )


# apps whose run_batch already triggered the serial-fallback warning (one
# warning per app per process, not one per chunk)
_WARNED_BATCH_FALLBACK: set = set()


def _run_batched(app: ApproxApp, specs: Sequence[ApproxSpec], repeats: int,
                 batch_size: int) -> List[AppResult]:
    """Batched-runner path: chunk specs and take the per-spec best of N
    batch invocations (same best-of-N statistic as _timed).

    A chunk whose run_batch raises falls back to the serial path, per spec,
    with the FULL repeat count: batch-amortized and serial wall times are
    not comparable best-of-N candidates, so partial batch repeats are
    discarded rather than mixed in, and one bad batch cannot abort a sweep.
    Protocol violations (wrong result count) still raise -- that is an app
    bug, not a transient evaluation failure.
    """
    out: List[AppResult] = []
    for lo in range(0, len(specs), max(1, batch_size)):
        chunk = list(specs[lo:lo + max(1, batch_size)])
        best: List[Optional[AppResult]] = [None] * len(chunk)
        failed = False
        for _ in range(max(1, repeats)):
            try:
                results = app.run_batch(chunk)
            except Exception as e:
                if app.name not in _WARNED_BATCH_FALLBACK:
                    _WARNED_BATCH_FALLBACK.add(app.name)
                    warnings.warn(
                        f"{app.name}.run_batch failed ({type(e).__name__}: "
                        f"{e}); falling back to the serial path for the "
                        "affected chunks. A deterministic failure here "
                        "silently costs the batched speedup -- fix the "
                        "app's group runner.")
                failed = True
                break
            if len(results) != len(chunk):
                raise ValueError(
                    f"{app.name}.run_batch returned {len(results)} results "
                    f"for {len(chunk)} specs")
            for i, r in enumerate(results):
                if best[i] is None or r.wall_time_s < best[i].wall_time_s:
                    best[i] = r
        if failed:
            best = [_timed(lambda s=s: app.run(s), repeats) for s in chunk]
        out.extend(best)
    return out


def run_specs(app: ApproxApp, specs: Sequence[ApproxSpec], repeats: int = 1,
              jobs: int = 1, *,
              substrate: Optional[str] = None,
              lint: bool = False) -> List[AppResult]:
    """Evaluate specs with best-of-`repeats` timing, dispatching to the
    app's batched runner (chunks of `jobs`) or a thread pool when jobs > 1.
    The single parallel-dispatch path shared by sweep and the autotuners.

    `substrate` ("host" / "pallas") scopes the ambient execution substrate
    for the whole evaluation (see `repro.core.substrate`): apps and
    ApproxRegions that resolve the substrate at run time are flipped onto
    the Pallas kernels; apps that pinned one at construction are unaffected.

    `lint=True` runs approxlint's A001 grouping check over THESE specs
    before anything executes (host-side only -- no tracing): specs that
    differ only in a quality knob but would not share a compiled
    evaluation raise ValueError instead of silently sweeping one compile
    per grid point. See docs/analysis.md.
    """
    specs = list(specs)
    if lint:
        from repro.analysis.rules import check_spec_grouping
        findings = check_spec_grouping(
            specs, subject_prefix=f"app.{app.name or 'specs'}")
        if findings:
            raise ValueError(
                "approxlint found recompile leaks in the spec population: "
                + "; ".join(f"{f.rule} {f.subject}: {f.message}"
                            for f in findings))
    def _one(s: ApproxSpec) -> AppResult:
        # per-spec span (thread-safe: the tracer locks appends and tags
        # each record with its emitting thread)
        with trace.span("harness.spec", app=app.name,
                        technique=s.technique.name):
            return _timed(lambda: app.run(s), repeats)

    with substrate_mod.use(substrate):
        if jobs > 1 and app.run_batch is not None:
            return _run_batched(app, specs, repeats, batch_size=jobs)
        if jobs > 1:
            with ThreadPoolExecutor(max_workers=jobs) as pool:
                return list(pool.map(_one, specs))
        return [_one(s) for s in specs]


def sweep(app: ApproxApp, specs: Iterable[ApproxSpec], repeats: int = 3,
          db_path: Optional[str] = None, verbose: bool = False, *,
          jobs: int = 1, resume: bool = True,
          substrate: Optional[str] = None,
          predict=None, predict_min_speedup: float = 1.0,
          predict_max_error: Optional[float] = None) -> List[Record]:
    """Run `app` once per spec (plus the exact baseline), computing error
    vs. the exact QoI and speedups; append new results to the JSON database.

    Resume semantics: when `db_path` exists and `resume` is True (the
    default), specs whose (app name, spec_hash) is already in the DB are NOT
    re-executed -- their cached rows are returned as Records in grid order.
    A sweep whose grid is fully cached performs zero executions (the exact
    baseline is also skipped). Only newly-evaluated rows are appended, so
    re-invocation is idempotent.

    Parallelism: `jobs > 1` evaluates uncached specs concurrently -- via
    `app.run_batch` (chunks of `jobs` specs per batch call) when the app
    provides one, otherwise via a `jobs`-wide thread pool. Records come
    back in grid order regardless of completion order, with the same
    spec/error/modeled_speedup content as a serial sweep. Wall-clock
    fields are per-run measurements: under the thread pool they include
    contention noise, and a batched runner reports batch time amortized
    per spec -- compare wall-time speedups only across rows produced the
    same way.

    `substrate`: ambient execution substrate for the evaluations (exact
    baseline included) -- see `run_specs`. Apps whose substrate matters to
    their results should bake it into `workload` so DB cache keys do not
    collide across substrates.

    `predict`: an `repro.analysis.cost.AppCostModel` (or any
    spec -> CostPrediction callable). The grid is PRUNED before anything
    executes: specs whose predicted speedup is below
    `predict_min_speedup` (default 1.0 -- "cannot pay for itself") or
    whose predicted error bound exceeds `predict_max_error` are dropped,
    with a logged kept/dropped count. Only the surviving specs are
    measured and returned, so the result list can be SHORTER than the
    input grid. Pruning composes with resume: cached rows for dropped
    specs are simply not consulted, and a later unpruned sweep fills
    them in.
    """
    specs = list(specs)
    if predict is not None:
        from repro.analysis.cost import filter_specs
        specs, _ = filter_specs(predict, specs,
                                min_speedup=predict_min_speedup,
                                max_error=predict_max_error,
                                context=f"sweep:{app.name}")
    hashes = [spec_hash(s) for s in specs]

    cached: Dict[str, Record] = {}
    if db_path and resume and os.path.exists(db_path):
        want = set(hashes)
        wkey = app.workload_hash
        for row in load_db(db_path):
            h = row.get("spec_hash") or spec_hash(row.get("spec", {}))
            if (row.get("app") == app.name and h in want and h not in cached
                    and workload_hash(row.get("workload", {})) == wkey):
                row = dict(row, spec_hash=h)
                cached[h] = record_from_row(row)

    # Dedupe uncached work (a grid may legitimately repeat a canonical spec).
    todo: List[Tuple[str, ApproxSpec]] = []
    seen = set()
    for h, s in zip(hashes, specs):
        if h not in cached and h not in seen:
            seen.add(h)
            todo.append((h, s))

    obs.count(f"sweep.{app.name}.cache_hits", float(len(cached)))
    obs.count(f"sweep.{app.name}.evaluated", float(len(todo)))
    fresh: Dict[str, Record] = {}
    if todo:
        with substrate_mod.use(substrate):
            with trace.span("harness.exact", app=app.name):
                exact = _timed(lambda: app.exact(), repeats)
        with trace.span("harness.sweep", app=app.name, specs=len(todo),
                        cached=len(cached), jobs=jobs):
            results = run_specs(app, [s for _, s in todo], repeats, jobs,
                                substrate=substrate)
        for (h, s), res in zip(todo, results):
            rec = _make_record(app, s, res, exact)
            fresh[h] = rec
            if verbose:
                print(f"[{app.name}] {rec.spec} err={rec.error:.4g} "
                      f"speedup={rec.speedup:.2f}x "
                      f"modeled={rec.modeled_speedup:.2f}x")

    if db_path and fresh:
        # resume=False means "re-measure": the fresh rows must replace any
        # stale cached rows instead of being dropped by the append dedupe.
        save_db(list(fresh.values()), db_path, append=True,
                overwrite=not resume)
    return [cached[h] if h in cached else fresh[h] for h in hashes]


def save_db(records: Sequence[Record], path: str, append: bool = False,
            overwrite: bool = False) -> None:
    """Persist records. With append=True, existing rows are kept and, by
    default, incoming rows that duplicate an existing cache key
    (app, spec_hash, workload_hash) are dropped, so repeated saves of the
    same sweep are idempotent. overwrite=True flips the precedence: the
    incoming rows replace same-key existing rows (used by resume=False
    re-measurement)."""
    rows = [r.to_json() for r in records]
    if append and os.path.exists(path):
        existing = load_db(path)
        if overwrite:
            incoming = {_row_key(r) for r in rows}
            rows = [r for r in existing
                    if _row_key(r) not in incoming] + rows
        else:
            have = {_row_key(r) for r in existing}
            rows = existing + [r for r in rows if _row_key(r) not in have]
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rows, f, indent=1)
    os.replace(tmp, path)


def load_db(path: str) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def _row_key(row: Dict) -> Tuple[str, str, str]:
    return (row.get("app"),
            row.get("spec_hash") or spec_hash(row.get("spec", {})),
            workload_hash(row.get("workload", {})))


def db_index(rows: Sequence[Dict]) -> Dict[Tuple[str, str, str], Dict]:
    """Index DB rows by their cache key (app, spec_hash, workload_hash)."""
    out: Dict[Tuple[str, str, str], Dict] = {}
    for row in rows:
        out.setdefault(_row_key(row), row)
    return out


# ----------------------------------------------------------------------------
# Parameter grids (paper Table 2)
# ----------------------------------------------------------------------------

def taf_grid(h_sizes=(1, 2, 3, 4, 5), p_sizes=(2, 8, 32, 128, 512),
             thresholds=(0.3, 0.6, 0.9, 1.2, 1.5, 3, 5, 20),
             levels=(Level.ELEMENT, Level.TILE)) -> List[ApproxSpec]:
    return [ApproxSpec(Technique.TAF, lv,
                       taf=TAFParams(h, p, t))
            for h, p, t, lv in itertools.product(h_sizes, p_sizes, thresholds,
                                                 levels)]


def iact_grid(t_sizes=(1, 2, 4, 8),
              thresholds=(0.1, 0.3, 0.5, 0.7, 0.9, 3, 5, 20),
              tables_per_block=(1, 2, 16, 32),
              levels=(Level.ELEMENT, Level.TILE)) -> List[ApproxSpec]:
    return [ApproxSpec(Technique.IACT, lv,
                       iact=IACTParams(s, t, w))
            for s, t, w, lv in itertools.product(t_sizes, thresholds,
                                                 tables_per_block, levels)]


def perfo_grid(skips=(2, 4, 8, 16, 32, 64),
               fractions=(0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9),
               kinds=(PerforationKind.SMALL, PerforationKind.LARGE,
                      PerforationKind.INI, PerforationKind.FINI),
               herded=(True,)) -> List[ApproxSpec]:
    out = []
    for k in kinds:
        if k in (PerforationKind.SMALL, PerforationKind.LARGE):
            for m in skips:
                for h in herded:
                    out.append(ApproxSpec(
                        Technique.PERFORATION,
                        perforation=PerforationParams(kind=k, skip=m, herded=h)))
        else:
            for fr in fractions:
                for h in herded:
                    out.append(ApproxSpec(
                        Technique.PERFORATION,
                        perforation=PerforationParams(kind=k, fraction=fr,
                                                      herded=h)))
    return out


def best_speedup_under_error(records: Sequence[Record], max_error: float = 0.10,
                             use_modeled: bool = False) -> Optional[Record]:
    """Paper Figure 6 statistic: fastest configuration whose error < bound."""
    ok = [r for r in records if r.error < max_error]
    if not ok:
        return None
    key = (lambda r: r.modeled_speedup) if use_modeled else (lambda r: r.speedup)
    return max(ok, key=key)
