"""The HPAC execution harness (paper section 2.3 "Design of HPAC").

"The HPAC execution harness exhaustively explores the space of user-provided
approximation techniques and parameters. [...] After executing the
approximated program, the harness calculates and saves runtime information
and error to a database."

`sweep` does exactly that over a grid of ApproxSpecs for an application that
follows the `ApproxApp` protocol; results land in a JSON "database" consumed
by benchmarks/ (one module per paper figure).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import time
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from .types import (ApproxSpec, IACTParams, Level, PerforationKind,
                    PerforationParams, TAFParams, Technique)


def mape(o_ac: np.ndarray, o_ap: np.ndarray, eps: float = 1e-30) -> float:
    """Mean absolute percent error -- paper Eq. (1)."""
    o_ac = np.asarray(o_ac, np.float64).ravel()
    o_ap = np.asarray(o_ap, np.float64).ravel()
    return float(np.mean(np.abs(o_ac - o_ap) /
                         np.maximum(np.abs(o_ac), eps)))


def mcr(o_ac: np.ndarray, o_ap: np.ndarray) -> float:
    """Misclassification rate -- paper Eq. (2) (used for K-Means)."""
    o_ac = np.asarray(o_ac).ravel()
    o_ap = np.asarray(o_ap).ravel()
    return float(np.mean(o_ac != o_ap))


ERROR_METRICS = {"mape": mape, "mcr": mcr}


@dataclasses.dataclass
class AppResult:
    """What one approximated execution returns to the harness."""

    qoi: np.ndarray                   # quantity of interest (paper Table 1)
    wall_time_s: float                # measured end-to-end (or kernel) time
    approx_fraction: float = 0.0      # fraction of invocations approximated
    flop_fraction: float = 1.0        # executed FLOPs / accurate FLOPs
    extra: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ApproxApp:
    """An application under study (one row of paper Table 1)."""

    name: str
    run: Callable[[ApproxSpec], AppResult]   # execute with a given spec
    error_metric: str = "mape"               # 'mape' or 'mcr'

    def exact(self) -> AppResult:
        return self.run(ApproxSpec())


@dataclasses.dataclass
class Record:
    app: str
    spec: Dict
    error: float
    speedup: float                 # measured wall-time speedup vs exact
    modeled_speedup: float         # 1 / flop_fraction: the TPU-roofline bound
    approx_fraction: float
    wall_time_s: float
    exact_time_s: float
    extra: Dict

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)


def spec_to_dict(spec: ApproxSpec) -> Dict:
    d: Dict = {"technique": spec.technique.value, "level": spec.level.value}
    if spec.taf:
        d.update(hSize=spec.taf.history_size, pSize=spec.taf.prediction_size,
                 thresh=spec.taf.rsd_threshold)
    if spec.iact:
        d.update(tSize=spec.iact.table_size, thresh=spec.iact.threshold,
                 tPerBlock=spec.iact.tables_per_block)
    if spec.perforation:
        d.update(kind=spec.perforation.kind.value, skip=spec.perforation.skip,
                 fraction=spec.perforation.fraction,
                 herded=spec.perforation.herded)
    return d


def _timed(fn: Callable[[], AppResult], repeats: int) -> AppResult:
    """Best-of-N timing: the paper runs 3 trials (8 for Blackscholes) and
    reports means; on a shared CPU container min-of-N is the lower-noise
    statistic, and the result payload is identical across repeats."""
    best: Optional[AppResult] = None
    for _ in range(max(1, repeats)):
        r = fn()
        if best is None or r.wall_time_s < best.wall_time_s:
            best = r
    return best


def sweep(app: ApproxApp, specs: Iterable[ApproxSpec], repeats: int = 3,
          db_path: Optional[str] = None, verbose: bool = False) -> List[Record]:
    """Run `app` exactly once per spec (plus the exact baseline), computing
    error vs. the exact QoI and speedups; append to the JSON database."""
    exact = _timed(lambda: app.exact(), repeats)
    metric = ERROR_METRICS[app.error_metric]
    records: List[Record] = []
    for spec in specs:
        res = _timed(lambda: app.run(spec), repeats)
        err = metric(exact.qoi, res.qoi)
        rec = Record(
            app=app.name,
            spec=spec_to_dict(spec),
            error=err,
            speedup=exact.wall_time_s / max(res.wall_time_s, 1e-12),
            modeled_speedup=1.0 / max(res.flop_fraction, 1e-12),
            approx_fraction=float(res.approx_fraction),
            wall_time_s=res.wall_time_s,
            exact_time_s=exact.wall_time_s,
            extra=res.extra,
        )
        records.append(rec)
        if verbose:
            print(f"[{app.name}] {rec.spec} err={err:.4g} "
                  f"speedup={rec.speedup:.2f}x modeled={rec.modeled_speedup:.2f}x")
    if db_path:
        save_db(records, db_path, append=True)
    return records


def save_db(records: Sequence[Record], path: str, append: bool = False) -> None:
    rows = [r.to_json() for r in records]
    if append and os.path.exists(path):
        with open(path) as f:
            rows = json.load(f) + rows
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(rows, f, indent=1)
    os.replace(tmp, path)


def load_db(path: str) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


# ----------------------------------------------------------------------------
# Parameter grids (paper Table 2)
# ----------------------------------------------------------------------------

def taf_grid(h_sizes=(1, 2, 3, 4, 5), p_sizes=(2, 8, 32, 128, 512),
             thresholds=(0.3, 0.6, 0.9, 1.2, 1.5, 3, 5, 20),
             levels=(Level.ELEMENT, Level.TILE)) -> List[ApproxSpec]:
    return [ApproxSpec(Technique.TAF, lv,
                       taf=TAFParams(h, p, t))
            for h, p, t, lv in itertools.product(h_sizes, p_sizes, thresholds,
                                                 levels)]


def iact_grid(t_sizes=(1, 2, 4, 8),
              thresholds=(0.1, 0.3, 0.5, 0.7, 0.9, 3, 5, 20),
              tables_per_block=(1, 2, 16, 32),
              levels=(Level.ELEMENT, Level.TILE)) -> List[ApproxSpec]:
    return [ApproxSpec(Technique.IACT, lv,
                       iact=IACTParams(s, t, w))
            for s, t, w, lv in itertools.product(t_sizes, thresholds,
                                                 tables_per_block, levels)]


def perfo_grid(skips=(2, 4, 8, 16, 32, 64),
               fractions=(0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9),
               kinds=(PerforationKind.SMALL, PerforationKind.LARGE,
                      PerforationKind.INI, PerforationKind.FINI),
               herded=(True,)) -> List[ApproxSpec]:
    out = []
    for k in kinds:
        if k in (PerforationKind.SMALL, PerforationKind.LARGE):
            for m in skips:
                for h in herded:
                    out.append(ApproxSpec(
                        Technique.PERFORATION,
                        perforation=PerforationParams(kind=k, skip=m, herded=h)))
        else:
            for fr in fractions:
                for h in herded:
                    out.append(ApproxSpec(
                        Technique.PERFORATION,
                        perforation=PerforationParams(kind=k, fraction=fr,
                                                      herded=h)))
    return out


def best_speedup_under_error(records: Sequence[Record], max_error: float = 0.10,
                             use_modeled: bool = False) -> Optional[Record]:
    """Paper Figure 6 statistic: fastest configuration whose error < bound."""
    ok = [r for r in records if r.error < max_error]
    if not ok:
        return None
    key = (lambda r: r.modeled_speedup) if use_modeled else (lambda r: r.speedup)
    return max(ok, key=key)
