"""Execution-substrate selection: host emulation vs Pallas kernels.

The paper's pitch is *portable* approximation: the same pragma runs on any
offload backend (HPAC-Offload section 2.2). This repo has two substrates
for an approximated region:

  "host"    -- the pure-jnp/numpy technique state machines (`core/taf.py`,
               `core/iact.py`, `kernels/ref.py` oracles): bit-faithful block
               semantics, runs anywhere, no Pallas involved.
  "pallas"  -- the Pallas TPU kernels (`kernels/`): Mosaic-compiled on TPU,
               interpret mode on CPU. Quality knobs (TAF rsd threshold, iACT
               distance threshold, perforation fraction) are TRACED kernel
               operands, so sweeps compile once per structural group and
               batched runners vmap stacked knobs straight through.

Selection is ambient with explicit override everywhere:

  * the process default comes from `$REPRO_SUBSTRATE` (else "host");
  * `use(substrate)` scopes a different choice (the harness entry points --
    `run_specs`, `sweep`, `autotune.*`, `pareto.refine` -- take a
    `substrate=` kwarg and evaluate inside `use(...)`);
  * `ApproxRegion(substrate=...)` / app factories pin one explicitly;
    `resolve(None)` reads the ambient value at call time.

`dispatch` + the `*_region` evaluators below are the kernel-backed
counterparts of the host technique entry points: spec-driven, hook-aware
(`rsd_threshold` / `threshold` / `fraction` may be traced scalars), and
uniform in what they return -- (output, approx_mask).
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional

from .types import ApproxSpec, PerforationKind, Technique

HOST = "host"
PALLAS = "pallas"
SUBSTRATES = (HOST, PALLAS)

# The ambient value is DELIBERATELY a process-wide global, not a
# ContextVar/thread-local: `run_specs(jobs>1)` evaluates specs on
# ThreadPoolExecutor workers, which do not inherit the caller's context,
# and those workers must see the `use(...)` scope the harness entered.
# The trade-off: two concurrent sweeps with DIFFERENT substrates in one
# process are unsupported -- pin the substrate on the app (e.g.
# `make_app(substrate=...)`) instead of relying on `use()` for that case.
_default: Optional[str] = None  # lazily read from the environment


def _env_default() -> str:
    sub = os.environ.get("REPRO_SUBSTRATE", HOST).strip().lower()
    if sub not in SUBSTRATES:
        raise ValueError(
            f"$REPRO_SUBSTRATE={sub!r} is not one of {SUBSTRATES}")
    return sub


def get_default() -> str:
    """The ambient substrate (process default or innermost `use(...)`)."""
    global _default
    if _default is None:
        _default = _env_default()
    return _default


def set_default(substrate: str) -> None:
    global _default
    _default = resolve(substrate)


def resolve(substrate: Optional[str]) -> str:
    """Validate an explicit choice; None means the ambient default."""
    if substrate is None:
        return get_default()
    if substrate not in SUBSTRATES:
        raise ValueError(
            f"unknown substrate {substrate!r}; expected one of {SUBSTRATES}")
    return substrate


@contextlib.contextmanager
def use(substrate: Optional[str]):
    """Scope the ambient substrate. `use(None)` is a no-op scope, so harness
    entry points can wrap evaluation unconditionally."""
    global _default
    if substrate is None:
        yield get_default()
        return
    prev = get_default()
    _default = resolve(substrate)
    try:
        yield _default
    finally:
        _default = prev


# ----------------------------------------------------------------------------
# Kernel-backed region evaluators (the "pallas" side of the dispatch)
# ----------------------------------------------------------------------------
# Imports of repro.kernels happen inside the functions: this module is
# imported by core/harness.py at package-init time and must stay light.

def taf_matmul_region(x, w, spec: ApproxSpec, *,
                      block_m: Optional[int] = None,
                      block_n: Optional[int] = None,
                      rsd_threshold=None, interpret: Optional[bool] = None):
    """TAF-memoized projection y = x @ w under `spec.taf`.

    `rsd_threshold` is the traced hook overriding the spec's static value.
    Block args left None resolve through the tuning cache / fallbacks in
    `kernels.ops` (mask granularity follows the resolved blocks).
    Returns (y, approx_mask (num_i, num_j) bool).
    """
    from repro.kernels import ops
    if spec.technique != Technique.TAF:
        raise ValueError(f"taf_matmul_region needs a TAF spec, got {spec}")
    p = spec.taf
    th = p.rsd_threshold if rsd_threshold is None else rsd_threshold
    return ops.taf_matmul(x, w, block_m=block_m, block_n=block_n,
                          history_size=p.history_size,
                          prediction_size=p.prediction_size,
                          rsd_threshold=th, interpret=interpret)


def iact_ffn_region(x, w1, w2, spec: ApproxSpec, *,
                    block_rows: Optional[int] = None,
                    threshold=None, interpret: Optional[bool] = None):
    """iACT-memoized FFN tile y = gelu(x @ w1) @ w2 under `spec.iact`.

    `threshold` is the traced hook. The kernel serves one table per row
    block (the paper's shared-memory table); `tables_per_block` other than
    its structural meaning of "state shape" is not re-partitioned here.
    Returns (y, block_approx_mask (num_blocks,) bool).
    """
    from repro.kernels import ops
    if spec.technique != Technique.IACT:
        raise ValueError(f"iact_ffn_region needs an IACT spec, got {spec}")
    p = spec.iact
    th = p.threshold if threshold is None else threshold
    return ops.iact_rowfn(x, w1, w2, block_rows=block_rows,
                          table_size=p.table_size, threshold=th,
                          interpret=interpret)


def attention_region(q, k, v, spec: Optional[ApproxSpec], *,
                     block_q: Optional[int] = None,
                     block_kv: Optional[int] = None,
                     fraction=None, causal: bool = True,
                     interpret: Optional[bool] = None):
    """(Perforated) flash attention under `spec.perforation` (None = exact).

    `fraction` is the traced hook (ini/fini/random kinds only: it flips the
    kernel into masked mode). Block args left None resolve through the
    tuning cache / fallbacks in `kernels.ops`; the kept-mask granularity
    follows the resolved block_kv. Returns (o, kept_block_mask (nkv,) bool)
    where the mask marks KV blocks that were EXECUTED (False = dropped).
    """
    import jax.numpy as jnp
    from repro.kernels import ops
    from . import perforation as perfo_mod
    # resolve once here: the host-side kept-mask below must agree with the
    # block_kv the kernel actually runs
    blocks = ops._resolve_blocks("perforated_attention", (q, k), q.dtype,
                                 block_q=block_q, block_kv=block_kv)
    block_q, block_kv = blocks["block_q"], blocks["block_kv"]
    nkv = k.shape[2] // block_kv
    if spec is None or spec.technique == Technique.NONE:
        o = ops.flash_attention(q, k, v, block_q=block_q, block_kv=block_kv,
                                causal=causal, interpret=interpret)
        return o, jnp.ones((nkv,), bool)
    if spec.technique != Technique.PERFORATION:
        raise ValueError(
            f"attention_region needs a perforation spec, got {spec}")
    p = spec.perforation
    o = ops.perforated_attention(q, k, v, block_q=block_q, block_kv=block_kv,
                                 perfo=p, fraction=fraction, causal=causal,
                                 interpret=interpret)
    if fraction is not None:
        mask = perfo_mod.traced_execute_mask(nkv, p, fraction)
    else:
        mask = jnp.asarray(perfo_mod.execute_mask(nkv, p))
    return o, mask


_REGIONS = {
    Technique.TAF: taf_matmul_region,
    Technique.IACT: iact_ffn_region,
    Technique.PERFORATION: attention_region,
}


def dispatch(technique: Technique):
    """The kernel-backed region evaluator for `technique` (KeyError-free)."""
    fn = _REGIONS.get(technique)
    if fn is None:
        raise ValueError(
            f"no pallas region evaluator for technique {technique}")
    return fn
