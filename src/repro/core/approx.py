"""The HPAC-Offload "pragma" as a JAX region API.

A C++ HPAC-Offload region:

    #pragma approx memo(in:2:0.5f:4) level(warp) in(...) out(...)
    output[i] = foo(&input[5*i], 5, N);

becomes:

    spec = parse_pragma("memo(in:2:0.5:4) level(warp)")     # or ApproxSpec(...)
    region = ApproxRegion(spec, foo_batched, n_elements=N, in_dim=5)
    out, _ = region(x)                 # stateful object API, or
    out, st, mask = region.step(st, x) # functional API for scan/jit

`ApproxRegion` owns the technique state (TAF window / iACT tables) exactly the
way the HPAC runtime owns the per-thread AC state, but as an explicit pytree.
Perforation is loop-shaped rather than region-shaped; `perforated_loop` and
`perforation.kept_indices` cover it.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from . import iact as iact_mod
from . import perforation as perfo_mod
from . import substrate as substrate_mod
from . import taf as taf_mod
from .types import ApproxSpec, Level, Technique, parse_pragma  # re-export

__all__ = [
    "ApproxSpec", "ApproxRegion", "parse_pragma", "perforated_loop",
]


@dataclasses.dataclass
class ApproxRegion:
    """An approximated code region (the dynamic extent of one pragma).

    fn: the accurate path, batched over elements: (N, in_dim)->(N, *out) for
    IACT, or ()->(N, *out) thunk inputs for TAF (TAF ignores inputs by
    definition -- it memoizes on *outputs*).
    """

    spec: ApproxSpec
    fn: Callable
    n_elements: int
    in_dim: int = 1
    out_shape: Tuple[int, ...] = ()
    out_dtype: object = jnp.float32
    tile_size: Optional[int] = None
    # Execution substrate: None resolves the ambient default at call time
    # (see repro.core.substrate -- the harness's `substrate=` kwarg scopes
    # it), "host"/"pallas" pin one. The pallas substrate needs a concrete
    # kernel implementation of THIS region's fn: a callable
    # `pallas_impl(x, *, rsd_threshold=None, threshold=None) ->
    # (out, approx_mask)` -- typically a partial over
    # `substrate.taf_matmul_region` / `substrate.iact_ffn_region` (the
    # memoization techniques are the only region-shaped ones; perforation
    # stays loop-shaped via perforated_loop / substrate.attention_region).
    substrate: Optional[str] = None
    pallas_impl: Optional[Callable] = None

    def _resolve_substrate(self) -> str:
        sub = substrate_mod.resolve(self.substrate)
        if sub == substrate_mod.PALLAS and self.pallas_impl is None:
            raise ValueError(
                "substrate='pallas' needs a pallas_impl: a kernel-backed "
                "implementation of this region (see repro.core.substrate)")
        return sub

    def init_state(self):
        t = self.spec.technique
        if t == Technique.TAF:
            return taf_mod.init(self.spec.taf, self.n_elements, self.out_shape,
                                self.out_dtype)
        if t == Technique.IACT:
            n_tab = iact_mod.n_tables_for(self.spec.iact, self.n_elements)
            return iact_mod.init(self.spec.iact, n_tab, self.in_dim,
                                 self.out_shape, self.out_dtype)
        return ()

    def _check_hooks(self, rsd_threshold, threshold):
        """Traced-parameter hooks are technique-specific: passing one the
        technique cannot honor is a spec bug, not a silent no-op."""
        t = self.spec.technique
        if rsd_threshold is not None and t != Technique.TAF:
            raise ValueError(
                f"rsd_threshold is a TAF hook; region technique is {t}")
        if threshold is not None and t != Technique.IACT:
            raise ValueError(
                f"threshold is an iACT hook; region technique is {t}")

    def step(self, state, x: Optional[jnp.ndarray] = None, *,
             rsd_threshold=None, threshold=None):
        """Functional single-invocation step -> (out, new_state, approx_mask).

        `rsd_threshold` (TAF) / `threshold` (iACT) are the traced-parameter
        hooks -- possibly traced scalars overriding the spec's static value,
        which is how a region participates in a vmapped batched sweep.
        Passing a hook the technique doesn't support raises ValueError.

        On the pallas substrate the kernel implementation is invoked (one
        kernel call = one invocation); the kernel owns its AC state in
        scratch memory, so `state` passes through unchanged.
        """
        self._check_hooks(rsd_threshold, threshold)
        t = self.spec.technique
        # Only the memoization techniques dispatch to a kernel here: NONE
        # (the exact region) runs its fn on any substrate, and PERFORATION
        # keeps its "use perforated_loop" contract on both substrates (the
        # loop-shaped techniques never fit the region step/run shape).
        if t in (Technique.TAF, Technique.IACT) and \
                self._resolve_substrate() == substrate_mod.PALLAS:
            out, mask = self.pallas_impl(x, rsd_threshold=rsd_threshold,
                                         threshold=threshold)
            return out, state, mask
        if t == Technique.TAF:
            thunk = (lambda: self.fn(x)) if x is not None else self.fn
            return taf_mod.step(state, thunk, self.spec.taf, self.spec.level,
                                tile_size=self.tile_size,
                                rsd_threshold=rsd_threshold)
        if t == Technique.IACT:
            return iact_mod.step(state, x, self.fn, self.spec.iact,
                                 self.spec.level, tile_size=self.tile_size,
                                 threshold=threshold)
        if t == Technique.NONE:
            y = self.fn(x) if x is not None else self.fn()
            return y, state, jnp.zeros((self.n_elements,), bool)
        raise ValueError(f"ApproxRegion.step does not handle {t}; use "
                         "perforated_loop for perforation")

    def run(self, xs: jnp.ndarray, *, rsd_threshold=None, threshold=None):
        """Run a whole invocation sequence (T, N, ...) under scan.

        Accepts the same traced-parameter hooks as `step`.
        Returns (outputs, approx_fraction).

        On the pallas substrate a single kernel call IS the invocation
        sequence (the sequential TPU grid is the paper's temporal loop), so
        `xs` is passed through whole and the kernel's approx mask yields
        the fraction.
        """
        self._check_hooks(rsd_threshold, threshold)
        t = self.spec.technique
        if t in (Technique.TAF, Technique.IACT) and \
                self._resolve_substrate() == substrate_mod.PALLAS:
            ys, mask = self.pallas_impl(xs, rsd_threshold=rsd_threshold,
                                        threshold=threshold)
            return ys, jnp.mean(jnp.asarray(mask).astype(jnp.float32))
        if t == Technique.TAF:
            ys, _, frac = taf_mod.run_sequence(self.spec.taf, xs, self.fn,
                                               self.spec.level,
                                               tile_size=self.tile_size,
                                               rsd_threshold=rsd_threshold)
            return ys, frac
        if t == Technique.IACT:
            ys, _, frac = iact_mod.run_sequence(self.spec.iact, xs, self.fn,
                                                self.spec.level,
                                                tile_size=self.tile_size,
                                                threshold=threshold)
            return ys, frac
        if t == Technique.NONE:
            ys = jax.lax.map(self.fn, xs)
            return ys, jnp.float32(0.0)
        raise ValueError(f"ApproxRegion.run does not handle {t}")


def perforated_loop(spec: ApproxSpec, n_iters: int,
                    body: Callable[[int, object], object], carry,
                    herded_structural: bool = True, fraction=None):
    """`for i in range(n): carry = body(i, carry)` with loop perforation.

    With herded perforation (spec.perforation.herded) the kept-iteration set
    is static, so the loop is *structurally* shortened (fori over the kept
    subset): iterations are genuinely not executed -- the paper's uniform
    control flow payoff. Returns (carry, executed_fraction).

    `fraction` is the traced-parameter hook: a (possibly traced) scalar
    overriding spec.perforation.fraction for the fraction-driven kinds
    (ini/fini/random). A traced fraction cannot shorten the loop
    structurally, so this path is the MASKED, non-herded variant: every
    iteration runs and the execute-mask (computed in-trace from the
    fraction) gates the body -- which is exactly what lets a batched runner
    vmap one compiled loop over a stack of fractions. The executed fraction
    is then a traced scalar too.
    """
    if spec.technique != Technique.PERFORATION:
        if fraction is not None:
            raise ValueError(
                f"fraction is a perforation hook; spec technique is "
                f"{spec.technique} (a hook the technique cannot honor is a "
                "spec bug, not a silent no-op)")
        for_all = jax.lax.fori_loop(
            0, n_iters, lambda i, c: body(i, c), carry)
        return for_all, 1.0
    p = spec.perforation
    if fraction is not None:
        mask_arr = perfo_mod.traced_execute_mask(n_iters, p, fraction)

        def traced_masked_body(i, c):
            return jax.lax.cond(mask_arr[i], lambda cc: body(i, cc),
                                lambda cc: cc, c)

        out = jax.lax.fori_loop(0, n_iters, traced_masked_body, carry)
        return out, jnp.mean(mask_arr.astype(jnp.float32))
    keep = perfo_mod.kept_indices(n_iters, p)
    if herded_structural and p.herded:
        keep_arr = jnp.asarray(keep, jnp.int32)

        def kept_body(j, c):
            return body(keep_arr[j], c)

        out = jax.lax.fori_loop(0, len(keep), kept_body, carry)
        return out, len(keep) / max(n_iters, 1)
    # Non-herded / masked fallback: the loop still visits every index, but
    # `body` is never invoked for a skipped iteration -- `lax.cond` passes
    # the carry through unchanged, so the saving is the body's compute
    # (uniformity, not trip count, is what this variant gives up).
    mask = perfo_mod.execute_mask(n_iters, p)
    mask_arr = jnp.asarray(mask)

    def masked_body(i, c):
        return jax.lax.cond(mask_arr[i], lambda cc: body(i, cc),
                            lambda cc: cc, c)

    out = jax.lax.fori_loop(0, n_iters, masked_body, carry)
    return out, float(mask.mean())
