"""The batched-runner protocol: one compiled evaluation per spec *group*.

The paper's harness "exhaustively explores the space of user-provided
approximation techniques and parameters" (section 2.3); on this substrate
the dominant sweep cost is one XLA compile + dispatch per spec. But most of
a Table-2 grid varies only a *scalar* knob (the TAF RSD threshold, the iACT
distance threshold, the perforation fraction) while the structural
parameters -- which shape the technique state and therefore the compiled
program -- stay fixed. Those scalars are traced-parameter hooks
(`taf.run_sequence(rsd_threshold=...)`, `iact.run_sequence(threshold=...)`,
`perforated_loop(fraction=...)`), so a whole group of specs sharing their
static structure evaluates as ONE compiled `jax.vmap` over the stacked
scalars.

This module is the reusable middle layer between `harness.run_specs` (which
calls `ApproxApp.run_batch` in chunks of `jobs`) and the apps:

  static_key(spec)   -- hashable (technique, level, structural-params) key;
                        None when the spec has no traced scalar (e.g.
                        skip-driven perforation) and must run serially.
  traced_param(spec) -- the spec's traced scalar.
  group_specs(specs) -- indices grouped by static_key + the serial leftovers.
  make_run_batch(..) -- assembles an `ApproxApp.run_batch` from an app's
                        `make_group_fn(key) -> fn(stacked_params)` factory.

An app's `make_group_fn(key)` returns a compiled callable mapping a (B,)
array of traced scalars to `(qoi_stack, frac_stack)` (optionally a third
dict of stacked per-spec extras), or None to decline the group (serial
fallback). Apps cache the compiled callable per key (`functools.lru_cache`)
so resumed or densified sweeps recompile nothing.
"""
from __future__ import annotations

import inspect
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from . import iact as iact_mod
from . import taf as taf_mod
from .harness import AppResult
from .perforation import FRACTION_KINDS  # re-export: the traced-fraction
#    kinds; skip-driven kinds are structural and cannot share a compile
from .types import (ApproxSpec, IACTParams, PerforationParams, TAFParams,
                    Technique)


def static_key(spec: ApproxSpec) -> Optional[Tuple]:
    """Hashable static-structure key, or None when the spec has no traced
    scalar and must be evaluated serially.

    Two specs with the same key differ ONLY in their traced parameter, so
    they can share one compiled (vmapped) evaluation.
    """
    if spec.technique == Technique.TAF:
        return (Technique.TAF, spec.level, spec.taf.history_size,
                spec.taf.prediction_size)
    if spec.technique == Technique.IACT:
        return (Technique.IACT, spec.level, spec.iact.table_size,
                spec.iact.tables_per_block)
    if spec.technique == Technique.PERFORATION:
        p = spec.perforation
        if p.kind in FRACTION_KINDS:
            return (Technique.PERFORATION, spec.level, p.kind, p.herded,
                    p.seed)
        return None  # small/large: `skip` is structural, nothing to stack
    return None


def params_from_key(key: Tuple):
    """Reconstruct a static key's technique params, traced scalar zeroed
    (it is supplied per vmap lane). The single inverse of `static_key`, so
    apps never index into the key tuple themselves."""
    tech = key[0]
    if tech == Technique.TAF:
        return TAFParams(key[2], key[3], 0.0)
    if tech == Technique.IACT:
        return IACTParams(key[2], 0.0, key[3])
    if tech == Technique.PERFORATION:
        return PerforationParams(kind=key[2], herded=key[3], seed=key[4])
    raise ValueError(f"not a batchable static key: {key}")


def spec_from_key(key: Tuple) -> ApproxSpec:
    """The static key as an ApproxSpec (traced scalar zeroed)."""
    tech, level = key[0], key[1]
    p = params_from_key(key)
    return ApproxSpec(tech, level,
                      taf=p if tech == Technique.TAF else None,
                      iact=p if tech == Technique.IACT else None,
                      perforation=p if tech == Technique.PERFORATION
                      else None)


def sequence_runner(key: Tuple, xs, fn):
    """`lambda th -> (ys, approx_fraction)` over the technique's
    run_sequence with the key's static params and `th` as the traced
    scalar -- the shared body of the memoization apps' group runners.
    Returns None for keys with no run_sequence shape (perforation)."""
    tech, level = key[0], key[1]
    params = params_from_key(key)
    if tech == Technique.TAF:
        def run(th):
            ys, _, frac = taf_mod.run_sequence(params, xs, fn, level,
                                               rsd_threshold=th)
            return ys, frac
        return run
    if tech == Technique.IACT:
        def run(th):
            ys, _, frac = iact_mod.run_sequence(params, xs, fn, level,
                                                threshold=th)
            return ys, frac
        return run
    return None


def traced_param(spec: ApproxSpec) -> float:
    """The spec's traced scalar (the parameter a batched runner stacks)."""
    if spec.technique == Technique.TAF:
        return float(spec.taf.rsd_threshold)
    if spec.technique == Technique.IACT:
        return float(spec.iact.threshold)
    if spec.technique == Technique.PERFORATION and \
            spec.perforation.kind in FRACTION_KINDS:
        return float(spec.perforation.fraction)
    raise ValueError(f"spec {spec} has no traced parameter")


def group_specs(specs: Sequence[ApproxSpec], min_group: int = 2
                ) -> Tuple[Dict[Tuple, List[int]], List[int]]:
    """Partition spec indices into vmappable groups and serial leftovers.

    Groups smaller than `min_group` are demoted to the serial list: a
    one-lane vmap amortizes nothing but still costs a fresh compile.
    """
    groups: Dict[Tuple, List[int]] = {}
    serial: List[int] = []
    for i, spec in enumerate(specs):
        key = static_key(spec)
        if key is None:
            serial.append(i)
        else:
            groups.setdefault(key, []).append(i)
    for key in [k for k, idxs in groups.items() if len(idxs) < min_group]:
        serial.extend(groups.pop(key))
    return groups, sorted(serial)


def group_lanes(specs: Sequence[Optional[ApproxSpec]]
                ) -> Tuple[Dict[Tuple, Tuple[List[int], List[float]]],
                           List[int]]:
    """Partition PER-LANE specs for one batched serving tick.

    Where `group_specs` partitions a sweep grid (and demotes tiny groups to
    the serial path -- a sweep can reorder freely), lanes are positional: a
    continuous-batching tick serves lane i's request at index i, so every
    lane must land somewhere and singleton groups are kept. Returns

      groups:  static-structure key -> (lane indices, their traced knobs)
               -- each group can run as ONE vmapped call per tick;
      precise: lanes whose spec is None / technique NONE (the exact path).

    A lane spec with no traced knob (skip-driven perforation) cannot be
    served under a shared compiled step and raises -- serving ladders are
    validated up front (`repro.qos.policy.validate_ladder_knobs`), so this
    is a programming error, not a runtime condition.
    """
    groups: Dict[Tuple, Tuple[List[int], List[float]]] = {}
    precise: List[int] = []
    for i, spec in enumerate(specs):
        if spec is None or spec.technique == Technique.NONE:
            precise.append(i)
            continue
        key = static_key(spec)
        if key is None:
            raise ValueError(
                f"lane {i} spec {spec} has no traced quality knob and "
                "cannot share a compiled serving step")
        idxs, knobs = groups.setdefault(key, ([], []))
        idxs.append(i)
        knobs.append(traced_param(spec))
    return groups, precise


def _default_result(qoi: np.ndarray, frac: float, extra: Dict,
                    wall: float) -> AppResult:
    return AppResult(qoi=qoi, wall_time_s=wall, approx_fraction=frac,
                     flop_fraction=max(1.0 - frac, 1e-3), extra=extra)


def _per_spec_extra(extras: Dict[str, np.ndarray], j: int) -> Dict:
    out = {}
    for k, v in extras.items():
        vj = np.asarray(v)[j]
        out[k] = vj.item() if np.ndim(vj) == 0 else vj
    return out


def run_batch_grouped(
        specs: Sequence[ApproxSpec],
        run_one: Callable[[ApproxSpec], AppResult],
        make_group_fn: Callable[[Tuple], Optional[Callable]],
        result_builder: Callable[..., AppResult] = _default_result,
        min_group: int = 2) -> List[AppResult]:
    """Evaluate `specs`, vmapping each static-structure group in one
    compiled call and falling back to `run_one` for the rest.

    Per group: `fn = make_group_fn(key)` is called twice on the stacked
    traced parameters -- once to compile + warm up, once timed -- and the
    batch wall time is amortized per spec (the same best-effort statistic
    the serial apps report after their own warmup call). `fn` returns
    `(qoi_stack, frac_stack)` or `(qoi_stack, frac_stack, extras_dict)`
    with every stack's leading dim == len(group).

    `result_builder(qoi, frac, extra, wall[, spec])` assembles each
    AppResult; builders that declare a 5th parameter also receive the
    spec (needed e.g. for technique-dependent FLOP accounting).
    """
    wants_spec = len(inspect.signature(result_builder).parameters) >= 5
    results: List[Optional[AppResult]] = [None] * len(specs)
    groups, serial = group_specs(specs, min_group=min_group)
    for i in serial:
        results[i] = run_one(specs[i])
    for key, idxs in groups.items():
        fn = make_group_fn(key)
        if fn is None:
            for i in idxs:
                results[i] = run_one(specs[i])
            continue
        params = jnp.asarray([traced_param(specs[i]) for i in idxs],
                             jnp.float32)
        from repro.obs import trace
        from repro.obs.timing import measure
        with trace.span("batching.group_compile", key=str(key),
                        specs=len(idxs)):
            jax.block_until_ready(fn(params))  # compile + warmup
        m = measure(fn, params, warmup=0, repeats=1,
                    span="batching.group_run")
        out, wall = m.value, m.seconds / len(idxs)
        qois, fracs = out[0], out[1]
        extras = out[2] if len(out) > 2 else {}
        qois = np.asarray(qois)
        fracs = np.asarray(fracs)
        if qois.shape[0] != len(idxs) or fracs.shape[0] != len(idxs):
            raise ValueError(
                f"group runner for {key} returned leading dim "
                f"{qois.shape[0]}/{fracs.shape[0]} for {len(idxs)} specs")
        for j, i in enumerate(idxs):
            args = (qois[j], float(fracs[j]), _per_spec_extra(extras, j),
                    wall)
            results[i] = (result_builder(*args, specs[i]) if wants_spec
                          else result_builder(*args))
    return results


def make_run_batch(run_one, make_group_fn,
                   result_builder: Callable[..., AppResult] = _default_result,
                   min_group: int = 2):
    """Build an `ApproxApp.run_batch` from an app's group-runner factory."""
    def run_batch(specs: Sequence[ApproxSpec]) -> List[AppResult]:
        return run_batch_grouped(specs, run_one, make_group_fn,
                                 result_builder=result_builder,
                                 min_group=min_group)
    return run_batch
