"""iACT: approximate input memoization -- paper sections 2.3, 3.1.4, 3.3.

Cache (input, output) pairs per table; a new invocation whose input lies
within `threshold` Euclidean distance of a cached input returns the cached
output, skipping the region.

GPU adaptations reproduced here:
  * Table sharing (paper `tperwarp` -> `tables_per_block`): elements are
    partitioned into groups that share one table, trading memory for a larger
    *aggregate* table and cross-element value reuse (paper section 3.1.4 advantages
    (1)-(3)).
  * Two-phase access (paper section 3.3): a read phase where all elements probe
    their table, then a write phase where a SINGLE writer per table -- the
    element with the largest distance from any table value -- inserts, with
    round-robin replacement. (Paper footnote 3: CLOCK gave no benefit.)
  * Hierarchical activation: the hit mask is voted per Level before use.

Like TAF, state is a pytree: usable under scan or as VMEM scratch in the
Pallas kernel variant (kernels/iact_memo.py).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import hierarchy
from .types import IACTParams, Level


class IACTState(NamedTuple):
    """`n_tables` memo tables of `table_size` entries each."""

    keys: jnp.ndarray       # (T, S, in_dim) cached inputs
    values: jnp.ndarray     # (T, S, *out_shape) cached outputs
    valid: jnp.ndarray      # (T, S) bool
    next_slot: jnp.ndarray  # (T,) int32 round-robin cursor


def init(params: IACTParams, n_tables: int, in_dim: int,
         out_shape: Tuple[int, ...] = (), dtype=jnp.float32) -> IACTState:
    return IACTState(
        keys=jnp.zeros((n_tables, params.table_size, in_dim), jnp.float32),
        values=jnp.zeros((n_tables, params.table_size) + tuple(out_shape), dtype),
        valid=jnp.zeros((n_tables, params.table_size), bool),
        next_slot=jnp.zeros((n_tables,), jnp.int32),
    )


def n_tables_for(params: IACTParams, n_elements: int) -> int:
    """Paper `tperwarp` semantics: tables per decision block of elements.

    tables_per_block == 0 -> one private table per element (paper default of
    one per thread). Otherwise `tables_per_block` tables serve each block of
    `block` elements; we normalize to a whole-population table count.
    """
    if params.tables_per_block == 0:
        return n_elements
    return max(1, min(n_elements, params.tables_per_block))


def _read_phase(state: IACTState, x: jnp.ndarray, params: IACTParams,
                threshold=None):
    """All elements probe their table. x: (T, G, in_dim) grouped inputs.

    `threshold` overrides params.threshold; it may be a traced scalar, which
    is what lets a batched runner `jax.vmap` one compiled sweep over a stack
    of activation thresholds (table_size / tables_per_block stay static --
    they shape the state).

    Returns (hit (T,G), best_value (T,G,*out), min_dist (T,G)).
    """
    if threshold is None:
        threshold = params.threshold
    # distances: (T, G, S)
    diff = x[:, :, None, :] - state.keys[:, None, :, :]
    dist = jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    dist = jnp.where(state.valid[:, None, :], dist, jnp.inf)
    best = jnp.argmin(dist, axis=-1)                       # (T, G)
    min_dist = jnp.take_along_axis(dist, best[..., None], axis=-1)[..., 0]
    best_value = jnp.take_along_axis(
        state.values, best.reshape(best.shape + (1,) * (state.values.ndim - 2)),
        axis=1)
    hit = min_dist < threshold
    return hit, best_value, min_dist


def _write_phase(state: IACTState, x: jnp.ndarray, y: jnp.ndarray,
                 computed: jnp.ndarray, min_dist: jnp.ndarray) -> IACTState:
    """Single writer per table: the computed element farthest from any cached
    value inserts at the round-robin cursor (paper section 3.3)."""
    neg_inf = jnp.float32(-jnp.inf)
    score = jnp.where(computed, jnp.where(jnp.isinf(min_dist),
                                          jnp.float32(jnp.finfo(jnp.float32).max),
                                          min_dist), neg_inf)
    writer = jnp.argmax(score, axis=1)                      # (T,)
    any_writer = jnp.any(computed, axis=1)                  # (T,)
    t_idx = jnp.arange(state.keys.shape[0])
    wx = x[t_idx, writer]                                   # (T, in_dim)
    wy = y[t_idx, writer]                                   # (T, *out)
    slot = state.next_slot                                  # (T,)
    keys = state.keys.at[t_idx, slot].set(
        jnp.where(any_writer[:, None], wx, state.keys[t_idx, slot]))
    values = state.values.at[t_idx, slot].set(
        jnp.where(any_writer.reshape((-1,) + (1,) * (state.values.ndim - 2)),
                  wy, state.values[t_idx, slot]))
    valid = state.valid.at[t_idx, slot].set(
        state.valid[t_idx, slot] | any_writer)
    next_slot = jnp.where(any_writer,
                          (slot + 1) % state.keys.shape[1], slot)
    return IACTState(keys, values, valid, next_slot)


def step(state: IACTState, x: jnp.ndarray,
         accurate_fn: Callable[[jnp.ndarray], jnp.ndarray],
         params: IACTParams, level: Level = Level.ELEMENT,
         tile_size: Optional[int] = None,
         threshold=None):
    """One invocation over all

    elements. x: (N, in_dim); accurate_fn: (N, in_dim) -> (N, *out).
    Elements are grouped contiguously onto tables: group g = elements
    [g*G, (g+1)*G) where G = N / n_tables.

    `threshold` (optional, possibly traced) overrides params.threshold --
    the batched-runner hook (see _read_phase).

    Returns (outputs (N, *out), new_state, approx_mask (N,)).
    """
    T = state.keys.shape[0]
    N = x.shape[0]
    if N % T != 0:
        raise ValueError(f"n_elements {N} must be divisible by n_tables {T}")
    G = N // T
    xg = x.reshape(T, G, -1).astype(jnp.float32)

    hit, best_value, min_dist = _read_phase(state, xg, params,
                                            threshold=threshold)
    approx_mask = hierarchy.vote(hit.reshape(-1), level, tile_size=tile_size)
    approx_g = approx_mask.reshape(T, G)

    if level == Level.BLOCK:
        # Scalar decision: genuinely skip the accurate path when possible.
        decision = hierarchy.block_majority(hit.reshape(-1))

        def approx_branch(st):
            out = best_value  # every element takes its nearest cached value
            return out.reshape((N,) + out.shape[2:]), st

        def accurate_branch(st):
            y = accurate_fn(x)
            yg = y.reshape((T, G) + y.shape[1:])
            computed = jnp.ones((T, G), bool)
            st2 = _write_phase(st, xg, yg.astype(st.values.dtype), computed,
                               min_dist)
            return y.astype(st.values.dtype), st2

        out, new_state = jax.lax.cond(decision, approx_branch, accurate_branch,
                                      state)
        return out, new_state, jnp.broadcast_to(decision, (N,))

    # ELEMENT / TILE: dense compute + select. iACT "must always pay the cost
    # of deciding whether to approximate" (paper Insight 4) -- and on a vector
    # unit it here also pays the masked compute; the Pallas kernel variant
    # recovers real savings at block granularity.
    y = accurate_fn(x)
    yg = y.reshape((T, G) + y.shape[1:]).astype(state.values.dtype)
    sel = approx_g.reshape(approx_g.shape + (1,) * (yg.ndim - 2))
    out_g = jnp.where(sel, best_value, yg)
    computed = ~approx_g
    new_state = _write_phase(state, xg, yg, computed, min_dist)
    return out_g.reshape((N,) + yg.shape[2:]), new_state, approx_mask


def run_sequence(params: IACTParams, xs: jnp.ndarray,
                 fn: Callable[[jnp.ndarray], jnp.ndarray],
                 level: Level = Level.ELEMENT,
                 tile_size: Optional[int] = None,
                 threshold=None):
    """Scan `step` over invocations xs: (T_steps, N, in_dim).

    `threshold` (optional, possibly traced) overrides params.threshold --
    the hook the harness's batched runners use to vmap one compiled sweep
    over a stack of thresholds (the structural table params stay static).

    Returns (outputs, final_state, approx_fraction).
    """
    n = xs.shape[1]
    n_tab = n_tables_for(params, n)
    probe = jax.eval_shape(fn, jax.ShapeDtypeStruct(xs.shape[1:], xs.dtype))
    state0 = init(params, n_tab, xs.shape[-1], probe.shape[1:], probe.dtype)

    def body(state, x_t):
        out, new_state, mask = step(state, x_t, fn, params, level,
                                    tile_size=tile_size, threshold=threshold)
        return new_state, (out, mask)

    final, (ys, masks) = jax.lax.scan(body, state0, xs)
    return ys, final, jnp.mean(masks.astype(jnp.float32))
