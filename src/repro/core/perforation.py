"""Loop perforation -- paper sections 2.3, 3.1.5.

Patterns:
  small(M): skip one of every M iterations.
  large(M): execute one of every M iterations.
  ini(f) / fini(f): drop the first / last fraction f of iterations
      (implemented, as in the paper, by changing the loop bounds).
  random(f): drop a pseudo-random fraction (HPAC parity).

Herded perforation (paper's GPU contribution, section 3.1.5): every element drops
the SAME iterations, so control flow is uniform across the machine. On TPU
this is what converts perforation from masking (zero savings) into a
*structurally smaller loop*: the kept-iteration set is static, so we simply
build shorter iteration spaces / skip whole blocks under ``@pl.when``.
Non-herded masks are provided for the error study (each element phase-shifts
its skip pattern, modeling per-thread counters).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np
import jax.numpy as jnp

from .types import PerforationKind, PerforationParams

# Kinds whose knob is the (traceable) fraction; skip-driven kinds
# (small/large) are purely structural. The single source of truth for the
# traced-fraction dispatch decision (batching, the attention kernel's
# masked mode, and `traced_execute_mask` below all share it).
FRACTION_KINDS = (PerforationKind.INI, PerforationKind.FINI,
                  PerforationKind.RANDOM)


def _n_dropped(fraction, n_iters: int) -> int:
    """floor(fraction * n_iters) in float32 -- the substrate's compute
    dtype, and what keeps the static mask bit-identical to
    `traced_execute_mask` (whose fraction arrives as a traced float32)."""
    return int(np.floor(np.float32(fraction) * np.float32(n_iters)))


def execute_mask(n_iters: int, params: PerforationParams) -> np.ndarray:
    """Static (host-side) bool mask, True = execute iteration. Herded view:
    identical for every element, hence a single 1-D mask.

    Fraction comparisons are performed in float32 to match
    `traced_execute_mask` exactly (the batched path stacks fractions as
    float32 lanes)."""
    i = np.arange(n_iters)
    k = params.kind
    if k == PerforationKind.SMALL:
        mask = (i % params.skip) != (params.skip - 1)
    elif k == PerforationKind.LARGE:
        mask = (i % params.skip) == 0
    elif k == PerforationKind.INI:
        mask = i >= _n_dropped(params.fraction, n_iters)
    elif k == PerforationKind.FINI:
        mask = i < (n_iters - _n_dropped(params.fraction, n_iters))
    elif k == PerforationKind.RANDOM:
        rng = np.random.RandomState(params.seed)
        mask = rng.uniform(size=n_iters).astype(np.float32) >= \
            np.float32(params.fraction)
    else:
        raise ValueError(f"unknown perforation kind {k}")
    return mask


def traced_execute_mask(n_iters: int, params: PerforationParams,
                        fraction=None) -> jnp.ndarray:
    """Execute-mask as a jnp array whose `fraction` may be a TRACED scalar.

    Only the fraction-driven kinds (ini/fini/random) admit a traced
    parameter -- skip-driven kinds (small/large) are purely structural.
    Matches `execute_mask` exactly when `fraction == params.fraction`
    (both compute the fraction comparisons in float32), so a batched
    (vmapped-over-fractions) evaluation reproduces the static path's
    results lane for lane.
    """
    if fraction is None:
        fraction = params.fraction
    fraction = jnp.asarray(fraction, jnp.float32)
    i = jnp.arange(n_iters)
    k = params.kind
    if k == PerforationKind.INI:
        return i >= jnp.floor(fraction * n_iters)
    if k == PerforationKind.FINI:
        return i < n_iters - jnp.floor(fraction * n_iters)
    if k == PerforationKind.RANDOM:
        u = jnp.asarray(
            np.random.RandomState(params.seed).uniform(size=n_iters),
            jnp.float32)
        return u >= fraction
    raise ValueError(
        f"perforation kind {k} has no traced fraction (skip is structural)")


def kept_indices(n_iters: int, params: PerforationParams) -> np.ndarray:
    """Indices of executed iterations -- the structural form used to build a
    genuinely smaller loop (herded perforation's payoff on TPU)."""
    return np.nonzero(execute_mask(n_iters, params))[0]


def element_masks(n_iters: int, n_elements: int,
                  params: PerforationParams) -> np.ndarray:
    """(n_elements, n_iters) masks. Herded: all rows identical. Non-herded:
    row e is phase-shifted by e (models private per-thread counters whose
    region-encounter counts differ across threads -- the divergent case the
    paper's herding eliminates)."""
    base = execute_mask(n_iters, params)
    if params.herded:
        return np.broadcast_to(base, (n_elements, n_iters)).copy()
    if params.kind in (PerforationKind.SMALL, PerforationKind.LARGE):
        rows = [np.roll(base, e % params.skip) for e in range(n_elements)]
        return np.stack(rows)
    if params.kind == PerforationKind.RANDOM:
        rows = []
        for e in range(n_elements):
            rng = np.random.RandomState(params.seed + e)
            rows.append(rng.uniform(size=n_iters) >= params.fraction)
        return np.stack(rows)
    # ini/fini change loop bounds; they are inherently uniform.
    return np.broadcast_to(base, (n_elements, n_iters)).copy()


def perforated_bounds(n_iters: int, params: PerforationParams) -> Tuple[int, int]:
    """Loop bounds for ini/fini (paper: 'the compiler generates code to change
    the lower or upper bounds of the loop')."""
    if params.kind == PerforationKind.INI:
        return int(np.floor(params.fraction * n_iters)), n_iters
    if params.kind == PerforationKind.FINI:
        return 0, n_iters - int(np.floor(params.fraction * n_iters))
    raise ValueError("perforated_bounds applies to ini/fini only")


def drop_fraction(n_iters: int, params: PerforationParams) -> float:
    """Fraction of iterations dropped = upper bound on FLOP savings."""
    return 1.0 - float(execute_mask(n_iters, params).mean())


def perforated_sum(xs: jnp.ndarray, params: PerforationParams,
                   axis: int = 0, rescale: bool = True) -> jnp.ndarray:
    """Reduce `xs` over `axis` using only kept iterations.

    `rescale` multiplies by n/kept -- the standard perforation extrapolation
    for additive reductions so the magnitude of the QoI is preserved.
    """
    keep = kept_indices(xs.shape[axis], params)
    sub = jnp.take(xs, jnp.asarray(keep), axis=axis)
    total = jnp.sum(sub, axis=axis)
    if rescale and len(keep) > 0:
        total = total * (xs.shape[axis] / len(keep))
    return total
