"""Approximation autotuning -- the paper's stated future work (section 4.2):

"there is considerable value in work that automates the end-to-end workflow
 [...] smart search/optimization techniques (genetic algorithms, Bayesian
 Optimization) to reduce parameter exploration costs."

`successive_halving` replaces the exhaustive Cartesian sweep with a
multi-fidelity race: all configs are evaluated on a cheap fidelity (few
repeats / reduced workload), the best `1/eta` survive to the next rung at
higher fidelity. `random_search` is the budget-capped baseline. Both emit
the same Record stream as harness.sweep (via the shared
`harness.evaluate_spec` scoring path), so benchmarks and the results
database are drop-in compatible, and both dispatch evaluations through
`harness.run_specs` (so `jobs > 1` uses an app's batched runner when it has
one). For Pareto-front-guided refinement of a coarse grid, see
`repro.core.pareto.refine`.
"""
from __future__ import annotations

import random
from typing import Callable, List, Optional, Sequence

from repro.obs import trace

from . import substrate as substrate_mod
from .harness import AppResult, ApproxApp, Record, _make_record, run_specs
from .types import ApproxSpec


def _evaluate_all(app: ApproxApp, specs: Sequence[ApproxSpec],
                  exact: AppResult, repeats: int, jobs: int,
                  substrate: Optional[str] = None) -> List[Record]:
    """Score a pool of specs via harness.run_specs -- the same dispatch as
    sweep (batched runner when the app has one, thread pool otherwise).
    `substrate` scopes the ambient execution substrate (host/pallas)."""
    results = run_specs(app, specs, repeats, jobs, substrate=substrate)
    return [_make_record(app, s, res, exact)
            for s, res in zip(specs, results)]


def _score(rec: Record, max_error: float) -> float:
    """Tuning objective: modeled speedup, zeroed when over the error bound
    (the paper's 'best speedup with error < 10%' criterion)."""
    if not (rec.error < max_error):
        return 0.0
    return rec.modeled_speedup


def successive_halving(app: ApproxApp, specs: Sequence[ApproxSpec], *,
                       max_error: float = 0.10, eta: int = 3,
                       base_repeats: int = 1, jobs: int = 1,
                       seed: int = 0,
                       substrate: Optional[str] = None,
                       predict=None) -> List[Record]:
    """Multi-fidelity race over `specs`: each rung costs ~n_base cheap
    evaluations (the pool shrinks by eta while fidelity grows by eta), so
    the total is ~n x n_rungs vs n x final_fidelity for an exhaustive sweep
    at the final fidelity. Returns the FINAL rung's records, best first.
    `jobs > 1` evaluates each rung's pool concurrently. `substrate` scopes
    the ambient execution substrate for every evaluation.

    `predict` (an `analysis.cost.AppCostModel`) prunes the STARTING pool
    before the first rung runs: specs predicted sub-1x, or whose error
    bound already exceeds `max_error`, never consume evaluations."""
    rng = random.Random(seed)
    pool = list(specs)
    if predict is not None:
        from repro.analysis.cost import filter_specs
        pool, _ = filter_specs(predict, pool, max_error=max_error,
                               context=f"autotune:{app.name}")
    with substrate_mod.use(substrate):
        exact = app.exact()
    rng.shuffle(pool)
    repeats = base_repeats
    rung_records: List[Record] = []
    rung = 0
    while pool:
        with trace.span("autotune.rung", app=app.name, rung=rung,
                        pool=len(pool), repeats=repeats):
            rung_records = _evaluate_all(app, pool, exact, repeats, jobs,
                                         substrate)
        rung += 1
        ranked = sorted(zip(rung_records, pool),
                        key=lambda rs: -_score(rs[0], max_error))
        keep = max(1, len(pool) // eta)
        if len(pool) == keep or keep == 1 and len(pool) <= eta:
            rung_records = [r for r, _ in ranked[:keep]]
            break
        pool = [s for _, s in ranked[:keep]]
        repeats *= eta
    return sorted(rung_records, key=lambda r: -_score(r, max_error))


def random_search(app: ApproxApp, sampler: Callable[[random.Random],
                                                    ApproxSpec], *,
                  budget: int = 20, max_error: float = 0.10,
                  repeats: int = 1, jobs: int = 1,
                  seed: int = 0,
                  substrate: Optional[str] = None,
                  predict=None) -> List[Record]:
    """Budget-capped random search with a spec sampler. `substrate` scopes
    the ambient execution substrate for every evaluation.

    With `predict`, sampled specs that the cost model rejects (sub-1x
    predicted speedup or error bound over `max_error`) are re-drawn
    instead of measured, so the evaluation budget is spent only on
    plausible candidates (bounded redraws: a sampler whose whole support
    is rejected degrades to the unpredicted behavior)."""
    rng = random.Random(seed)
    with substrate_mod.use(substrate):
        exact = app.exact()
    if predict is None:
        specs = [sampler(rng) for _ in range(budget)]
    else:
        from repro.analysis.cost import filter_specs
        specs, attempts = [], 0
        while len(specs) < budget and attempts < 20 * budget:
            draw = [sampler(rng) for _ in range(budget - len(specs))]
            attempts += len(draw)
            kept, _ = filter_specs(predict, draw, max_error=max_error,
                                   context=f"autotune:{app.name}")
            specs.extend(kept)
        specs = specs[:budget] or [sampler(rng) for _ in range(budget)]
    with trace.span("autotune.random_search", app=app.name,
                    budget=len(specs), repeats=repeats):
        records = _evaluate_all(app, specs, exact, repeats, jobs, substrate)
    return sorted(records, key=lambda r: -_score(r, max_error))
