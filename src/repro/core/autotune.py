"""Approximation autotuning -- the paper's stated future work (section 4.2):

"there is considerable value in work that automates the end-to-end workflow
 [...] smart search/optimization techniques (genetic algorithms, Bayesian
 Optimization) to reduce parameter exploration costs."

`successive_halving` replaces the exhaustive Cartesian sweep with a
multi-fidelity race: all configs are evaluated on a cheap fidelity (few
repeats / reduced workload), the best `1/eta` survive to the next rung at
higher fidelity. `random_search` is the budget-capped baseline. Both emit
the same Record stream as harness.sweep, so benchmarks and the results
database are drop-in compatible.
"""
from __future__ import annotations

import math
import random
from typing import Callable, List, Optional, Sequence

from .harness import AppResult, ApproxApp, ERROR_METRICS, Record, spec_to_dict
from .types import ApproxSpec


def _evaluate(app: ApproxApp, spec: ApproxSpec, exact: AppResult,
              repeats: int) -> Record:
    metric = ERROR_METRICS[app.error_metric]
    best: Optional[AppResult] = None
    for _ in range(max(1, repeats)):
        r = app.run(spec)
        if best is None or r.wall_time_s < best.wall_time_s:
            best = r
    return Record(
        app=app.name, spec=spec_to_dict(spec),
        error=metric(exact.qoi, best.qoi),
        speedup=exact.wall_time_s / max(best.wall_time_s, 1e-12),
        modeled_speedup=1.0 / max(best.flop_fraction, 1e-12),
        approx_fraction=float(best.approx_fraction),
        wall_time_s=best.wall_time_s, exact_time_s=exact.wall_time_s,
        extra=best.extra)


def _score(rec: Record, max_error: float) -> float:
    """Tuning objective: modeled speedup, zeroed when over the error bound
    (the paper's 'best speedup with error < 10%' criterion)."""
    if not (rec.error < max_error):
        return 0.0
    return rec.modeled_speedup


def successive_halving(app: ApproxApp, specs: Sequence[ApproxSpec], *,
                       max_error: float = 0.10, eta: int = 3,
                       base_repeats: int = 1,
                       seed: int = 0) -> List[Record]:
    """Multi-fidelity race over `specs`: each rung costs ~n_base cheap
    evaluations (the pool shrinks by eta while fidelity grows by eta), so
    the total is ~n x n_rungs vs n x final_fidelity for an exhaustive sweep
    at the final fidelity. Returns the FINAL rung's records, best first."""
    rng = random.Random(seed)
    exact = app.exact()
    pool = list(specs)
    rng.shuffle(pool)
    repeats = base_repeats
    rung_records: List[Record] = []
    while pool:
        rung_records = [_evaluate(app, s, exact, repeats) for s in pool]
        ranked = sorted(zip(rung_records, pool),
                        key=lambda rs: -_score(rs[0], max_error))
        keep = max(1, len(pool) // eta)
        if len(pool) == keep or keep == 1 and len(pool) <= eta:
            rung_records = [r for r, _ in ranked[:keep]]
            break
        pool = [s for _, s in ranked[:keep]]
        repeats *= eta
    return sorted(rung_records, key=lambda r: -_score(r, max_error))


def random_search(app: ApproxApp, sampler: Callable[[random.Random],
                                                    ApproxSpec], *,
                  budget: int = 20, max_error: float = 0.10,
                  repeats: int = 1, seed: int = 0) -> List[Record]:
    """Budget-capped random search with a spec sampler."""
    rng = random.Random(seed)
    exact = app.exact()
    records = [_evaluate(app, sampler(rng), exact, repeats)
               for _ in range(budget)]
    return sorted(records, key=lambda r: -_score(r, max_error))
