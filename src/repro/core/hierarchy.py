"""Hierarchical decision-making (paper section 3.1.2, 3.3).

The paper implements thread/warp/block "majority-rules" voting with CUDA
``ballot`` + ``popcount`` intrinsics. TPUs have no warp intrinsics; the vote is
a masked reduction over the decision group (DESIGN.md section 2), which on TPU is
essentially free next to the MXU work the vote can skip.

Semantics (paper): when the majority of a group's elements meet the activation
criteria, the ENTIRE group approximates; otherwise ALL elements take the
accurate path. A group vote can therefore force elements whose own criteria
were unmet to approximate (paper section 4, LavaMD discussion) -- this is
intentional and is what eliminates divergence.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp

from .types import Level, TILE_SHAPE


def grouped_majority(mask: jnp.ndarray, group_size: int, axis: int = -1) -> jnp.ndarray:
    """Majority-rules vote within contiguous groups of `group_size` along `axis`.

    Returns a mask of the same shape where every element carries its group's
    collective decision. `group_size` must divide the axis length.
    Majority = strictly more than half (ties -> accurate path), matching the
    paper's "if the majority of threads can approximate, the entire block
    follows suit".
    """
    axis = axis % mask.ndim
    n = mask.shape[axis]
    if group_size <= 1:
        return mask
    if n % group_size != 0:
        raise ValueError(f"group_size {group_size} must divide axis length {n}")
    new_shape = mask.shape[:axis] + (n // group_size, group_size) + mask.shape[axis + 1:]
    grouped = mask.reshape(new_shape)
    votes = jnp.sum(grouped, axis=axis + 1, keepdims=True)  # ballot+popcount
    decision = votes * 2 > group_size
    return jnp.broadcast_to(decision, new_shape).reshape(mask.shape)


def block_majority(mask: jnp.ndarray) -> jnp.ndarray:
    """Whole-array (block/team-level) vote. Returns a scalar bool.

    Scalar-ness matters: a scalar decision can drive ``lax.cond`` /
    ``@pl.when`` and therefore actually skip compute on TPU.
    """
    votes = jnp.sum(mask)
    return votes * 2 > mask.size


def vote(mask: jnp.ndarray, level: Level,
         tile_size: Optional[int] = None) -> jnp.ndarray:
    """Apply the hierarchy vote for `level` to a flat per-element mask.

    ELEMENT: identity (paper: per-thread decisions).
    TILE:    contiguous groups of `tile_size` (default: 128 lanes -- one VREG
             row; pass 1024 for a full 8x128 tile).
    BLOCK:   one decision for the whole mask, broadcast back.
    """
    if level == Level.ELEMENT:
        return mask
    if level == Level.TILE:
        ts = tile_size or TILE_SHAPE[1]
        if mask.size % ts != 0:
            # pad with False (accurate) votes so stragglers bias to accuracy
            pad = (-mask.size) % ts
            flat = jnp.concatenate([mask.reshape(-1), jnp.zeros((pad,), bool)])
            voted = grouped_majority(flat, ts)
            return voted[: mask.size].reshape(mask.shape)
        flat = mask.reshape(-1)
        return grouped_majority(flat, ts).reshape(mask.shape)
    if level == Level.BLOCK:
        return jnp.broadcast_to(block_majority(mask), mask.shape)
    raise ValueError(f"unknown level: {level}")


def tile_vote_2d(mask: jnp.ndarray, tile_shape: Tuple[int, int] = TILE_SHAPE) -> jnp.ndarray:
    """2-D tile vote used inside Pallas kernels where the decision unit is a
    (sublane, lane) = (8, 128) VREG tile."""
    th, tw = tile_shape
    h, w = mask.shape[-2], mask.shape[-1]
    if h % th or w % tw:
        raise ValueError(f"mask {mask.shape} not divisible by tile {tile_shape}")
    lead = mask.shape[:-2]
    g = mask.reshape(lead + (h // th, th, w // tw, tw))
    votes = jnp.sum(g, axis=(-3, -1), keepdims=True)
    decision = votes * 2 > (th * tw)
    return jnp.broadcast_to(decision, g.shape).reshape(mask.shape)
