"""Continuous-batching serving loop.

Production serving substrate: a slot-based scheduler multiplexes many
requests over one decode-step function. Requests enter a FIFO queue; free
slots are (re)filled via per-slot prefill; every engine tick decodes ONE
token for ALL active slots (the batched serve_step that decode_32k lowers);
finished sequences (EOS or max_tokens) free their slot immediately --
no head-of-line blocking on long generations.

Composes with the paper's technique: a TAF `approx_decode` config skips
stable layers inside the shared decode step, and the engine reports the
skipped-layer fraction alongside throughput.

QoS hook (docs/qos.md): pass `qos=QosEngine(...)` and the decode loop runs
under a controller-chosen spec. Each tick the engine groups live lanes by
their request class's current knob (`batching.group_lanes` via
`QosEngine.plan_tick`), actuates the strictest live rung by writing the
TAF threshold into the decode cache -- a TRACED value, so knob moves never
recompile -- and, on canary ticks, re-executes the step through the precise
model from the same pre-tick state and feeds the compared logits to the
quality monitor. A hard fallback zeroes both the threshold and the
in-flight prediction counters, so "precise" takes effect on the very next
token.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import ApproxSpec, Technique
from repro.launch import steps as steps_mod
from repro.models.lm import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    qos_class: str = "default"      # maps to a QosEngine target class
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


def _percentile(values: List[float], q: float) -> Optional[float]:
    if not values:
        return None
    return float(np.percentile(np.asarray(values, np.float64), q))


@dataclasses.dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    finished: int = 0
    taf_skipped: int = 0
    taf_total: int = 0
    canary_ticks: int = 0           # ticks re-executed through the oracle
    knob_moves: int = 0             # actuator writes (QoS rung changes)
    # per-request latency samples (seconds), appended as requests progress:
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    latency_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def taf_skip_fraction(self) -> float:
        return self.taf_skipped / max(self.taf_total, 1)

    @property
    def ttft_p50(self) -> Optional[float]:
        return _percentile(self.ttft_s, 50)

    @property
    def ttft_p99(self) -> Optional[float]:
        return _percentile(self.ttft_s, 99)

    @property
    def latency_p50(self) -> Optional[float]:
        return _percentile(self.latency_s, 50)

    @property
    def latency_p99(self) -> Optional[float]:
        return _percentile(self.latency_s, 99)

    def latency_summary(self) -> Dict[str, Optional[float]]:
        """Time-to-first-token and end-to-end request latency, p50/p99 --
        what the QoS benchmark reports alongside throughput and error."""
        return {
            "ttft_p50_s": self.ttft_p50, "ttft_p99_s": self.ttft_p99,
            "latency_p50_s": self.latency_p50,
            "latency_p99_s": self.latency_p99,
            "requests": len(self.latency_s),
        }


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch size."""

    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 256, prompt_len: int = 32, qos=None):
        self.model = model
        self.params = params
        self.n_slots = slots
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.queue: Deque[Request] = collections.deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)       # next write position
        self.limit = np.zeros(slots, np.int64)     # stop position
        self.stats = EngineStats()
        # one shared cache sized (slots, max_len); per-slot prefill writes
        # into its row via the batched prefill below
        self._prefill = jax.jit(steps_mod.make_prefill_step(model, max_len))
        self._serve = jax.jit(steps_mod.make_serve_step(model))
        self.cache = None
        self.tokens = jnp.zeros((slots,), jnp.int32)
        self.qos = qos
        self._knob: Optional[float] = None          # last actuated threshold
        # (tick, threshold) per actuation -- the engine-level knob
        # trajectory (controller trajectories live on the QosEngine)
        self.knob_log: List[tuple] = []
        self._serve_exact = None
        if qos is not None:
            if (model.cfg.approx_decode.technique != Technique.TAF
                    or model.cfg.use_mla or model.cfg.moe is not None):
                raise ValueError(
                    "QoS-controlled serving needs decode-time TAF: build "
                    "the model with cfg.approx_decode = a TAF spec (the "
                    "threshold is the online actuator)")
            # The actuator writes ONLY the threshold scalar, so every
            # rung must describe THIS model's decode step (the ladder
            # semantics live qos-side; see the helper's docstring).
            from repro.qos import validate_ladder_taf
            validate_ladder_taf(qos.policy, model.cfg.approx_decode.taf)
            # the canary oracle: the SAME params through a precise decode
            # step (approx_decode disabled). Its cache layout matches --
            # the extra 'taf' entry rides through the pytree untouched.
            from repro.models import build
            exact_model = build(dataclasses.replace(
                model.cfg, approx_decode=ApproxSpec()))
            self._serve_exact = jax.jit(
                steps_mod.make_serve_step(exact_model))

    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue.append(req)

    def _admit(self):
        """Fill free slots from the queue. Slot admission re-prefills the
        whole batch row-set for simplicity (single-host engine); a
        production multi-host engine prefilling per-slot uses the same
        cache layout with dynamic_update_slice on the batch dim."""
        free = [i for i, r in enumerate(self.active) if r is None]
        if not free or not self.queue:
            return
        changed = False
        for i in free:
            if not self.queue:
                break
            req = self.queue.popleft()
            self.active[i] = req
            self.pos[i] = self.prompt_len
            self.limit[i] = min(self.prompt_len + req.max_new_tokens,
                                self.max_len)
            changed = True
        if changed:
            prompts = np.zeros((self.n_slots, self.prompt_len), np.int32)
            for i, r in enumerate(self.active):
                if r is not None:
                    p = r.prompt[-self.prompt_len:]
                    prompts[i, -len(p):] = p
            logits, self.cache = self._prefill(self.params,
                                               {"tokens": jnp.asarray(prompts)})
            self.tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            self._knob = None   # prefill rebuilt the cache: re-actuate

    def _apply_knob(self, knob: Optional[float]):
        """Write the controller-chosen TAF threshold into the decode cache.

        The threshold is a traced input of the jitted serve step, so this
        is a pure data write -- no recompilation. `None` (precise) writes
        0.0 AND cancels in-flight predictions ("remaining"), making a hard
        fallback effective on the next token rather than after up to
        prediction_size more approximated layer-steps.
        """
        val = 0.0 if knob is None else float(knob)
        if self.cache is None or val == self._knob:
            return
        from repro.qos import set_decode_threshold
        self.cache = set_decode_threshold(self.cache, val)
        self._knob = val
        # Admission re-prefills rebuild the cache and force a re-apply of
        # the SAME value (self._knob reset to None); that is maintenance,
        # not a controller decision -- only genuine value changes are
        # knob moves in the stats and the trajectory artifact.
        if not self.knob_log or self.knob_log[-1][1] != val:
            self.stats.knob_moves += 1
            self.knob_log.append((self.stats.ticks, val))

    def tick(self) -> int:
        """One engine step: admit, decode one token for all active slots,
        retire finished requests. Returns number of live slots."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        lane_classes = []
        if self.qos is not None:
            lane_classes = [self.active[i].qos_class for i in live]
            plan = self.qos.plan_tick(lane_classes)
            self._apply_knob(plan.knob)
        pos = int(self.pos[live].min())  # single shared timeline position
        pre_tokens, pre_cache = self.tokens, self.cache
        self.tokens, logits, self.cache = self._serve(
            self.params, self.cache, self.tokens, jnp.int32(pos))
        if self.qos is not None and self.qos.should_sample():
            # canary: the precise oracle from the SAME pre-tick state.
            # Score ONLY the live lanes -- idle/retired slots hold
            # zero-padded or stale state nobody consumes, and their
            # garbage logits would pollute the quality estimate.
            _, exact_logits, _ = self._serve_exact(
                self.params, pre_cache, pre_tokens, jnp.int32(pos))
            self.qos.observe_decode(np.asarray(exact_logits)[live],
                                    np.asarray(logits)[live], lane_classes)
            self.stats.canary_ticks += 1
        toks = np.asarray(self.tokens)
        if self.cache is not None and "taf" in self.cache:
            rem = np.asarray(self.cache["taf"]["remaining"])
            self.stats.taf_skipped += int((rem > 0).sum())
            self.stats.taf_total += rem.size
        now = time.time()
        for i in live:
            req = self.active[i]
            if req.first_token_at is None:
                req.first_token_at = now
                self.stats.ttft_s.append(now - req.submitted_at)
            req.output.append(int(toks[i]))
            self.pos[i] += 1
            self.stats.tokens_out += 1
            done = (self.pos[i] >= self.limit[i] or
                    (req.eos_id is not None and toks[i] == req.eos_id))
            if done:
                req.finished_at = now
                self.stats.latency_s.append(now - req.submitted_at)
                self.active[i] = None
                self.stats.finished += 1
        self.stats.ticks += 1
        if self.qos is not None:
            self.qos.update(lane_classes)
        return len([r for r in self.active if r is not None])

    def run_until_drained(self, max_ticks: int = 10_000) -> EngineStats:
        for _ in range(max_ticks):
            live = self.tick()
            if live == 0 and not self.queue:
                break
        return self.stats
