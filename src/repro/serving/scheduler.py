"""Continuous-batching serving loop.

Production serving substrate: a slot-based scheduler multiplexes many
requests over one decode-step function. Requests enter a FIFO queue; free
slots are (re)filled via per-slot prefill; every engine tick decodes ONE
token for ALL active slots (the batched serve_step that decode_32k lowers);
finished sequences (EOS or max_tokens) free their slot immediately --
no head-of-line blocking on long generations.

Composes with the paper's technique: a TAF `approx_decode` config skips
stable layers inside the shared decode step, and the engine reports the
skipped-layer fraction alongside throughput.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.launch import steps as steps_mod
from repro.models.lm import Model


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


@dataclasses.dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    finished: int = 0
    taf_skipped: int = 0
    taf_total: int = 0

    @property
    def taf_skip_fraction(self) -> float:
        return self.taf_skipped / max(self.taf_total, 1)


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch size."""

    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 256, prompt_len: int = 32):
        self.model = model
        self.params = params
        self.n_slots = slots
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.queue: Deque[Request] = collections.deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)       # next write position
        self.limit = np.zeros(slots, np.int64)     # stop position
        self.stats = EngineStats()
        # one shared cache sized (slots, max_len); per-slot prefill writes
        # into its row via the batched prefill below
        self._prefill = jax.jit(steps_mod.make_prefill_step(model, max_len))
        self._serve = jax.jit(steps_mod.make_serve_step(model))
        self.cache = None
        self.tokens = jnp.zeros((slots,), jnp.int32)

    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue.append(req)

    def _admit(self):
        """Fill free slots from the queue. Slot admission re-prefills the
        whole batch row-set for simplicity (single-host engine); a
        production multi-host engine prefilling per-slot uses the same
        cache layout with dynamic_update_slice on the batch dim."""
        free = [i for i, r in enumerate(self.active) if r is None]
        if not free or not self.queue:
            return
        changed = False
        for i in free:
            if not self.queue:
                break
            req = self.queue.popleft()
            self.active[i] = req
            self.pos[i] = self.prompt_len
            self.limit[i] = min(self.prompt_len + req.max_new_tokens,
                                self.max_len)
            changed = True
        if changed:
            prompts = np.zeros((self.n_slots, self.prompt_len), np.int32)
            for i, r in enumerate(self.active):
                if r is not None:
                    p = r.prompt[-self.prompt_len:]
                    prompts[i, -len(p):] = p
            logits, self.cache = self._prefill(self.params,
                                               {"tokens": jnp.asarray(prompts)})
            self.tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def tick(self) -> int:
        """One engine step: admit, decode one token for all active slots,
        retire finished requests. Returns number of live slots."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        pos = int(self.pos[live].min())  # single shared timeline position
        self.tokens, _, self.cache = self._serve(
            self.params, self.cache, self.tokens, jnp.int32(pos))
        toks = np.asarray(self.tokens)
        if self.cache is not None and "taf" in self.cache:
            rem = np.asarray(self.cache["taf"]["remaining"])
            self.stats.taf_skipped += int((rem > 0).sum())
            self.stats.taf_total += rem.size
        now = time.time()
        for i in live:
            req = self.active[i]
            if req.first_token_at is None:
                req.first_token_at = now
            req.output.append(int(toks[i]))
            self.pos[i] += 1
            self.stats.tokens_out += 1
            done = (self.pos[i] >= self.limit[i] or
                    (req.eos_id is not None and toks[i] == req.eos_id))
            if done:
                req.finished_at = now
                self.active[i] = None
                self.stats.finished += 1
        self.stats.ticks += 1
        return len([r for r in self.active if r is not None])

    def run_until_drained(self, max_ticks: int = 10_000) -> EngineStats:
        for _ in range(max_ticks):
            live = self.tick()
            if live == 0 and not self.queue:
                break
        return self.stats
