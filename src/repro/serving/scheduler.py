"""Continuous-batching serving loop.

Production serving substrate: a slot-based scheduler multiplexes many
requests over one decode-step function. Requests enter a FIFO queue; free
slots are (re)filled via per-slot prefill; every engine tick decodes ONE
token for ALL active slots (the batched serve_step that decode_32k lowers);
finished sequences (EOS or max_tokens) free their slot immediately --
no head-of-line blocking on long generations.

Composes with the paper's technique: a TAF `approx_decode` config skips
stable layers inside the shared decode step, and the engine reports the
skipped-layer fraction alongside throughput.

QoS hook (docs/qos.md): pass `qos=QosEngine(...)` and the decode loop runs
under a controller-chosen spec. Each tick the engine groups live lanes by
their request class's current knob (`batching.group_lanes` via
`QosEngine.plan_tick`), actuates the strictest live rung by writing the
TAF threshold into the decode cache -- a TRACED value, so knob moves never
recompile -- and, on canary ticks, re-executes the step through the precise
model from the same pre-tick state and feeds the compared logits to the
quality monitor. A hard fallback zeroes both the threshold and the
in-flight prediction counters, so "precise" takes effect on the very next
token.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import ApproxSpec, Technique
from repro.launch import steps as steps_mod
from repro.models.lm import Model
from repro.obs import metrics as obs_metrics
from repro.obs import recorder as obs_recorder
from repro.obs import trace
from repro.obs.metrics import percentile as _percentile


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    qos_class: str = "default"      # maps to a QosEngine target class
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class KnobMove:
    """One actuator write: the typed record behind `knob_log`.

    `value`/`previous` are the threshold actually written -- a float, or
    a per-shard tuple on sharded engines (`previous` is None for the
    first actuation). `reason` classifies the move from the controller
    state and the value delta: init | tighten | loosen | fallback |
    mixed. Emitted as an obs `knob_move` event when tracing."""
    tick: int
    value: object
    previous: object
    reason: str
    shard: Optional[int] = None


@dataclasses.dataclass
class EngineStats:
    ticks: int = 0
    tokens_out: int = 0
    finished: int = 0
    taf_skipped: int = 0
    taf_total: int = 0
    canary_ticks: int = 0           # ticks re-executed through the oracle
    knob_moves: int = 0             # actuator writes (QoS rung changes)
    # per-request latency samples (seconds), appended as requests progress:
    ttft_s: List[float] = dataclasses.field(default_factory=list)
    latency_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def taf_skip_fraction(self) -> float:
        return self.taf_skipped / max(self.taf_total, 1)

    @property
    def ttft_p50(self) -> Optional[float]:
        return _percentile(self.ttft_s, 50)

    @property
    def ttft_p99(self) -> Optional[float]:
        return _percentile(self.ttft_s, 99)

    @property
    def latency_p50(self) -> Optional[float]:
        return _percentile(self.latency_s, 50)

    @property
    def latency_p99(self) -> Optional[float]:
        return _percentile(self.latency_s, 99)

    def latency_summary(self) -> Dict[str, Optional[float]]:
        """Time-to-first-token and end-to-end request latency, p50/p99 --
        what the QoS benchmark reports alongside throughput and error."""
        return {
            "ttft_p50_s": self.ttft_p50, "ttft_p99_s": self.ttft_p99,
            "latency_p50_s": self.latency_p50,
            "latency_p99_s": self.latency_p99,
            "requests": len(self.latency_s),
        }


class ServingEngine:
    """Slot-based continuous batching over a fixed decode batch size.

    Sharded mode (`mesh=`/`devices=`): the decode step runs
    `shard_map`'d over the mesh's data axes (`make_sharded_serve_step`)
    with `shards` logical shards of `slots // shards` contiguous lanes
    each. Logical shards are decoupled from the device count -- any
    multiple of the mesh's data extent -- so the same engine config runs
    1-device and 8-device with bit-identical outputs. Each shard carries
    its own TAF detector state and traced threshold knob; with `qos=`,
    the control plane is switched to per-shard actuation
    (`QosEngine.enable_sharding`) and every tick plans, canaries, and
    updates per shard.
    """

    def __init__(self, model: Model, params, *, slots: int = 4,
                 max_len: int = 256, prompt_len: int = 32, qos=None,
                 mesh=None, devices: Optional[int] = None,
                 shards: Optional[int] = None, lint: bool = False):
        self.model = model
        self.params = params
        self.n_slots = slots
        self.max_len = max_len
        self.prompt_len = prompt_len
        self.queue: Deque[Request] = collections.deque()
        self.active: List[Optional[Request]] = [None] * slots
        self.pos = np.zeros(slots, np.int64)       # next write position
        self.limit = np.zeros(slots, np.int64)     # stop position
        self.stats = EngineStats()
        if devices is not None and mesh is None:
            from repro.runtime import elastic
            if devices > len(jax.devices()):
                raise ValueError(
                    f"devices={devices} but only {len(jax.devices())} "
                    f"visible (set XLA_FLAGS="
                    f"--xla_force_host_platform_device_count=N for a fake "
                    f"multi-device host)")
            mesh = elastic.data_mesh_for(devices)
        self.mesh = mesh
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.runtime import sharding as shardlib
            # Commit the params to the mesh ONCE (replicated). Feeding the
            # sharded step uncommitted single-device arrays makes pjit
            # re-replicate every leaf on EVERY call -- per-tick
            # batched_device_put was the whole serving budget (~5ms/tick on
            # the 8-device CI host) before this landed.
            self.params = jax.device_put(
                params, NamedSharding(mesh, PartitionSpec()))
            da = shardlib.data_axes(mesh)
            n_data = 1
            for a in da:
                n_data *= int(mesh.shape[a])
            self.n_shards = int(shards) if shards is not None else n_data
            if self.n_shards < 1 or self.n_shards % n_data:
                raise ValueError(
                    f"shards ({self.n_shards}) must be a positive multiple "
                    f"of the mesh's data extent ({n_data})")
            if slots % self.n_shards:
                raise ValueError(
                    f"slots ({slots}) must divide evenly into "
                    f"{self.n_shards} shards")
        else:
            if shards not in (None, 1):
                raise ValueError(
                    "shards needs a mesh (pass devices=1 for a "
                    "single-device data-parallel mesh)")
            self.n_shards = 1
        self.lanes_per_shard = slots // self.n_shards
        # one shared cache sized (slots, max_len); per-slot prefill writes
        # into its row via the batched prefill below. Prefill stays a
        # plain jit even in sharded mode: admission cost is per-REQUEST
        # (not per-token) and its cache output is resharded once.
        self._prefill = jax.jit(steps_mod.make_prefill_step(model, max_len))
        self._lane_write = self._make_lane_write()
        if mesh is not None:
            self._serve = jax.jit(steps_mod.make_sharded_serve_step(
                model, mesh, self.n_shards, slots))
        else:
            self._serve = jax.jit(steps_mod.make_serve_step(model))
        self.cache = None
        self.tokens = self._place_tokens(jnp.zeros((slots,), jnp.int32))
        self.qos = qos
        self._knob = None                    # last actuated threshold(s)
        # typed engine-level knob trajectory (controller trajectories
        # live on the QosEngine); the legacy `knob_log` view derives
        # from it. Sharded engines log a per-shard tuple per move.
        self.knob_events: List[KnobMove] = []
        self._serve_exact = None
        if qos is not None:
            if (model.cfg.approx_decode.technique != Technique.TAF
                    or model.cfg.use_mla or model.cfg.moe is not None):
                raise ValueError(
                    "QoS-controlled serving needs decode-time TAF: build "
                    "the model with cfg.approx_decode = a TAF spec (the "
                    "threshold is the online actuator)")
            # The actuator writes ONLY the threshold scalar, so every
            # rung must describe THIS model's decode step (the ladder
            # semantics live qos-side; see the helper's docstring).
            from repro.qos import validate_ladder_taf
            validate_ladder_taf(qos.policy, model.cfg.approx_decode.taf)
            # the canary oracle: the SAME params through a precise decode
            # step (approx_decode disabled). Its cache layout matches --
            # the extra 'taf' entry rides through the pytree untouched.
            # In sharded mode the oracle goes through the SAME sharded
            # wrapper, so its lane->device packing (and therefore its
            # numerics) match the approximate step bit for bit.
            from repro.models import build
            exact_model = build(dataclasses.replace(
                model.cfg, approx_decode=ApproxSpec()))
            if mesh is not None:
                self._serve_exact = jax.jit(
                    steps_mod.make_sharded_serve_step(
                        exact_model, mesh, self.n_shards, slots))
                qos.enable_sharding(self.n_shards)
            else:
                self._serve_exact = jax.jit(
                    steps_mod.make_serve_step(exact_model))
        if lint:
            # opt-in approxlint pass over what this engine will actually
            # serve: the policy ladder (A004, raw entries, cross-checked
            # against THIS model's structural TAF params) and the mesh
            # commitment of every leaf already placed (A005 -- the params;
            # the cache is audited too once prefilled, but the params are
            # where the PR 6 per-tick re-shard regression lived)
            from repro.analysis import rules as lint_rules
            findings = []
            if qos is not None:
                t = model.cfg.approx_decode.taf
                findings += lint_rules.check_policy_document(
                    qos.policy.to_json(), subject="engine.policy",
                    model_taf=(t.history_size, t.prediction_size))
            findings += lint_rules.check_engine_placement(self)
            if findings:
                raise ValueError(
                    "approxlint found serving misconfigurations: "
                    + "; ".join(f"{f.rule} {f.subject}: {f.message}"
                                for f in findings))

    @property
    def knob_log(self) -> List[tuple]:
        """Backward-compatible `(tick, value)` view of `knob_events` --
        exactly the tuples the pre-obs list held, so `BENCH_qos.json`
        trajectories and the sharded-parity tests compare unchanged."""
        return [(m.tick, m.value) for m in self.knob_events]

    def _knob_reason(self, val, prev) -> str:
        """Classify an actuator write from controller state + the value
        delta. The plan's knob realizes decisions the controllers took at
        the END of the previous tick, so `in_fallback` is current here."""
        if prev is None:
            return "init"
        if self.qos is not None and any(
                c.in_fallback for c in self.qos.controllers.values()):
            return "fallback"
        old = prev if isinstance(prev, tuple) else (prev,)
        new = val if isinstance(val, tuple) else (val,)
        if len(old) != len(new):            # resharding edge: no delta
            return "init"
        up = any(n > o for o, n in zip(old, new))
        down = any(n < o for o, n in zip(old, new))
        if up and down:
            return "mixed"
        # lower TAF threshold => fewer skips => more precise
        return "tighten" if down else "loosen"

    @property
    def sharded(self) -> bool:
        return self.mesh is not None

    @property
    def mesh_shape(self) -> Optional[tuple]:
        if self.mesh is None:
            return None
        return tuple(int(self.mesh.shape[a]) for a in self.mesh.axis_names)

    def _lane_shard(self, lane: int) -> int:
        """Shards are contiguous lane ranges: lane -> owning shard."""
        return lane // self.lanes_per_shard

    @property
    def _admit_width(self) -> int:
        """Admission batch width: how many arriving requests one prefill +
        one cache splice covers. Lanes-per-shard, capped BELOW the full
        batch -- the splice tells batch rows from batchless detector
        state by their differing batch extents, so the width must not
        equal the slot count."""
        return (self.lanes_per_shard
                if self.lanes_per_shard < self.n_slots else 1)

    def _make_lane_write(self):
        """Jitted multi-lane cache surgery: splice a batch-W prefill's
        rows into the live cache at traced `lanes` (one compile covers
        every slot combination). Leaves without a batch dim (per-shard
        detector state, knob thresholds) keep their LIVE values:
        admission must not reset another lane's quality state or the
        actuated knob. This is what makes admission cost per-REQUEST
        instead of per-batch -- the full-batch re-prefill it replaced
        was ~a whole decode tick of compute per arriving request, threw
        away every ongoing lane's generated KV, and (on a mesh) stalled
        every tick of the arrival phase on eager multi-device gathers."""
        n = self.n_slots

        def write(cache, rows, tokens, row_logits, lanes):
            w = lanes.shape[0]

            def one(c, r):
                if c.ndim != r.ndim:
                    return c        # sharded detector state: per-shard
                axis = None
                for ax, (cs, rs) in enumerate(zip(c.shape, r.shape)):
                    if cs != rs:
                        if rs == w and cs == n:
                            axis = ax
                            break
                        return c    # non-batch mismatch: keep live state
                if axis is None:
                    return c        # batchless leaf (detector state)
                for j in range(w):  # w is small and static: unrolled
                    row = jax.lax.dynamic_index_in_dim(r, j, axis,
                                                       keepdims=True)
                    c = jax.lax.dynamic_update_slice_in_dim(
                        c, row.astype(c.dtype), lanes[j], axis)
                return c

            new_cache = jax.tree_util.tree_map(one, cache, rows)
            new_toks = jnp.argmax(row_logits, axis=-1).astype(tokens.dtype)
            # duplicate lanes (padding repeats row 0) carry identical
            # values, so scatter order cannot matter
            new_tokens = tokens.at[lanes].set(new_toks)
            return new_cache, new_tokens

        return jax.jit(write)

    def _place_cache(self, cache):
        """Commit every cache leaf to its canonical mesh sharding
        (`decode_partition_specs`): batch leaves over the data axis,
        detector state over its shard dim, the rest replicated. Leaves
        already resident under the right sharding pass through untouched,
        so this is cheap to call after any host-side cache surgery
        (admission prefill, knob writes) -- and calling it is what keeps
        the jitted sharded step at ONE sharding signature: mixed
        committed/uncommitted inputs would both recompile per combination
        and re-shard every leaf on every tick."""
        if self.mesh is None or cache is None:
            return cache
        from jax.sharding import NamedSharding
        from repro.runtime import sharding as shardlib
        specs = shardlib.decode_partition_specs(self.mesh, cache,
                                                self.n_slots)
        return jax.tree_util.tree_map(
            lambda leaf, spec: jax.device_put(
                leaf, NamedSharding(self.mesh, spec)), cache, specs)

    def _place_tokens(self, tokens):
        if self.mesh is None:
            return tokens
        from jax.sharding import NamedSharding
        from repro.runtime import sharding as shardlib
        return jax.device_put(
            tokens, NamedSharding(self.mesh, shardlib.batch_spec(self.mesh)))

    def _shard_cache(self, cache):
        """Convert a freshly prefilled cache to the sharded TAF layout
        (leading shard dim on the detector state) and commit it to the
        mesh. No-op unsharded."""
        if self.mesh is None or cache is None:
            return cache
        if "taf" in cache:
            from repro.models.lm import shard_taf_state
            cache = shard_taf_state(cache, self.n_shards)
        return self._place_cache(cache)

    def warmup(self):
        """Compile prefill, serve, and (QoS) the canary oracle on
        throwaway state, so the first timed tick measures decode, not
        compilation. Benchmarks call this outside their timed region --
        the PR 5 review caught single-device compile time polluting
        throughput, and the sharded step compiles are bigger still.
        Engine state is untouched."""
        with trace.span("engine.warmup", slots=self.n_slots,
                        shards=self.n_shards):
            self._warmup_body()

    def _warmup_body(self):
        prompts = jnp.zeros((self.n_slots, self.prompt_len), jnp.int32)
        logits, cache = self._prefill(self.params, {"tokens": prompts})
        cache = self._shard_cache(cache)
        tokens = self._place_tokens(
            jnp.argmax(logits, axis=-1).astype(jnp.int32))
        pos = jnp.int32(self.prompt_len)
        jax.block_until_ready(
            self._serve(self.params, cache, tokens, pos)[0])
        if self._serve_exact is not None:
            jax.block_until_ready(
                self._serve_exact(self.params, cache, tokens, pos)[0])
        if self.n_slots > 1:
            # the admission path: batch-W prefill + multi-lane splice
            w = self._admit_width
            row_logits, rows = self._prefill(
                self.params,
                {"tokens": jnp.zeros((w, self.prompt_len), jnp.int32)})
            jax.block_until_ready(self._lane_write(
                cache, rows, tokens, row_logits,
                jnp.zeros((w,), jnp.int32))[1])

    def submit(self, req: Request):
        req.submitted_at = time.time()
        self.queue.append(req)

    def _admit(self):
        """Fill free slots from the queue. The FIRST admission prefills
        the whole batch (there is no live cache yet); afterwards each
        arriving request costs one batch-1 prefill plus a per-lane cache
        splice (`_make_lane_write`), so admission is per-request work that
        leaves ongoing lanes' KV, detector state, and the actuated knob
        untouched -- a production multi-host engine admits the same way."""
        free = [i for i, r in enumerate(self.active) if r is None]
        if not free or not self.queue:
            return
        admitted = []
        for i in free:
            if not self.queue:
                break
            req = self.queue.popleft()
            self.active[i] = req
            self.pos[i] = self.prompt_len
            self.limit[i] = min(self.prompt_len + req.max_new_tokens,
                                self.max_len)
            admitted.append(i)
        if not admitted:
            return
        # batch-1 surgery cannot tell a 1-slot batch dim from batchless
        # detector state, so 1-slot engines always take the full path
        if self.cache is None or self.n_slots == 1:
            prompts = np.zeros((self.n_slots, self.prompt_len), np.int32)
            for i, r in enumerate(self.active):
                if r is not None:
                    p = r.prompt[-self.prompt_len:]
                    prompts[i, -len(p):] = p
            logits, cache = self._prefill(self.params,
                                          {"tokens": jnp.asarray(prompts)})
            self.cache = self._shard_cache(cache)
            self.tokens = self._place_tokens(
                jnp.argmax(logits, axis=-1).astype(jnp.int32))
            self._knob = None   # fresh cache: actuate on the next plan
            return
        cache, tokens = self.cache, self.tokens
        w = self._admit_width
        for g in range(0, len(admitted), w):
            grp = admitted[g:g + w]
            prompts = np.zeros((w, self.prompt_len), np.int32)
            lanes = np.zeros((w,), np.int32)
            for j, i in enumerate(grp):
                p = self.active[i].prompt[-self.prompt_len:]
                prompts[j, -len(p):] = p
                lanes[j] = i
            # pad short groups by re-writing row 0 (idempotent)
            for j in range(len(grp), w):
                prompts[j] = prompts[0]
                lanes[j] = lanes[0]
            row_logits, rows = self._prefill(self.params,
                                             {"tokens": jnp.asarray(prompts)})
            cache, tokens = self._lane_write(cache, rows, tokens,
                                             row_logits,
                                             jnp.asarray(lanes))
        self.cache = self._place_cache(cache)
        self.tokens = self._place_tokens(tokens)

    def _apply_knob(self, knob):
        """Write the controller-chosen TAF threshold(s) into the decode
        cache.

        The threshold is a traced input of the jitted serve step, so this
        is a pure data write -- no recompilation. `None` (precise) writes
        0.0 AND cancels in-flight predictions ("remaining"), making a hard
        fallback effective on the next token rather than after up to
        prediction_size more approximated layer-steps. Sharded engines
        pass a per-shard sequence (`TickPlan.shard_knobs`): each value
        lands on its shard's row of the threshold leaf, and only shards
        set precise have their predictions cancelled.
        """
        if isinstance(knob, (list, tuple)):
            val = tuple(0.0 if k is None else float(k) for k in knob)
        else:
            val = 0.0 if knob is None else float(knob)
        if self.cache is None or val == self._knob:
            return
        from repro.qos import set_decode_threshold
        # re-commit after the write: the threshold/remaining leaves come
        # out of host-dispatched jnp ops with default placement, and an
        # uncommitted leaf in the serve inputs costs a recompile plus a
        # per-tick re-shard of the whole cache
        self.cache = self._place_cache(set_decode_threshold(self.cache,
                                                            val))
        prev = self._knob
        self._knob = val
        # Admission re-prefills rebuild the cache and force a re-apply of
        # the SAME value (self._knob reset to None); that is maintenance,
        # not a controller decision -- only genuine value changes are
        # knob moves in the stats and the trajectory artifact.
        if not self.knob_events or self.knob_events[-1].value != val:
            self.stats.knob_moves += 1
            last = (self.knob_events[-1].value if self.knob_events
                    else prev)
            move = KnobMove(tick=self.stats.ticks, value=val,
                            previous=last,
                            reason=self._knob_reason(val, last))
            self.knob_events.append(move)
            trace.event("knob_move", tick=move.tick, value=move.value,
                        previous=move.previous, reason=move.reason)

    def tick(self) -> int:
        """One engine step: admit, decode one token for all active slots,
        retire finished requests. Returns number of live slots.

        Instrumentation contract (docs/observability.md): the obs hooks
        below are host-side timers and event appends only -- they must
        never add a `block_until_ready`, read a traced value, or perturb
        the serve signature. Zero extra compiles with obs on OR off is
        pinned by `tests/test_obs.py` via `_serve._cache_size()`, and the
        disabled-path cost by the BENCH_obs throughput-ratio gate."""
        tr_on = trace.enabled()
        rec = obs_recorder.get_recorder()
        t_tick = time.perf_counter() if (tr_on or rec is not None) else 0.0
        with trace.span("engine.tick", tick=self.stats.ticks):
            with trace.span("tick.admit"):
                self._admit()
            live = [i for i, r in enumerate(self.active) if r is not None]
            if not live:
                return 0
            lane_classes = []
            shard_classes = None
            if self.qos is not None:
                lane_classes = [self.active[i].qos_class for i in live]
                with trace.span("tick.actuate"):
                    if self.sharded:
                        shard_classes = [[] for _ in range(self.n_shards)]
                        for i in live:
                            shard_classes[self._lane_shard(i)].append(
                                self.active[i].qos_class)
                        plan = self.qos.plan_shards(shard_classes)
                        self._apply_knob(plan.shard_knobs)
                    else:
                        plan = self.qos.plan_tick(lane_classes)
                        self._apply_knob(plan.knob)
            pos = int(self.pos[live].min())  # single shared timeline pos
            pre_tokens, pre_cache = self.tokens, self.cache
            with trace.span("tick.serve", live=len(live)):
                self.tokens, logits, self.cache = self._serve(
                    self.params, self.cache, self.tokens, jnp.int32(pos))
            if self.qos is not None and self.qos.should_sample():
                # canary: the precise oracle from the SAME pre-tick state.
                # Score ONLY the live lanes -- idle/retired slots hold
                # zero-padded or stale state nobody consumes, and their
                # garbage logits would pollute the quality estimate.
                with trace.span("tick.canary"):
                    _, exact_logits, _ = self._serve_exact(
                        self.params, pre_cache, pre_tokens, jnp.int32(pos))
                    ex = np.asarray(exact_logits)
                    ap = np.asarray(logits)
                    if self.sharded:
                        # per-shard attribution: each shard's slice is
                        # scored separately, so a canary error is credited
                        # only to the shard (and the classes) that ran
                        # under that knob
                        for s in range(self.n_shards):
                            lanes = [i for i in live
                                     if self._lane_shard(i) == s]
                            if lanes:
                                self.qos.observe_shard(
                                    s, ex[lanes], ap[lanes],
                                    shard_classes[s])
                    else:
                        self.qos.observe_decode(ex[live], ap[live],
                                                lane_classes)
                self.stats.canary_ticks += 1
            with trace.span("tick.host_read"):
                toks = np.asarray(self.tokens)
                if self.cache is not None and "taf" in self.cache:
                    rem = np.asarray(self.cache["taf"]["remaining"])
                    self.stats.taf_skipped += int((rem > 0).sum())
                    self.stats.taf_total += rem.size
            now = time.time()
            with trace.span("tick.retire"):
                for i in live:
                    req = self.active[i]
                    if req.first_token_at is None:
                        req.first_token_at = now
                        self.stats.ttft_s.append(now - req.submitted_at)
                    req.output.append(int(toks[i]))
                    self.pos[i] += 1
                    self.stats.tokens_out += 1
                    done = (self.pos[i] >= self.limit[i] or
                            (req.eos_id is not None
                             and toks[i] == req.eos_id))
                    if done:
                        req.finished_at = now
                        self.stats.latency_s.append(now - req.submitted_at)
                        self.active[i] = None
                        self.stats.finished += 1
            self.stats.ticks += 1
            if self.qos is not None:
                with trace.span("tick.qos_update"):
                    if self.sharded:
                        self.qos.update_shards(shard_classes)
                    else:
                        self.qos.update(lane_classes)
        if tr_on or rec is not None:
            dt = time.perf_counter() - t_tick
            if tr_on:
                reg = obs_metrics.registry()
                reg.histogram("serving.tick_s").observe(dt)
                reg.gauge("serving.live_lanes").set(len(live))
                reg.counter("serving.tokens_out").inc(len(live))
            if rec is not None:
                # close out the note the QoS update opened for this tick
                rec.amend(tick_s=dt, live=len(live), knob=self._knob)
        return len([r for r in self.active if r is not None])

    def run_until_drained(self, max_ticks: int = 10_000) -> EngineStats:
        for _ in range(max_ticks):
            live = self.tick()
            if live == 0 and not self.queue:
                break
        return self.stats
