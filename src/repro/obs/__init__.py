"""repro.obs -- unified tracing, metrics, and flight-recorder layer.

One import surface for instrumented code::

    from repro.obs import trace, metrics, timing, recorder

    with trace.span("tick", index=i):        # Perfetto "X" span
        ...
    trace.event("knob_move", value=0.2)      # instant event
    obs.count("engine.recompiles")           # counter in BOTH sinks
    m = timing.measure(fn, x, repeats=5)     # warm + block_until_ready
    rec = recorder.get_recorder()

Contracts (enforced by tests/test_obs.py and benchmarks/obs_overhead.py,
documented in docs/observability.md):

  * zero-cost when disabled -- with no tracer installed, span()/event()/
    counter() are a single module-attribute read; the serving hot path
    shows zero extra compiles and >= 0.95 tick-throughput ratio;
  * never force device->host -- payloads are stored as given; lint rule
    A008 audits for traced values leaking into event payloads.
"""
from __future__ import annotations

from repro.obs import metrics, recorder, timing, trace  # noqa: F401
from repro.obs.metrics import percentile, stamp  # noqa: F401
from repro.obs.timing import Measurement, measure  # noqa: F401
from repro.obs.trace import (  # noqa: F401
    Tracer, counter, disable, enable, enabled, event, get_tracer, span,
    use,
)


def count(name: str, value: float = 1.0) -> None:
    """Increment `name` in the always-on metrics registry AND (when
    tracing) as a trace counter track -- the one-call idiom for tallies
    like cache hits and recompiles that belong in both BENCH stamps and
    Perfetto timelines."""
    metrics.registry().counter(name).inc(value)
    trace.counter(name, value)
