"""`python -m repro.obs.report <file.json>` -- render an obs artifact
into a human-readable summary.

Accepts either artifact the layer produces:

  * a Chrome/Perfetto trace (`{"traceEvents": [...]}`, as written by
    `Tracer.save()` / `benchmarks/run.py --trace`): prints a top-k table
    of span names by total duration, final counter values, instant-event
    counts, and (with `--timeline`) the first N spans as an indented
    wall-clock timeline;
  * a `BENCH_*.json` with an embedded `{"obs": {"metrics": ...}}` stamp:
    prints the counters/gauges/histogram summaries.

For interactive digging, load the trace file in https://ui.perfetto.dev
instead -- this CLI is the terminal-grade view.
"""
from __future__ import annotations

import argparse
import json
from collections import Counter as _TallyCounter
from typing import Dict, List


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.3f}s"
    if us >= 1e3:
        return f"{us / 1e3:.3f}ms"
    return f"{us:.1f}us"


def summarize_trace(doc: Dict, top: int = 15) -> List[str]:
    events = doc.get("traceEvents", [])
    spans = [e for e in events if e.get("ph") == "X"]
    instants = [e for e in events if e.get("ph") == "i"]
    counters = [e for e in events if e.get("ph") == "C"]

    lines = [f"trace: {len(spans)} spans, {len(instants)} events, "
             f"{len(counters)} counter samples"]

    if spans:
        total: Dict[str, float] = {}
        calls: Dict[str, int] = {}
        for e in spans:
            total[e["name"]] = total.get(e["name"], 0.0) + e.get("dur", 0.0)
            calls[e["name"]] = calls.get(e["name"], 0) + 1
        lines.append("")
        lines.append(f"top {min(top, len(total))} spans by total time:")
        lines.append(f"  {'name':<36} {'calls':>6} {'total':>10} {'mean':>10}")
        for name, dur in sorted(total.items(), key=lambda kv: -kv[1])[:top]:
            n = calls[name]
            lines.append(f"  {name:<36} {n:>6} {_fmt_us(dur):>10} "
                         f"{_fmt_us(dur / n):>10}")

    if counters:
        finals: Dict[str, float] = {}
        for e in counters:  # samples are cumulative; last one wins
            finals[e["name"]] = e.get("args", {}).get("value", 0.0)
        lines.append("")
        lines.append("counters (final):")
        for name in sorted(finals):
            lines.append(f"  {name:<36} {finals[name]:g}")

    if instants:
        tally = _TallyCounter(e["name"] for e in instants)
        lines.append("")
        lines.append("events:")
        for name, n in tally.most_common():
            lines.append(f"  {name:<36} {n}")
    return lines


def timeline(doc: Dict, limit: int = 40) -> List[str]:
    spans = sorted((e for e in doc.get("traceEvents", [])
                    if e.get("ph") == "X"), key=lambda e: e.get("ts", 0.0))
    lines = [f"timeline (first {min(limit, len(spans))} of {len(spans)} "
             f"spans):"]
    # Indent by how many earlier spans are still open at this start time.
    open_ends: List[float] = []
    for e in spans[:limit]:
        ts, dur = e.get("ts", 0.0), e.get("dur", 0.0)
        open_ends = [t for t in open_ends if t > ts]
        depth = len(open_ends)
        open_ends.append(ts + dur)
        lines.append(f"  {_fmt_us(ts):>10}  {'  ' * depth}{e['name']} "
                     f"[{_fmt_us(dur)}]")
    return lines


def summarize_metrics(snap: Dict) -> List[str]:
    lines = []
    if snap.get("counters"):
        lines.append("counters:")
        for name, v in snap["counters"].items():
            lines.append(f"  {name:<36} {v:g}")
    if snap.get("gauges"):
        lines.append("gauges:")
        for name, v in snap["gauges"].items():
            lines.append(f"  {name:<36} {v:g}")
    if snap.get("histograms"):
        lines.append("histograms:")
        for name, h in snap["histograms"].items():
            mean = h.get("mean")
            p50, p99 = h.get("p50"), h.get("p99")
            lines.append(
                f"  {name:<36} n={h.get('count', 0)}"
                + (f" mean={mean:.6g}" if mean is not None else "")
                + (f" p50={p50:.6g}" if p50 is not None else "")
                + (f" p99={p99:.6g}" if p99 is not None else ""))
    return lines or ["(no metrics)"]


def render(doc: Dict, top: int = 15, show_timeline: bool = False,
           timeline_limit: int = 40) -> str:
    lines: List[str] = []
    if "traceEvents" in doc:
        lines += summarize_trace(doc, top=top)
        if show_timeline:
            lines.append("")
            lines += timeline(doc, limit=timeline_limit)
    elif "obs" in doc:
        lines.append("embedded obs metrics stamp "
                     f"(schema {doc['obs'].get('schema')}):")
        lines += summarize_metrics(doc["obs"].get("metrics", {}))
    elif "counters" in doc or "histograms" in doc:
        lines += summarize_metrics(doc)
    else:
        lines.append("no obs data found (expected traceEvents or an "
                     "'obs' stamp)")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize an obs trace or BENCH metrics stamp.")
    ap.add_argument("path", help="trace JSON or BENCH_*.json")
    ap.add_argument("--top", type=int, default=15,
                    help="rows in the top-spans table")
    ap.add_argument("--timeline", action="store_true",
                    help="also print a wall-clock span timeline")
    ap.add_argument("--timeline-limit", type=int, default=40)
    args = ap.parse_args(argv)
    with open(args.path) as f:
        doc = json.load(f)
    print(render(doc, top=args.top, show_timeline=args.timeline,
                 timeline_limit=args.timeline_limit))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
